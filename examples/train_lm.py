"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with geo-enriched synthetic data, checkpoints and an injected failure.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil

import jax

from repro.configs.base import ModelConfig, RunConfig
from repro.core.cells import build_cell_covering
from repro.core.fast import FastConfig, FastIndex
from repro.core.synth import build_synth_census
from repro.data.pipeline import make_source
from repro.models.model import build_model
from repro.models.module import init_params, param_count
from repro.optim import adamw
from repro.runtime.driver import DriverConfig, train_loop
from repro.runtime.steps import make_train_step

# ~103M params: 12L x 768d, llama-style.
CFG = ModelConfig(name="demo-100m", family="dense", n_layers=12,
                  d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                  vocab=32000, act="swiglu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    run = RunConfig(remat="none", learning_rate=3e-4, schedule="cosine",
                    total_steps=args.steps, warmup_steps=20,
                    attn_chunk_q=128, attn_chunk_kv=128)
    model = build_model(CFG)
    params = init_params(model.specs, jax.random.key(0))
    opt = adamw.init(params)
    print(f"[example] {CFG.name}: {param_count(model.specs)/1e6:.1f}M params")

    # Geo-enriched pipeline: each sequence carries a location joined onto
    # the synthetic census via the paper's fast index.
    sc = build_synth_census(seed=1)
    cov = build_cell_covering(sc.census, max_level=8)
    geo = (FastIndex.from_covering(cov, sc.census, gbits=4),
           FastConfig(mode="approx"))

    class Shape:
        global_batch = args.batch
        seq_len = args.seq
    src = make_source(CFG, Shape, seed=0, geo=geo)

    step_fn = jax.jit(make_train_step(model, run))
    dcfg = DriverConfig(total_steps=args.steps, ckpt_every=100,
                        ckpt_dir=args.ckpt_dir, log_every=20)
    # Inject one failure mid-run to demonstrate checkpoint/restart.
    params, opt, hist = train_loop(step_fn, params, opt, src, dcfg,
                                   fail_at={args.steps // 2})
    print(f"[example] loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"({hist['steps_run']} steps, {hist['restarts']} restart)")
    assert hist["loss"][-1] < hist["loss"][0]


if __name__ == "__main__":
    main()
