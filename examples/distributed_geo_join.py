"""Distributed geo join on 8 simulated devices, two flavours:

  * replicated-points lookup (core/distributed.py): every model-rank scans
    the whole batch against its Morton slice, an i32 pmax combines;
  * dispatch-routed lookup (GeoEngine.assign_sharded): points are bucketed
    by owning shard through the MoE dispatch primitive, so each rank
    resolves only the ~N/S points it owns (DESIGN.md §2, §6).

    PYTHONPATH=src python examples/distributed_geo_join.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.cells import build_cell_covering  # noqa: E402
from repro.core.distributed import assign_fast_distributed, \
    shard_covering  # noqa: E402
from repro.core.engine import EngineConfig, GeoEngine  # noqa: E402
from repro.core.fast import FastConfig  # noqa: E402
from repro.core.synth import build_synth_census  # noqa: E402
from repro.launch.mesh import make_test_mesh, use_mesh  # noqa: E402


def main():
    sc = build_synth_census(seed=0, n_states=16, counties_per_state=8,
                            blocks_per_county=24)
    cov = build_cell_covering(sc.census, max_level=9)
    mesh = make_test_mesh((2, 4))       # ("data", "model")
    sidx = shard_covering(cov, sc.census, n_shards=4)
    print(f"[dist] {len(cov.lo)} cells -> 4 Morton shards, "
          f"{sidx.index_bytes_per_shard()/1e6:.2f} MB/shard "
          f"(vs {cov.nbytes()/1e6:.2f} MB replicated)")

    rng = np.random.default_rng(7)
    xy, bid, cid, sid = sc.sample_points(rng, 65536)
    cfg = FastConfig(mode="exact", cap_boundary=0.5)
    with use_mesh(mesh):
        f = jax.jit(lambda p: assign_fast_distributed(sidx, p, mesh, cfg))
        s, c, b, stats = f(jnp.asarray(xy))   # compile
        t0 = time.perf_counter()
        s, c, b, stats = f(jnp.asarray(xy))
        b.block_until_ready()
        dt = time.perf_counter() - t0
    acc = float(np.mean(np.asarray(b) == bid))
    print(f"[dist] {len(xy)/dt/1e6:.2f}M pts/s on {mesh.devices.size} "
          f"devices, accuracy {acc:.4f}, "
          f"PIP evals/pt {int(stats['n_pip'])/len(xy):.3f}")
    assert acc == 1.0

    # Same lookup through the engine facade, dispatch-routed: each shard
    # receives only its own points (capacity-bucketed, drops counted).
    engine = GeoEngine.build(sc.census, "fast",
                             EngineConfig(mode="exact", cap_boundary=0.5),
                             covering=cov)
    with use_mesh(mesh):
        g = jax.jit(lambda p: engine.assign_sharded(p, mesh))
        res = g(jnp.asarray(xy))      # compile
        t0 = time.perf_counter()
        res = g(jnp.asarray(xy))
        res.block.block_until_ready()
        dt = time.perf_counter() - t0
    acc = float(np.mean(np.asarray(res.block) == bid))
    print(f"[engine] {len(xy)/dt/1e6:.2f}M pts/s dispatch-routed, "
          f"accuracy {acc:.4f}, dropped {int(res.stats.extra['n_dropped'])}")
    assert acc == 1.0


if __name__ == "__main__":
    main()
