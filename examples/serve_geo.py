"""Serving quickstart: GeoServer over a synthetic census — micro-batched
mixed-size requests, hot-cell caching, live metrics, and a two-region
router (DESIGN.md §10).

    PYTHONPATH=src python examples/serve_geo.py
"""
import json

import numpy as np

from repro.core.engine import EngineConfig, GeoEngine
from repro.core.synth import build_synth_census
from repro.serving import GeoServer, ServeConfig


def main():
    # 1. Build a census and a serving engine (any strategy works; hybrid
    #    balances boundary accuracy against candidate-PIP volume).
    print("building synthetic census...")
    sc = build_synth_census(seed=0, n_states=16, counties_per_state=8,
                            blocks_per_county=24)
    engine = GeoEngine.build(sc.census, "hybrid",
                             EngineConfig(cap_boundary=0.5))
    server = GeoServer(engine, ServeConfig(buckets=(256, 1024, 4096)))

    # 2. Warm: pre-pay every bucket's JIT before traffic arrives.
    print("warming buckets:", {b: f"{t:.2f}s"
                               for b, t in server.warm().items()})

    # 3. A bursty request stream: mixed sizes, 30% re-queries of a hot
    #    pool (popular venues) — the hot-cell cache's home turf.
    rng = np.random.default_rng(7)
    xy, bid, *_ = sc.sample_points(rng, 50_000)
    hot = xy[rng.choice(len(xy), 128, replace=False)]
    served = correct = 0
    off = 0
    while off < len(xy):
        if rng.uniform() < 0.3:
            req = hot[rng.integers(0, len(hot), 64)]
            res = server.submit(req)
        else:
            size = int(rng.integers(1, 4096))
            req, truth = xy[off:off + size], bid[off:off + size]
            res = server.submit(req)
            correct += int(np.sum(res.block == truth))
            off += len(req)
        served += len(req)
    print(f"served {served} points; batch-stream accuracy "
          f"{correct / off:.4f}")

    # 4. The live metrics snapshot (what a /metrics endpoint would serve).
    print(json.dumps(server.snapshot(), indent=2, sort_keys=True))

    # 5. Multi-region routing: two regional engines behind one submit().
    scW = build_synth_census(seed=3, n_states=4, counties_per_state=4,
                             blocks_per_county=8,
                             extent=(-120.0, -100.0, 30.0, 45.0))
    scE = build_synth_census(seed=4, n_states=4, counties_per_state=4,
                             blocks_per_county=8,
                             extent=(-100.0, -80.0, 30.0, 45.0))
    router = GeoServer(
        [GeoEngine.build(scW.census, "fast"),
         GeoEngine.build(scE.census, "fast")],
        ServeConfig(buckets=(256, 1024)))
    xyW, *_ = scW.sample_points(rng, 300)
    xyE, *_ = scE.sample_points(rng, 300)
    nowhere = np.array([[-150.0, 10.0]], np.float32)
    res = router.submit(np.concatenate([xyW, xyE, nowhere]))
    counts = {int(r): int(n) for r, n in
              zip(*np.unique(res.region, return_counts=True))}
    print(f"router: {counts[0]} points -> region 0 (west), "
          f"{counts[1]} -> region 1 (east), "
          f"{counts.get(-1, 0)} in no region (block "
          f"{res.block[-1]})")


if __name__ == "__main__":
    main()
