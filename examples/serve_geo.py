"""Serving quickstart: GeoServer over a synthetic census — micro-batched
mixed-size requests, hot-cell caching, deadline flushes, live metrics,
artifact cold start, and a two-region router (DESIGN.md §10, §11).

    PYTHONPATH=src python examples/serve_geo.py
"""
import json
import tempfile

import numpy as np

from repro.core.engine import EngineConfig, GeoEngine
from repro.core.synth import build_synth_census
from repro.serving import GeoServer, ServeConfig


def main():
    # 1. Build a census and a serving engine.  strategy="auto" lets the
    #    planner pick; max_delay_ms bounds how long a trickle request can
    #    sit in the queue before a flush fires (latency SLO).
    print("building synthetic census...")
    sc = build_synth_census(seed=0, n_states=16, counties_per_state=8,
                            blocks_per_county=24)
    engine = GeoEngine.build(sc.census, "auto",
                             EngineConfig(cap_boundary=0.5))
    print(f"planner chose {engine.explain()['strategy']!r}")
    server = GeoServer(engine, ServeConfig(buckets=(256, 1024, 4096),
                                           max_delay_ms=50.0))

    # 2. Warm: pre-pay every bucket's JIT before traffic arrives.
    print("warming buckets:", {b: f"{t:.2f}s"
                               for b, t in server.warm().items()})

    # 3. A bursty request stream: mixed sizes, 30% re-queries of a hot
    #    pool (popular venues) — the hot-cell cache's home turf.
    rng = np.random.default_rng(7)
    xy, bid, *_ = sc.sample_points(rng, 50_000)
    hot = xy[rng.choice(len(xy), 128, replace=False)]
    served = correct = 0
    off = 0
    while off < len(xy):
        if rng.uniform() < 0.3:
            req = hot[rng.integers(0, len(hot), 64)]
            res = server.submit(req)
        else:
            size = int(rng.integers(1, 4096))
            req, truth = xy[off:off + size], bid[off:off + size]
            res = server.submit(req)
            correct += int(np.sum(res.block == truth))
            off += len(req)
        served += len(req)
    print(f"served {served} points; batch-stream accuracy "
          f"{correct / off:.4f}")

    # 4. The live metrics snapshot (what a /metrics endpoint would serve;
    #    deadline_flushes appears once max_delay_ms ever fires).
    print(json.dumps(server.snapshot(), indent=2, sort_keys=True))

    # 5. Cold start: persist the index artifact once, then bring up a
    #    fresh server from disk — no covering BFS on the restart path.
    # The artifact stores geometry, not engine knobs: pass the same
    # EngineConfig (capacity fractions etc.) for bit-identical serving.
    with tempfile.TemporaryDirectory() as tmp:
        engine.indices.save(tmp)
        cold = GeoServer.from_artifact(tmp, strategy="auto",
                                       engine_cfg=engine.cfg,
                                       cfg=ServeConfig(buckets=(256,
                                                                1024)))
        probe = xy[:512]
        same = np.array_equal(cold.submit(probe).block,
                              server.submit(probe).block)
        print(f"cold-started server from artifact: bit-identical={same}")

    # 6. Multi-region routing: two regional engines behind one submit().
    scW = build_synth_census(seed=3, n_states=4, counties_per_state=4,
                             blocks_per_county=8,
                             extent=(-120.0, -100.0, 30.0, 45.0))
    scE = build_synth_census(seed=4, n_states=4, counties_per_state=4,
                             blocks_per_county=8,
                             extent=(-100.0, -80.0, 30.0, 45.0))
    router = GeoServer(
        [GeoEngine.build(scW.census, "fast"),
         GeoEngine.build(scE.census, "fast")],
        ServeConfig(buckets=(256, 1024)))
    xyW, *_ = scW.sample_points(rng, 300)
    xyE, *_ = scE.sample_points(rng, 300)
    nowhere = np.array([[-150.0, 10.0]], np.float32)
    res = router.submit(np.concatenate([xyW, xyE, nowhere]))
    counts = {int(r): int(n) for r, n in
              zip(*np.unique(res.region, return_counts=True))}
    print(f"router: {counts[0]} points -> region 0 (west), "
          f"{counts[1]} -> region 1 (east), "
          f"{counts.get(-1, 0)} in no region (block "
          f"{res.block[-1]})")


if __name__ == "__main__":
    main()
