"""Streaming analytics quickstart: a GeoServer with the windowed
analytics mount — point traffic becomes per-block occupancy windows,
crowding density, top-k crowded blocks, and k-anonymity suppression,
all without a second pass over the data (DESIGN.md §16).

    PYTHONPATH=src python examples/analytics_geo.py
"""
import numpy as np

from repro.analytics import AnalyticsConfig, BlockAggregator
from repro.core.engine import GeoEngine
from repro.core.synth import build_synth_census
from repro.serving import GeoServer, ServeConfig


def main():
    # 1. A census and an engine, as ever; the analytics mount is one
    #    config field.  window_s=8/slide_s=2 → sliding windows of 4
    #    panes; k_anon=5 suppresses any block seen by <5 distinct
    #    sources; the injected clock makes the demo deterministic.
    print("building synthetic census...")
    sc = build_synth_census(seed=3, n_states=8, counties_per_state=6,
                            blocks_per_county=16)
    engine = GeoEngine.build(sc.census, "fast")
    now = [0.0]
    server = GeoServer(engine, ServeConfig(
        buckets=(1024, 4096),
        analytics=AnalyticsConfig(window_s=8.0, slide_s=2.0, k_anon=5,
                                  sketch_bits=2048,
                                  clock=lambda: now[0])))
    server.warm()

    # 2. Traffic with structure: a background of uniform points plus a
    #    "venue" hotspot — one block that 40% of sources flock to.
    rng = np.random.default_rng(11)
    xy, bid, *_ = sc.sample_points(rng, 40_000)
    venue_block = int(np.bincount(bid[bid >= 0]).argmax())
    venue_pts = xy[bid == venue_block]
    print(f"venue block: {venue_block} ({len(venue_pts)} sampled pts)")

    off = 0
    stream = []
    for second in range(16):          # 16 simulated seconds of traffic
        now[0] = float(second)
        req = xy[off:off + 2048]
        off += len(req)
        if len(venue_pts) and second >= 4:   # the crowd arrives at t=4
            extra = venue_pts[rng.integers(0, len(venue_pts), 1024)]
            req = np.concatenate([req, extra])
        stream.append(req)
        server.submit(req)
    now[0] = 32.0                     # push the watermark: one trailing
    server.submit(xy[:1])             # batch closes every open window

    # 3. The analytics snapshot: per-region window history.  Each
    #    finalized window publishes suppression-filtered top-k rows —
    #    blocks under the k_anon floor are counted but never named.
    snap = server.snapshot_analytics()
    region = snap["regions"][0]
    print(f"\nobserved {region['observed']} points "
          f"({region['off_map']} off-map), "
          f"{region['finalized_total']} windows finalized")
    for w in region["finalized"][-4:]:
        top = ", ".join(f"block {r['block']}: {r['count']}"
                        f" ({r['distinct']} sources)"
                        for r in w["top"][:3])
        print(f"  [{w['start']:5.1f}, {w['end']:5.1f})  "
              f"{w['n_events']:6d} events  "
              f"{w['active_blocks']:4d} active  "
              f"{w['suppressed_blocks']:4d} suppressed  top: {top}")

    # 4. The batch layer under the same roof: one fused assign→aggregate
    #    call gives whole-stream occupancy, density, and an HVI-style
    #    composite (z-scored density + occupancy, 60/40 blend).
    agg = BlockAggregator.from_engine(engine)
    counts = agg.fused_counts(np.concatenate(stream))
    density = agg.density(counts)
    hvi = agg.weighted_index(
        np.stack([density, counts.astype(np.float64)], axis=1),
        [0.6, 0.4])
    top = np.argsort(-hvi)[:5]
    print("\nwhole-stream composite index (density 0.6 / occupancy 0.4):")
    for b in top:
        print(f"  block {int(b):5d}  count {int(counts[b]):5d}  "
              f"density {density[b]:9.1f}  index {hvi[b]:6.2f}")
    assert int(top[0]) == venue_block or counts[top[0]] >= counts.max()


if __name__ == "__main__":
    main()
