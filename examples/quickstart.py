"""Quickstart: map locations onto census blocks with both paper approaches.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.cells import build_cell_covering
from repro.core.fast import FastConfig, FastIndex, assign_fast
from repro.core.simple import SimpleConfig, SimpleIndex, assign_simple
from repro.core.synth import build_synth_census


def main():
    # 1. A synthetic census: 16 states / 128 counties / 3,072 block groups
    #    (same structure as the real data; see core/synth.py).
    print("building synthetic census...")
    sc = build_synth_census(seed=0, n_states=16, counties_per_state=8,
                            blocks_per_county=24)
    census = sc.census
    print(f"  states={census.states.n_poly} counties={census.counties.n_poly}"
          f" blocks={census.blocks.n_poly}")

    # 2. A batch of device locations with known ground truth.
    rng = np.random.default_rng(7)
    xy, bid, cid, sid = sc.sample_points(rng, 100_000)
    pts = jnp.asarray(xy)

    # 3. SIMPLE approach (paper §III): hierarchical bbox cascade + PIP.
    sidx = SimpleIndex.from_census(census)
    cfg = SimpleConfig(cap_state=0.5, cap_county=0.5, cap_block=0.5)
    s, c, b, stats = assign_simple(sidx, pts, cfg)   # warm up + compile
    t0 = time.perf_counter()
    s, c, b, stats = assign_simple(sidx, pts, cfg)
    b.block_until_ready()
    dt = time.perf_counter() - t0
    acc = float(np.mean(np.asarray(b) == bid))
    pip = sum(int(stats[k]["n_pip"]) for k in stats) / len(xy)
    print(f"simple: {len(xy)/dt/1e6:.2f}M pts/s, accuracy {acc:.4f}, "
          f"{pip:.3f} PIP evals/pt")

    # 4. FAST approach (paper §IV): true-hit-filter cell index.
    print("building cell covering...")
    cov = build_cell_covering(census, max_level=9)
    fidx = FastIndex.from_covering(cov, census, gbits=4)
    fcfg = FastConfig(mode="exact", cap_boundary=0.5)
    *_, b2, fstats = assign_fast(fidx, pts, fcfg)
    t0 = time.perf_counter()
    s2, c2, b2, fstats = assign_fast(fidx, pts, fcfg)
    b2.block_until_ready()
    dt2 = time.perf_counter() - t0
    acc2 = float(np.mean(np.asarray(b2) == bid))
    print(f"fast (exact): {len(xy)/dt2/1e6:.2f}M pts/s, accuracy {acc2:.4f},"
          f" {int(fstats['n_pip'])/len(xy):.3f} PIP evals/pt, "
          f"index {fidx.nbytes()/1e6:.1f} MB")

    *_, b3, _ = assign_fast(fidx, pts, FastConfig(mode="approx"))
    t0 = time.perf_counter()
    *_, b3, _ = assign_fast(fidx, pts, FastConfig(mode="approx"))
    b3.block_until_ready()
    dt3 = time.perf_counter() - t0
    acc3 = float(np.mean(np.asarray(b3) == bid))
    print(f"fast (approx): {len(xy)/dt3/1e6:.2f}M pts/s, accuracy {acc3:.4f}"
          f" (error bounded by one leaf cell)")


if __name__ == "__main__":
    main()
