"""Quickstart: map locations onto census blocks with every GeoEngine
strategy — the paper's simple (§III) and fast (§IV) approaches plus the
engine's hybrid mode.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, GeoEngine
from repro.core.synth import build_synth_census


def timed_assign(engine, pts):
    res = engine.assign(pts)                  # warm up + compile
    res.block.block_until_ready()
    t0 = time.perf_counter()
    res = engine.assign(pts)
    res.block.block_until_ready()
    return res, time.perf_counter() - t0


def main():
    # 1. A synthetic census: 16 states / 128 counties / 3,072 block groups
    #    (same structure as the real data; see core/synth.py).
    print("building synthetic census...")
    sc = build_synth_census(seed=0, n_states=16, counties_per_state=8,
                            blocks_per_county=24)
    census = sc.census
    print(f"  states={census.states.n_poly} counties={census.counties.n_poly}"
          f" blocks={census.blocks.n_poly}")

    # 2. A batch of device locations with known ground truth.
    rng = np.random.default_rng(7)
    xy, bid, cid, sid = sc.sample_points(rng, 100_000)
    pts = jnp.asarray(xy)

    # 3. One facade, four strategy/mode combinations.  The covering is
    #    built once and shared by the cell-index strategies.
    print("building cell covering...")
    covering = None
    for label, strategy, cfg in (
        ("simple      ", "simple",
         EngineConfig(cap_state=0.5, cap_county=0.5, cap_block=0.5)),
        ("fast (exact)", "fast", EngineConfig(mode="exact",
                                              cap_boundary=0.5)),
        ("fast (approx)", "fast", EngineConfig(mode="approx")),
        ("hybrid      ", "hybrid", EngineConfig(cap_boundary=0.5)),
    ):
        engine = GeoEngine.build(census, strategy, cfg, covering=covering)
        covering = covering or engine.covering
        res, dt = timed_assign(engine, pts)
        acc = float(np.mean(np.asarray(res.block) == bid))
        print(f"{label}: {len(xy)/dt/1e6:5.2f}M pts/s, accuracy {acc:.4f},"
              f" {int(res.stats.n_pip)/len(xy):.3f} PIP evals/pt,"
              f" overflow {int(res.stats.overflow)}")

    # 4. Or skip the choice entirely: strategy="auto" asks the planner
    #    (device kind, measured boundary fraction, index capabilities)
    #    and explain() says what it chose and why.
    engine = GeoEngine.build(census, "auto", covering=covering)
    plan = engine.explain()
    res, dt = timed_assign(engine, pts)
    acc = float(np.mean(np.asarray(res.block) == bid))
    print(f"auto -> {plan['strategy']:7s}: {len(xy)/dt/1e6:5.2f}M pts/s, "
          f"accuracy {acc:.4f}")
    for reason in plan["reasons"]:
        print(f"  because: {reason}")


if __name__ == "__main__":
    main()
