"""Serve a small LM with batched requests: prefill + greedy decode with a
KV cache, reporting tokens/s.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models.model import build_model
from repro.models.module import init_params, param_count
from repro.runtime.steps import make_serve_step

CFG = ModelConfig(name="demo-serve-25m", family="dense", n_layers=6,
                  d_model=512, n_heads=8, n_kv_heads=4, d_ff=1408,
                  vocab=32000, act="swiglu")


def main():
    run = RunConfig(remat="none", attn_chunk_q=64, attn_chunk_kv=64)
    model = build_model(CFG)
    params = init_params(model.specs, jax.random.key(0))
    print(f"[serve_lm] {param_count(model.specs)/1e6:.1f}M params")

    batch, prompt_len, gen = 8, 64, 32
    max_len = prompt_len + gen
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, CFG.vocab, (batch, prompt_len)),
                          jnp.int32)

    prefill = jax.jit(lambda p, t: model.prefill(p, run, t, max_len))
    serve_step = jax.jit(make_serve_step(model, run))

    # Warm-up compiles.
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    tok, cache = serve_step(params, tok, cache)

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    toks = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        tok, cache = serve_step(params, tok, cache)
        toks.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0

    out = np.asarray(jnp.concatenate(toks, 1))
    print(f"[serve_lm] prefill {batch}x{prompt_len}: "
          f"{batch*prompt_len/t_prefill:.0f} tok/s; decode: "
          f"{batch*(gen-1)/t_dec:.0f} tok/s")
    print("[serve_lm] first sequence:", out[0][:16])
    assert out.shape == (batch, gen)


if __name__ == "__main__":
    main()
