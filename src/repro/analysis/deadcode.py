"""Import-hygiene + unreachable-code rules (DESIGN.md §17, rule ids
``unused-import`` and ``unreachable``).

``unused-import`` is a deliberately conservative pyflakes-lite: a name
bound by ``import`` / ``from ... import`` is unused when it appears in
no other ``Name`` node in the module and not in the module's
``__all__`` list (package ``__init__`` re-exports are public surface,
not dead weight).  ``from __future__ import ...`` and ``import x  #
noqa``-style side-effect imports suppressed with ``# geolint:
ignore[unused-import] -- reason`` are exempt.

``unreachable`` flags statements that follow a terminal statement
(``return`` / ``raise`` / ``break`` / ``continue``) in the same block —
the classic leftovers of a refactor.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.common import (RULE_UNREACHABLE, RULE_UNUSED_IMPORT,
                                   Finding, SourceModule)

__all__ = ["check_unused_imports", "check_unreachable"]


def _exported_names(tree: ast.Module) -> set[str]:
    """String entries of a module-level ``__all__`` list/tuple."""
    out: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets):
            if isinstance(node.value, (ast.List, ast.Tuple)):
                for elt in node.value.elts:
                    if isinstance(elt, ast.Constant) and \
                            isinstance(elt.value, str):
                        out.add(elt.value)
    return out


def check_unused_imports(mods: Iterable[SourceModule]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in mods:
        bound: list[tuple[str, int, str]] = []   # (local name, line, what)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    local = a.asname or a.name.split(".")[0]
                    bound.append((local, node.lineno, f"import {a.name}"))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for a in node.names:
                    if a.name == "*":
                        continue
                    local = a.asname or a.name
                    bound.append((local, node.lineno,
                                  f"from {node.module or '.'} "
                                  f"import {a.name}"))
        if not bound:
            continue
        import_lines = {ln for _, ln, _ in bound}
        used: set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Name) and \
                    node.lineno not in import_lines:
                used.add(node.id)
        used |= _exported_names(mod.tree)
        for local, line, what in bound:
            if local in used or local.startswith("_"):
                continue
            if mod.suppressed(RULE_UNUSED_IMPORT, line):
                continue
            findings.append(Finding(
                RULE_UNUSED_IMPORT, mod.path, line,
                f"'{what}' binds '{local}', never used in this module "
                f"(and not re-exported via __all__)"))
    return findings


_TERMINAL = (ast.Return, ast.Raise, ast.Break, ast.Continue)


def check_unreachable(mods: Iterable[SourceModule]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in mods:
        for node in ast.walk(mod.tree):
            for field in ("body", "orelse", "finalbody"):
                block = getattr(node, field, None)
                if not isinstance(block, list):
                    continue
                for i, stmt in enumerate(block[:-1]):
                    if isinstance(stmt, _TERMINAL):
                        nxt = block[i + 1]
                        if mod.suppressed(RULE_UNREACHABLE, nxt.lineno):
                            break
                        findings.append(Finding(
                            RULE_UNREACHABLE, mod.path, nxt.lineno,
                            f"statement unreachable after "
                            f"'{type(stmt).__name__.lower()}' on line "
                            f"{stmt.lineno}"))
                        break
    return findings
