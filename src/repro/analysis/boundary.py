"""Compat-boundary checker (DESIGN.md §17, rule id ``compat-boundary``).

DESIGN.md §12's rule: the repo runs on jax 0.4.x *and* 0.5+, and the
only module allowed to touch version-gated jax surface is
``src/repro/compat.py``.  Everything else imports the compat wrappers
(``use_mesh``, ``get_abstract_mesh``, ``shard_map``,
``with_sharding_constraint``).  A direct use anywhere else is a latent
AttributeError on one jax generation — exactly the class of bug that
took 27 model-stack tests down before PR 5.

Flagged outside ``compat.py``:

  * any ``jax._src`` import or attribute chain (private API — no
    stability contract at all);
  * the version-gated public symbols: ``jax.set_mesh``,
    ``jax.shard_map``, ``jax.sharding.get_abstract_mesh``,
    ``jax.sharding.AxisType``, ``jax.experimental.shard_map.shard_map``
    — as imports *and* as attribute references;
  * the legacy ``check_rep=`` keyword (0.4.x spelling of
    ``check_vma`` — callers must go through ``compat.shard_map``,
    which translates).

``hasattr(jax, "set_mesh")``-style *probes* are fine anywhere (the
string literal is not an attribute access); in practice they too live
only in compat.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.common import (RULE_BOUNDARY, Finding, SourceModule,
                                   dotted_name)

__all__ = ["check_boundary", "ALLOWED_FILES"]

# Module basenames allowed to touch gated symbols (repo-relative match
# on the path tail).  compat.py is the sanctioned surface.
ALLOWED_FILES = ("repro/compat.py",)

_PRIVATE_PREFIX = "jax._src"

# (module, name) pairs whose import is version-gated.
_GATED_IMPORTS = {
    ("jax", "set_mesh"),
    ("jax", "shard_map"),
    ("jax.sharding", "get_abstract_mesh"),
    ("jax.sharding", "AxisType"),
    ("jax.experimental.shard_map", "shard_map"),
}

# Fully-dotted attribute chains whose *reference* is version-gated.
_GATED_ATTRS = {
    "jax.set_mesh",
    "jax.shard_map",
    "jax.sharding.get_abstract_mesh",
    "jax.sharding.AxisType",
}

_GATED_KWARGS = {"check_rep"}


def _allowed(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(norm.endswith(tail) for tail in ALLOWED_FILES)


def check_boundary(mods: Iterable[SourceModule]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in mods:
        if _allowed(mod.path):
            continue
        for node in ast.walk(mod.tree):
            hits: list[str] = []
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith(_PRIVATE_PREFIX):
                        hits.append(f"import {a.name}")
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module.startswith(_PRIVATE_PREFIX):
                    hits.append(f"from {node.module} import ...")
                else:
                    for a in node.names:
                        if (node.module, a.name) in _GATED_IMPORTS:
                            hits.append(
                                f"from {node.module} import {a.name}")
            elif isinstance(node, ast.Attribute):
                name = dotted_name(node)
                if name is None:
                    pass
                elif name.startswith(_PRIVATE_PREFIX):
                    hits.append(name)
                elif name in _GATED_ATTRS:
                    # Only flag the full chain once (the walk also visits
                    # the inner Attribute nodes, whose dotted names are
                    # prefixes and never in the gated set).
                    hits.append(name)
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in _GATED_KWARGS:
                        hits.append(f"{kw.arg}= (0.4.x-only kwarg; use "
                                    f"compat.shard_map(check_vma=...))")
            for what in hits:
                if mod.suppressed(RULE_BOUNDARY, node.lineno):
                    continue
                findings.append(Finding(
                    RULE_BOUNDARY, mod.path, node.lineno,
                    f"version-gated jax surface outside compat.py: "
                    f"{what} (DESIGN.md §12 — route through "
                    f"repro.compat)"))
    return findings
