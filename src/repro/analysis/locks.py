"""Lock-discipline checker + wall-clock lint (DESIGN.md §17, rule ids
``lock-discipline`` and ``wallclock``).

The serving/analytics stack's concurrency story rests on a single-guard
lock table (DESIGN.md §14): every mutable shared field has exactly one
owning lock, and every write runs under it.  This checker makes that
table *executable*: fields are annotated at their initialising
assignment with ``# guarded-by: <lock>`` and the checker flags any
write to an annotated field that is not lexically inside a
``with <base>.<lock>:`` block.

Annotation grammar (two scopes, deliberately distinct):

  * **instance-private** — annotation on a ``self.<field> = ...`` line
    inside a method (normally ``__init__``).  Checked for ``self``
    writes *within the declaring class only*: the field is an
    implementation detail and outside code never touches it.
  * **shared** — annotation on a class-level field (dataclass style,
    e.g. ``_Region.stats``).  Checked at **every** write site in the
    analyzed tree, whatever the base expression: ``region.stats = ...``
    must sit inside ``with region.lock:`` — same base, owning lock.

A helper that is documented as "called with the lock held" (the
``WindowedAggregator`` state machine) declares it with
``# requires-lock: <lock>`` on its ``def`` line; its body then counts
as guarded for the lexical checker, and the runtime detector
(lockcheck.py) verifies the claim on every instrumented test run.

Writes are assignments, augmented assignments, deletes, and container
stores through the field (``self.counters[k] = v`` is a write to
``counters``).  Mutating *method* calls (``.append``/``.popitem``) are
out of lexical reach — the runtime detector's attribute hook and the
thread batteries cover those paths.

The wall-clock lint (``wallclock``) flags every ``time.time()`` call:
latency and deadline arithmetic must use a monotonic clock
(``time.monotonic()`` / ``time.perf_counter()``) — wall time jumps
(NTP slew, DST, manual set) and a latency window or flush deadline
computed from it silently corrupts.  Sites that *mean* wall time
(event-time stamping) annotate ``# wallclock-ok: <reason>``.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Optional

from repro.analysis.common import (RULE_LOCKS, RULE_WALLCLOCK, Finding,
                                   SourceModule, dotted_name,
                                   import_aliases, resolve_call_name)

__all__ = ["FieldGuard", "collect_guards", "check_locks",
           "check_wallclock"]


@dataclasses.dataclass(frozen=True)
class FieldGuard:
    """One ``# guarded-by:`` annotation: ``field`` of ``cls`` is owned
    by lock attribute ``lock``; ``shared`` marks class-level (cross-
    object-checked) declarations."""

    path: str
    cls: str
    field: str
    lock: str
    shared: bool


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def collect_guards(mod: SourceModule) -> list[FieldGuard]:
    """Every ``# guarded-by:`` annotation in the module (see module
    docstring for the instance-private vs shared split)."""
    guards: list[FieldGuard] = []
    for cls in ast.walk(mod.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for node in cls.body:                      # class-level = shared
            field = None
            if isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name):
                field = node.target.id
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                field = node.targets[0].id
            lock = mod.guarded_by(node.lineno) if field else None
            if field and lock:
                guards.append(FieldGuard(mod.path, cls.name, field, lock,
                                         shared=True))
        for fn in cls.body:                        # init-site = private
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.Assign, ast.AnnAssign)):
                    targets = node.targets \
                        if isinstance(node, ast.Assign) else [node.target]
                    lock = mod.guarded_by(node.lineno)
                    if not lock:
                        continue
                    for t in targets:
                        field = _self_attr(t)
                        if field:
                            guards.append(FieldGuard(
                                mod.path, cls.name, field, lock,
                                shared=False))
    return guards


def _write_targets(node: ast.AST) -> list[ast.AST]:
    if isinstance(node, ast.Assign):
        return list(node.targets)
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target] if getattr(node, "value", True) else []
    if isinstance(node, ast.Delete):
        return list(node.targets)
    return []


def _written_field(target: ast.AST) -> Optional[tuple[ast.AST, str]]:
    """(base expression, field name) for attribute writes and container
    stores through an attribute: ``b.f = ...``, ``b.f[k] = ...``,
    ``b.f[k][j] += ...``, ``del b.f[k]`` all write field ``f`` of
    ``b``."""
    while isinstance(target, (ast.Subscript, ast.Starred)):
        target = target.value
    if isinstance(target, ast.Attribute):
        return target.value, target.attr
    return None


def _base_repr(node: ast.AST) -> Optional[str]:
    return dotted_name(node)


def _held_locks(mod: SourceModule, node: ast.AST) -> set[str]:
    """Dotted lock expressions lexically held at ``node``: one entry
    per ``with`` item on the ancestor path (``self._cond`` ->
    "self._cond"), plus ``<base>.<lock>`` synthesized from any
    enclosing ``# requires-lock:`` def (the caller-holds contract).
    Ancestry stops adding ``with`` items across nested ``def``
    boundaries: a closure body runs later, outside the lock."""
    held: set[str] = set()
    crossed_def = False
    for anc in mod.ancestors(node):
        if isinstance(anc, ast.With) and not crossed_def:
            for item in anc.items:
                name = dotted_name(item.context_expr)
                if name:
                    held.add(name)
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not crossed_def:
                lock = mod.requires_lock(anc.lineno)
                if lock is not None:
                    held.add(f"self.{lock}")
            crossed_def = True
    return held


def check_locks(mods: Iterable[SourceModule],
                extra_guards: Iterable[FieldGuard] = ()) -> list[Finding]:
    """Run the lock-discipline rule over ``mods``.  Guards are collected
    from the same modules (plus ``extra_guards``) first, so shared
    fields are checked at write sites in *other* modules too."""
    mods = list(mods)
    guards = list(extra_guards)
    for mod in mods:
        guards.extend(collect_guards(mod))
    # self-writes: (cls, field) -> lock;  shared: field -> {locks}
    private: dict[tuple[str, str], str] = {}
    shared: dict[str, set[str]] = {}
    for g in guards:
        private[(g.cls, g.field)] = g.lock
        if g.shared:
            shared.setdefault(g.field, set()).add(g.lock)

    findings: list[Finding] = []
    for mod in mods:
        for node in ast.walk(mod.tree):
            for target in _write_targets(node):
                hit = _written_field(target)
                if hit is None:
                    continue
                base, field = hit
                fn = mod.enclosing_function(target)
                if fn is not None and fn.name in ("__init__", "__new__"):
                    continue               # construction publishes
                lock = None
                base_name = _base_repr(base)
                if base_name == "self":
                    cls = mod.enclosing_class(target)
                    if cls is not None:
                        lock = private.get((cls.name, field))
                if lock is None and field in shared and base_name and \
                        base_name != "self":
                    locks = shared[field]
                    held = _held_locks(mod, target)
                    if any(f"{base_name}.{lk}" in held for lk in locks):
                        continue
                    if mod.suppressed(RULE_LOCKS, node.lineno):
                        continue
                    findings.append(Finding(
                        RULE_LOCKS, mod.path, node.lineno,
                        f"write to shared guarded field "
                        f"'{base_name}.{field}' outside "
                        f"'with {base_name}.{'/'.join(sorted(locks))}:'"))
                    continue
                if lock is None:
                    continue
                held = _held_locks(mod, target)
                if f"self.{lock}" in held:
                    continue
                if mod.suppressed(RULE_LOCKS, node.lineno):
                    continue
                findings.append(Finding(
                    RULE_LOCKS, mod.path, node.lineno,
                    f"write to 'self.{field}' (guarded-by: {lock}) "
                    f"outside 'with self.{lock}:'"))
    return findings


def check_wallclock(mods: Iterable[SourceModule]) -> list[Finding]:
    """Flag ``time.time()`` calls without a ``# wallclock-ok:``
    annotation (see module docstring)."""
    findings: list[Finding] = []
    for mod in mods:
        aliases = import_aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if resolve_call_name(mod, node, aliases) != "time.time":
                continue
            if mod.wallclock_ok(node.lineno) or \
                    mod.suppressed(RULE_WALLCLOCK, node.lineno):
                continue
            findings.append(Finding(
                RULE_WALLCLOCK, mod.path, node.lineno,
                "time.time() is wall-clock: latency/deadline math needs "
                "time.monotonic() or time.perf_counter() (annotate "
                "'# wallclock-ok: <reason>' if wall time is the point)"))
    return findings
