"""GeoLint: repo-specific static analysis + runtime lock checking
(DESIGN.md §17).

Six rules over four checker modules, all operating on parsed
``SourceModule`` objects (AST + recovered comments):

  ================  ====================================================
  rule id           what it enforces
  ================  ====================================================
  lock-discipline   every write to a ``# guarded-by:`` field is inside
                    ``with`` of the owning lock (DESIGN.md §14 table)
  wallclock         ``time.time()`` only under ``# wallclock-ok:``
  compat-boundary   version-gated jax surface only in compat.py (§12)
  trace-purity      no host side effects reachable from jit/pallas
  unused-import     imports bind names that are actually used
  unreachable       no statements after return/raise/break/continue
  ================  ====================================================

``run_all(roots)`` is the single entry point ``scripts/check_static.py``
ratchets; ``lockcheck`` (imported explicitly, not via ``run_all``) is
the opt-in runtime detector behind ``REPRO_LOCKCHECK=1``.
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.analysis.boundary import check_boundary
from repro.analysis.common import (ALL_RULES, RULE_BOUNDARY, RULE_LOCKS,
                                   RULE_PURITY, RULE_UNREACHABLE,
                                   RULE_UNUSED_IMPORT, RULE_WALLCLOCK,
                                   Finding, SourceModule, load_modules)
from repro.analysis.deadcode import check_unreachable, check_unused_imports
from repro.analysis.locks import (FieldGuard, check_locks, check_wallclock,
                                  collect_guards)
from repro.analysis.purity import check_purity

__all__ = [
    "ALL_RULES", "RULE_BOUNDARY", "RULE_LOCKS", "RULE_PURITY",
    "RULE_UNREACHABLE", "RULE_UNUSED_IMPORT", "RULE_WALLCLOCK",
    "Finding", "SourceModule", "FieldGuard", "load_modules",
    "collect_guards", "check_locks", "check_wallclock", "check_boundary",
    "check_purity", "check_unused_imports", "check_unreachable",
    "run_all", "counts_by_rule",
]

# Rules whose scope is the library tree only: lock discipline and the
# call-graph walk key off annotations/roots that live in src/repro;
# import hygiene on tests/benches would fight pytest fixtures.
_SRC_ONLY_RULES = (RULE_LOCKS, RULE_PURITY, RULE_UNUSED_IMPORT,
                   RULE_UNREACHABLE)


def run_all(src_roots: Sequence[str],
            wide_roots: Sequence[str] = ()) -> list[Finding]:
    """Run every static rule.  ``src_roots`` (the library tree) gets all
    six rules; ``wide_roots`` (benchmarks / examples / scripts / tests)
    additionally gets the portable rules — wallclock and
    compat-boundary — whose contracts hold repo-wide."""
    src_mods = load_modules(src_roots)
    wide_mods = load_modules(wide_roots) if wide_roots else []
    every = src_mods + wide_mods

    findings: list[Finding] = []
    findings += check_locks(src_mods)
    findings += check_purity(src_mods)
    findings += check_unused_imports(src_mods)
    findings += check_unreachable(src_mods)
    findings += check_wallclock(every)
    findings += check_boundary(every)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def counts_by_rule(findings: Iterable[Finding]) -> dict[str, int]:
    """Per-rule totals in a stable key order — the ratchet's unit."""
    out = {rule: 0 for rule in ALL_RULES}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out
