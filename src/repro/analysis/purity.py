"""Trace-purity checker (DESIGN.md §17, rule id ``trace-purity``).

Code reachable from a ``@jax.jit`` / ``pl.pallas_call`` site runs at
*trace* time: host-side effects execute once, get baked into the
compiled program as constants, and silently disagree with every later
invocation.  A ``time.time()`` read, a Python/numpy RNG draw, or a
mutation of closure state inside a jitted function is therefore a
correctness bug that no unit test on a single call can see.

The checker builds a project-wide call graph:

  * **roots** — functions decorated with ``jax.jit`` (bare or through
    ``functools.partial(jax.jit, ...)``), functions wrapped at a
    ``jax.jit(f)`` call site, and kernel bodies passed to
    ``pl.pallas_call``;
  * **edges** — direct calls by name (same module, any nesting level)
    and cross-module calls through import aliases
    (``simple_mod.cascade_assign(...)`` resolves into
    ``repro/core/simple.py``).  Method calls on objects are out of
    static reach and not followed.

Inside every reachable function it flags:

  * ``time.*`` calls (trace-time clock reads);
  * Python RNG (``random.*``) and numpy RNG (``np.random.*``) calls;
  * ``np.*`` calls other than the dtype/static-shape allowlist below —
    numpy executes on host at trace time, so data-dependent numpy is a
    tracer leak (jnp is the device spelling);
  * ``global`` / ``nonlocal`` declarations (closure-state mutation
    inside a traced function re-runs only at trace time).

The numpy allowlist covers trace-time-constant usage: dtype
constructors and scalar types (``np.float32(...)``), and static shape
arithmetic on Python ints (``np.prod(shape)``-style) — those are pure
functions of static arguments, re-evaluated identically at every
retrace.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Optional

from repro.analysis.common import (RULE_PURITY, Finding, SourceModule,
                                   dotted_name, import_aliases)

__all__ = ["check_purity", "NUMPY_ALLOWED"]

# np.* calls that are pure functions of static (trace-time-constant)
# arguments — dtype constructors/casts and static shape arithmetic.
NUMPY_ALLOWED = frozenset({
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "dtype", "finfo",
    "iinfo", "prod", "ceil", "floor", "log2", "sqrt", "asarray",
    "array", "arange", "zeros", "ones", "full",
})

_JIT_NAMES = {"jax.jit", "jax.pmap"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


@dataclasses.dataclass
class _FuncInfo:
    key: tuple          # (module path, qualname)
    node: ast.AST       # FunctionDef / AsyncFunctionDef / Lambda
    module: "_ModInfo"
    is_root: bool = False


@dataclasses.dataclass
class _ModInfo:
    mod: SourceModule
    dotted: Optional[str]                 # e.g. "repro.core.fast"
    aliases: dict
    by_name: dict                         # simple name -> [_FuncInfo]


def _module_dotted(path: str) -> Optional[str]:
    norm = path.replace("\\", "/")
    marker = "/src/"
    ix = norm.rfind(marker)
    if ix < 0:
        if norm.startswith("src/"):
            tail = norm[len("src/"):]
        else:
            return None
    else:
        tail = norm[ix + len(marker):]
    if not tail.endswith(".py"):
        return None
    tail = tail[:-3]
    if tail.endswith("/__init__"):
        tail = tail[:-len("/__init__")]
    return tail.replace("/", ".")


def _resolve(aliases: dict, name: Optional[str]) -> Optional[str]:
    """Alias-resolve a dotted reference to its imported origin."""
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = aliases.get(head)
    if origin is None:
        return name
    return f"{origin}.{rest}" if rest else origin


def _is_jit_decorator(aliases: dict, dec: ast.AST) -> bool:
    name = _resolve(aliases, dotted_name(dec))
    if name in _JIT_NAMES:
        return True
    if isinstance(dec, ast.Call):
        fname = _resolve(aliases, dotted_name(dec.func))
        if fname in _JIT_NAMES:
            return True
        if fname in _PARTIAL_NAMES and dec.args:
            return _resolve(aliases, dotted_name(dec.args[0])) \
                in _JIT_NAMES
    return False


def _index_module(mod: SourceModule) -> _ModInfo:
    info = _ModInfo(mod, _module_dotted(mod.path),
                    import_aliases(mod.tree), {})
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fi = _FuncInfo((mod.path, node.name), node, info)
            info.by_name.setdefault(node.name, []).append(fi)
    return info


def _mark_roots(info: _ModInfo) -> None:
    mod, aliases = info.mod, info.aliases
    for fis in info.by_name.values():
        for fi in fis:
            for dec in getattr(fi.node, "decorator_list", ()):
                if _is_jit_decorator(aliases, dec):
                    fi.is_root = True
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _resolve(aliases, dotted_name(node.func))
        args: list[ast.AST] = []
        if fname in _JIT_NAMES:
            args = node.args[:1]
        elif fname is not None and (fname == "pallas_call" or
                                    fname.endswith(".pallas_call")):
            args = node.args[:1]
        for arg in args:
            if isinstance(arg, ast.Name):
                for fi in info.by_name.get(arg.id, ()):
                    fi.is_root = True


def _callees(fi: _FuncInfo, index: dict) -> list[_FuncInfo]:
    """Static call edges out of one function's own body (nested defs
    are separate graph nodes, reached through call edges)."""
    info = fi.module
    out: list[_FuncInfo] = []
    for node in _own_body_walk(fi.node):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name is None:
            continue
        if "." not in name:                       # same-module by name
            out.extend(info.by_name.get(name, ()))
            continue
        origin = _resolve(info.aliases, name)
        if origin is None or "." not in origin:
            continue
        mod_part, _, func_part = origin.rpartition(".")
        target = index.get(mod_part)
        if target is not None and "." not in func_part:
            out.extend(target.by_name.get(func_part, ()))
    return out


def _own_body_walk(fn: ast.AST):
    """Walk a function's body without descending into nested function
    definitions (their bodies are separate call-graph nodes); the
    nested ``def`` node itself is yielded so calls in its decorators
    and defaults still count."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def check_purity(mods: Iterable[SourceModule]) -> list[Finding]:
    infos = [_index_module(m) for m in mods]
    index = {i.dotted: i for i in infos if i.dotted}
    for info in infos:
        _mark_roots(info)

    # BFS over the call graph from the jit/pallas roots.
    reachable: dict[int, _FuncInfo] = {}
    frontier = [fi for info in infos
                for fis in info.by_name.values()
                for fi in fis if fi.is_root]
    while frontier:
        fi = frontier.pop()
        if id(fi.node) in reachable:
            continue
        reachable[id(fi.node)] = fi
        frontier.extend(_callees(fi, index))

    findings: list[Finding] = []
    for fi in reachable.values():
        mod, aliases = fi.module.mod, fi.module.aliases
        for node in _own_body_walk(fi.node):
            what = None
            if isinstance(node, ast.Call):
                origin = _resolve(aliases, dotted_name(node.func))
                if origin is None:
                    continue
                if origin == "time.time" or origin.startswith("time."):
                    what = f"trace-time clock read: {origin}()"
                elif origin == "random" or origin.startswith("random."):
                    what = f"Python RNG under trace: {origin}()"
                elif origin.startswith("numpy.random"):
                    what = f"numpy RNG under trace: {origin}()"
                elif origin.startswith("numpy."):
                    leaf = origin.split(".", 1)[1]
                    if leaf not in NUMPY_ALLOWED:
                        what = (f"host numpy call under trace: "
                                f"{origin}() (use jnp, or move it out "
                                f"of the traced function)")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                kind = "global" if isinstance(node, ast.Global) \
                    else "nonlocal"
                what = (f"'{kind} {', '.join(node.names)}' — closure/"
                        f"module state mutation inside a traced "
                        f"function runs once, at trace time")
            if what is None:
                continue
            if mod.suppressed(RULE_PURITY, node.lineno):
                continue
            findings.append(Finding(
                RULE_PURITY, mod.path, node.lineno,
                f"{what} [reachable from jit/pallas root "
                f"'{fi.node.name}']"))
    return findings
