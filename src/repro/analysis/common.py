"""Shared infrastructure for the GeoLint static-analysis suite
(DESIGN.md §17).

Every checker in this package works on a ``SourceModule``: one parsed
file bundling the AST, the raw source lines, and — crucially, since the
``ast`` module discards them — the **per-line comments** recovered with
``tokenize``.  Comments carry the whole annotation grammar:

  * ``# guarded-by: <lock>``     — on a field-initialising assignment:
    every later write to that field must run under ``with self.<lock>``
    (locks.py; the runtime detector enforces the same table live);
  * ``# requires-lock: <lock>``  — on a ``def``: the method is only
    called with ``<lock>`` already held, so its body counts as inside
    the lock for the lexical checker (and the runtime detector verifies
    the claim on every instrumented run);
  * ``# wallclock-ok: <reason>`` — on a ``time.time()`` call site:
    wall-clock time is intended here (event-time stamping), not a
    latency/deadline measurement bug;
  * ``# geolint: ignore[<rule>] -- <reason>`` — suppress one rule on
    one line.  The reason is mandatory: a bare ignore does not
    suppress (undocumented exemptions are exactly the rot this suite
    exists to stop).

Checkers yield ``Finding`` rows; ``scripts/check_static.py`` ratchets
their per-rule counts against ``scripts/static_baseline.json``.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Iterable, Iterator, Optional

# Rule ids, one per checker pass (the baseline keys).
RULE_LOCKS = "lock-discipline"
RULE_WALLCLOCK = "wallclock"
RULE_BOUNDARY = "compat-boundary"
RULE_PURITY = "trace-purity"
RULE_UNUSED_IMPORT = "unused-import"
RULE_UNREACHABLE = "unreachable"

ALL_RULES = (RULE_LOCKS, RULE_WALLCLOCK, RULE_BOUNDARY, RULE_PURITY,
             RULE_UNUSED_IMPORT, RULE_UNREACHABLE)

_IGNORE_RE = re.compile(
    r"geolint:\s*ignore\[(?P<rules>[a-z0-9_,\- ]+)\]\s*--\s*\S")
_GUARDED_RE = re.compile(r"guarded-by:\s*(?P<lock>[A-Za-z_][A-Za-z0-9_]*)")
_REQUIRES_RE = re.compile(r"requires-lock:\s*(?P<lock>[A-Za-z_][A-Za-z0-9_]*)")
_WALLCLOCK_OK_RE = re.compile(r"wallclock-ok:\s*\S")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str                   # repo-relative where possible
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class SourceModule:
    """One parsed source file: AST + lines + per-line comments."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # line number -> comment text ("#" stripped, whitespace trimmed).
        # tokenize is the only faithful way to recover end-of-line
        # comments; ast drops them.
        self.comments: dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = \
                        tok.string.lstrip("#").strip()
        except tokenize.TokenError:      # pragma: no cover - parse said ok
            pass
        # Attach parent pointers once: several checkers need lexical
        # ancestry (with-block containment, enclosing function/class).
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._geolint_parent = node  # type: ignore[attr-defined]

    @classmethod
    def load(cls, path: str) -> "SourceModule":
        with open(path, encoding="utf-8") as f:
            return cls(path, f.read())

    # -- annotation grammar ------------------------------------------------

    def comment_at(self, line: int) -> str:
        return self.comments.get(line, "")

    def suppressed(self, rule: str, line: int) -> bool:
        """True when ``line`` (or the line above, for statements whose
        annotation would not fit inline) carries
        ``# geolint: ignore[rule] -- reason``."""
        for ln in (line, line - 1):
            m = _IGNORE_RE.search(self.comment_at(ln))
            if m and rule in {r.strip()
                              for r in m.group("rules").split(",")}:
                return True
        return False

    def guarded_by(self, line: int) -> Optional[str]:
        m = _GUARDED_RE.search(self.comment_at(line))
        return m.group("lock") if m else None

    def requires_lock(self, line: int) -> Optional[str]:
        for ln in (line, line - 1):
            m = _REQUIRES_RE.search(self.comment_at(ln))
            if m:
                return m.group("lock")
        return None

    def wallclock_ok(self, line: int) -> bool:
        for ln in (line, line - 1):
            if _WALLCLOCK_OK_RE.search(self.comment_at(ln)):
                return True
        return False

    # -- lexical helpers ---------------------------------------------------

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return getattr(node, "_geolint_parent", None)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def enclosing_class(self, node: ast.AST):
        """Nearest ClassDef ancestor — method bodies and closures nested
        inside them both count (a closure's ``self`` is the method's)."""
        for anc in self.ancestors(node):
            if isinstance(anc, ast.ClassDef):
                return anc
        return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None (calls, subscripts
    and anything dynamic break the chain — those are not static
    references to a module symbol)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def import_aliases(tree: ast.Module) -> dict[str, str]:
    """Local name -> imported dotted origin, for every top-level (and
    function-local) import in the module.  ``import numpy as np`` maps
    ``np -> numpy``; ``from time import monotonic`` maps
    ``monotonic -> time.monotonic``."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def resolve_call_name(mod: SourceModule, call: ast.Call,
                      aliases: Optional[dict] = None) -> Optional[str]:
    """The *origin* dotted name of a call target: local aliases are
    rewritten to their imported origin, so ``from time import time;
    time()`` and ``import time; time.time()`` both resolve to
    ``time.time``."""
    name = dotted_name(call.func)
    if name is None:
        return None
    if aliases is None:
        aliases = import_aliases(mod.tree)
    head, _, rest = name.partition(".")
    origin = aliases.get(head)
    if origin is None:
        return name
    return f"{origin}.{rest}" if rest else origin


def iter_py_files(roots: Iterable[str]) -> Iterator[str]:
    """Every .py file under ``roots`` (files accepted verbatim), sorted
    for deterministic finding order."""
    out = []
    for root in roots:
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(filenames) if f.endswith(".py"))
    return iter(sorted(set(out)))


def load_modules(roots: Iterable[str]) -> list[SourceModule]:
    mods = []
    for path in iter_py_files(roots):
        try:
            mods.append(SourceModule.load(path))
        except SyntaxError as e:
            # A file the analyzers cannot parse is itself a finding-level
            # event, but the tier-1 suite already fails on it; re-raise
            # so check_static never silently skips a broken file.
            raise SyntaxError(f"{path}: {e}") from e
    return mods
