"""Runtime lock-order race detector (DESIGN.md §17, opt-in via
``REPRO_LOCKCHECK=1``).

The static checker (locks.py) proves *lexical* discipline; this module
checks the two properties lexical analysis cannot: the **acquisition
order graph** (a cycle across threads is a potential deadlock even if
every individual site looks fine) and the **caller-holds contracts**
(``# requires-lock:`` claims, and writes reached through aliases or
container methods the AST rule cannot see).

``install()`` patches the serving/analytics classes of the DESIGN.md
§14 lock table:

  * every lock attribute is wrapped in an instrumented proxy the moment
    it is assigned (``__setattr__`` interception), so all later
    ``with``/``acquire``/``wait`` traffic is recorded — per-thread held
    stacks plus a global edge set ``held -> acquired`` keyed by
    ``Class.attr``;
  * writes to ``# guarded-by:`` fields (the table is *derived from the
    annotations* via ``locks.collect_guards`` — one source of truth)
    are checked against the held stack: a rebind without the owning
    lock held is recorded as a violation.  ``__init__`` frames are
    exempt (construction publishes; the refcount handles subclass
    chains like ``_FutureTicket -> _Ticket``).

The conftest hook asserts, after every test, that no violations
accumulated and the edge graph is still acyclic.  Deliberately NOT a
general happens-before race detector: it enforces this repo's single-
guard table, nothing more.
"""
from __future__ import annotations

import importlib
import threading
from typing import Optional

from repro.analysis.common import SourceModule
from repro.analysis.locks import collect_guards

__all__ = ["install", "uninstall", "registry", "wrap_lock",
           "LockCheckRegistry"]


class LockCheckRegistry:
    """Per-thread held stacks + global acquisition-order edges +
    recorded violations.  All mutation is GIL-atomic dict/list/set ops
    on primitive keys — no lock of its own (it must never perturb the
    ordering it observes)."""

    def __init__(self) -> None:
        self._tls = threading.local()
        # lock name -> set of lock names acquired while it was held.
        self.edges: dict[str, set[str]] = {}
        self.violations: list[str] = []

    # -- held stack --------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def note_acquire(self, lock: "_InstrumentedLock") -> None:
        st = self._stack()
        for held in st:
            if held is lock or held.name == lock.name:
                continue           # RLock / same-named reentrance
            self.edges.setdefault(held.name, set()).add(lock.name)
        st.append(lock)

    def note_release(self, lock: "_InstrumentedLock") -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] is lock:
                del st[i]
                return

    def holds(self, lock: "_InstrumentedLock") -> bool:
        return any(h is lock for h in self._stack())

    # -- reporting ---------------------------------------------------------

    def violation(self, msg: str) -> None:
        self.violations.append(msg)

    def find_cycle(self) -> Optional[list[str]]:
        """A cycle in the acquisition-order graph, as the lock-name
        path, or None.  Any cycle means two code paths take the same
        locks in opposite orders — a deadlock waiting for the right
        interleaving."""
        WHITE, GREY, BLACK = 0, 1, 2
        color = {n: WHITE for n in self.edges}
        path: list[str] = []

        def dfs(n: str) -> Optional[list[str]]:
            color[n] = GREY
            path.append(n)
            for m in sorted(self.edges.get(n, ())):
                c = color.get(m, WHITE)
                if c == GREY:
                    return path[path.index(m):] + [m]
                if c == WHITE:
                    hit = dfs(m)
                    if hit:
                        return hit
            path.pop()
            color[n] = BLACK
            return None

        for n in sorted(self.edges):
            if color[n] == WHITE:
                hit = dfs(n)
                if hit:
                    return hit
        return None

    def reset(self) -> None:
        self.edges.clear()
        self.violations.clear()


registry = LockCheckRegistry()


class _InstrumentedLock:
    """Proxy over Lock/RLock recording acquire/release order."""

    _DELEGATE = ("locked",)

    def __init__(self, inner, name: str):
        self._inner = inner
        self.name = name

    def acquire(self, *a, **k):
        got = self._inner.acquire(*a, **k)
        if got:
            registry.note_acquire(self)
        return got

    def release(self):
        registry.note_release(self)
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):                                # pragma: no cover
        return f"<lockcheck {self.name} over {self._inner!r}>"


class _InstrumentedCondition(_InstrumentedLock):
    """Condition proxy: ``wait`` releases and reacquires the underlying
    lock, and the held stack must mirror that or every waiter would
    look like it holds the lock across the sleep."""

    def wait(self, timeout=None):
        registry.note_release(self)
        try:
            return self._inner.wait(timeout)
        finally:
            registry.note_acquire(self)

    def wait_for(self, predicate, timeout=None):
        registry.note_release(self)
        try:
            return self._inner.wait_for(predicate, timeout)
        finally:
            registry.note_acquire(self)

    def notify(self, n: int = 1):
        self._inner.notify(n)

    def notify_all(self):
        self._inner.notify_all()


def wrap_lock(inner, name: str):
    """Public wrapper used by tests and by the ``__setattr__`` hook."""
    if isinstance(inner, _InstrumentedLock):
        return inner
    if isinstance(inner, threading.Condition):
        return _InstrumentedCondition(inner, name)
    return _InstrumentedLock(inner, name)


# -- class instrumentation --------------------------------------------------

# (module, class, lock attributes).  Guarded fields come from the
# # guarded-by: annotations in the sources — collect_guards below.
_TARGETS = (
    ("repro.serving.batcher", "MicroBatcher", ("_cond",)),
    ("repro.serving.cache", "HotCellCache", ("_lock",)),
    ("repro.serving.metrics", "ServerMetrics", ("_lock",)),
    ("repro.serving.metrics", "LatencyWindow", ("_lock",)),
    ("repro.serving.server", "_Ticket", ("_lock",)),
    ("repro.serving.server", "_Region", ("lock",)),
    ("repro.serving.frontend", "_FutureTicket", ()),
    ("repro.serving.frontend", "AsyncGeoServer", ("_dispatch_lock",)),
    ("repro.analytics.window", "WindowedAggregator", ("_lock",)),
    ("repro.obs.trace", "SpanBuffer", ("_lock",)),
)

# id(instance) -> __init__ nesting depth (construction exemption for
# guarded-field writes; refcounted so subclass __init__ chains stay
# exempt end to end).
_constructing: dict[int, int] = {}
_installed: list[tuple] = []       # (cls, attr, original or _MISSING)
_MISSING = object()


def _module_guards(module) -> dict[str, dict[str, str]]:
    """class name -> {field -> owning lock attr} from the module's own
    ``# guarded-by:`` annotations."""
    path = getattr(module, "__file__", None)
    if not path:                                       # pragma: no cover
        return {}
    guards: dict[str, dict[str, str]] = {}
    for g in collect_guards(SourceModule.load(path)):
        guards.setdefault(g.cls, {})[g.field] = g.lock
    return guards


def _patch(cls, lock_attrs: tuple, guarded: dict) -> None:
    lock_set = frozenset(lock_attrs)
    orig_setattr = cls.__setattr__
    orig_init = cls.__dict__.get("__init__")

    def __setattr__(self, name, value):
        if name in lock_set:
            value = wrap_lock(value, f"{cls.__name__}.{name}")
        elif name in guarded and id(self) not in _constructing:
            lock = getattr(self, guarded[name], None)
            if isinstance(lock, _InstrumentedLock) and \
                    not registry.holds(lock):
                registry.violation(
                    f"write to {cls.__name__}.{name} on thread "
                    f"{threading.current_thread().name} without "
                    f"{lock.name} held")
        orig_setattr(self, name, value)

    _record(cls, "__setattr__", cls.__dict__.get("__setattr__", _MISSING))
    cls.__setattr__ = __setattr__

    if orig_init is not None:
        def __init__(self, *a, **k):
            key = id(self)
            _constructing[key] = _constructing.get(key, 0) + 1
            try:
                orig_init(self, *a, **k)
            finally:
                left = _constructing[key] - 1
                if left:
                    _constructing[key] = left
                else:
                    del _constructing[key]

        _record(cls, "__init__", orig_init)
        cls.__init__ = __init__


def _record(cls, attr, original) -> None:
    _installed.append((cls, attr, original))


def install() -> None:
    """Idempotent: patch every §14 class for instrumentation."""
    if _installed:
        return
    for mod_name, cls_name, lock_attrs in _TARGETS:
        module = importlib.import_module(mod_name)
        cls = getattr(module, cls_name)
        guards = _module_guards(module).get(cls_name, {})
        _patch(cls, lock_attrs, guards)


def uninstall() -> None:
    """Restore the patched classes (test isolation only — the conftest
    hook installs once per instrumented session and never unwinds)."""
    while _installed:
        cls, attr, original = _installed.pop()
        if original is _MISSING:
            delattr(cls, attr)
        else:
            setattr(cls, attr, original)
    _constructing.clear()
    registry.reset()
