"""Async, atomically-committed, mesh-agnostic checkpointing.

Layout:  <dir>/step_<N>/arrays.npz + meta.json, committed via tmp-dir
rename (a partially-written checkpoint is never visible).  Arrays are saved
as *global* host arrays keyed by pytree path, so a restore can re-place
them onto ANY mesh/sharding — this is what makes elastic re-scaling a
restore-with-new-shardings, not a format migration.

Async mode snapshots to host in the caller, then writes on a background
thread; ``wait()`` drains.  ``keep`` bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra_meta: Optional[dict] = None):
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = {"step": int(step), "keys": sorted(host.keys())}
        meta.update(extra_meta or {})
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; if ``shardings``
        (a matching pytree of NamedSharding) is given, arrays are placed
        directly onto the (possibly different) mesh — elastic re-scaling.

        Without explicit ``shardings``, each leaf is re-placed with the
        sharding of the corresponding ``like_tree`` leaf when it is a
        committed jax.Array: a mid-run restart under a mesh must put
        params back on their FSDP/TP layout, not concentrate them on the
        default device.  Plain host arrays restore to the default device
        as before.
        """
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            host = {k: z[k] for k in z.files}
        flat_like = _flatten(like_tree)
        assert set(flat_like) == set(host), (
            sorted(set(flat_like) ^ set(host))[:5])
        leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path_)
                for path_, _ in
                jax.tree_util.tree_flatten_with_path(like_tree)[0]]
        arrays = [host[k] for k in keys]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.device_put(np.asarray(a), self._leaf_sharding(l))
                      for a, l in zip(arrays, leaves_like)]
        return jax.tree_util.tree_unflatten(treedef, arrays)

    @staticmethod
    def _leaf_sharding(like_leaf):
        """The placement to restore onto: the like-leaf's own sharding for
        committed device arrays, default placement (None) otherwise."""
        sh = getattr(like_leaf, "sharding", None)
        if sh is not None and getattr(like_leaf, "is_deleted", lambda: False)():
            return None
        return sh

    def meta(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "meta.json")
        with open(path) as f:
            return json.load(f)
