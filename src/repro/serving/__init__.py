"""Streaming geo-assignment serving subsystem (DESIGN.md §10, §14).

Public surface:

    from repro.serving import GeoServer, ServeConfig          # sync
    from repro.serving import AsyncGeoServer, FrontendConfig  # concurrent

plus the composable pieces for custom serving loops: ``MicroBatcher`` /
``QueueFull`` (thread-safe micro-batching + backpressure),
``HotCellCache`` / ``CellTable`` (exact hot-cell shortcut),
``ServerMetrics`` (live counters / per-stage latency histograms /
Prometheus-style exposition).  Observability (DESIGN.md §15) plugs in
via ``repro.obs``: ``GeoServer(..., tracer=Tracer())`` records
per-request span timelines, ``GeoServer.metrics_text()`` exposes the
registry, and ``ServeConfig(trace_device=True)`` +
``start_profile``/``stop_profile`` capture named device traces.
Windowed streaming analytics (DESIGN.md §16) mounts behind the same
facade: ``ServeConfig(analytics=AnalyticsConfig(...))`` +
``GeoServer.snapshot_analytics()``.
"""
from repro.analytics import AnalyticsConfig
from repro.serving.batcher import (DEFAULT_BUCKETS, MicroBatch,
                                   MicroBatcher, QueueFull, bucket_for,
                                   pad_points)
from repro.serving.cache import (CellTable, HotCellCache, np_extent_mask,
                                 np_quantize_codes)
from repro.serving.frontend import AsyncGeoServer, FrontendConfig
from repro.serving.metrics import LatencyWindow, ServerMetrics
from repro.serving.server import GeoServer, ServeConfig, ServeResult

__all__ = [
    "AnalyticsConfig",
    "DEFAULT_BUCKETS", "MicroBatch", "MicroBatcher", "QueueFull",
    "bucket_for", "pad_points", "CellTable", "HotCellCache",
    "np_extent_mask", "np_quantize_codes", "LatencyWindow",
    "ServerMetrics", "GeoServer", "ServeConfig", "ServeResult",
    "AsyncGeoServer", "FrontendConfig",
]
