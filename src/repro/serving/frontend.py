"""AsyncGeoServer: the concurrent GeoServer front-end (DESIGN.md §14).

``GeoServer`` is a synchronous facade — one thread, one request round
trip at a time.  The paper's serving claim (100M+ projections/sec for
pandemic-response queries) and its deployed analogues (mContain's
encounter-density service) are *concurrent* services: many clients in
flight, batches coalesced across them, multiple engine replicas draining
one queue.  This module is that layer, built on the same machinery:

    server = AsyncGeoServer.build(census, strategy="fast",
                                  frontend=FrontendConfig(n_replicas=4))
    fut = server.submit_async(points)     # concurrent-safe, returns now
    res = fut.result()                    # ServeResult, same contract
    server.close()                        # or: with AsyncGeoServer...

Three thread groups, each owning one stage of the serve path:

  * **submitters** (``FrontendConfig.n_submitters`` pool): turn
    ``submit_async`` into a queued ticket without blocking the caller.
    Backpressure lives here — under the "block" policy a submitter
    sleeps on the batcher's condition until a drain frees room; under
    "shed" the ticket's future fails with ``QueueFull`` immediately.
  * **one flusher**: the deadline/size loop.  Sleeps on
    ``MicroBatcher.wait_for_work``, drains when the queue reaches
    ``flush_points`` or the oldest request ages past the deadline
    (``ServeConfig.max_delay_ms``, falling back to
    ``FrontendConfig.max_delay_ms`` so trickle traffic is never
    stranded), then runs the HOST stage (``GeoServer._prepare_batch``:
    routing + cache lookup/learn) on each micro-batch *in arrival
    order* before dispatching it round-robin to a replica queue.
  * **replicas** (``n_replicas`` workers): each drains its dispatch
    queue through the DEVICE stage (``GeoServer._complete_batch``:
    padded engine assigns + ticket fills).  Replicas share the server's
    immutable region indices — on one host that IS replication (the
    same compiled executables run concurrently); a multi-device
    deployment would pin each worker's engines to its own device at
    this seam.

Why output is bit-identical to the synchronous server (and to direct
``engine.assign``): the host stage is serialized in the flusher, so the
cache's hit/miss/learn sequence — the only stateful, order-sensitive
part of serving — is deterministic in enqueue order; the device stage
computes a pure function of each batch; and tickets preallocate their
result arrays so parts merge in ticket order (disjoint row ranges)
whatever the replica completion order.  GeoStats merges are sums, hence
order-free.  See DESIGN.md §14 for the lock boundaries.

Failure recovery extends the sync server's requeue contract: a replica
whose batch dies requeues the drained-but-unserved slices at the queue
front (FIFO preserved, atomic under the batcher lock) and the work
retries on a later flush — but each ticket carries a retry budget
(``max_retries``), after which its future fails with the engine's
exception instead of crash-looping.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.core.engine import EngineConfig, GeoEngine
from repro.core.geometry import CensusMap
from repro.serving.batcher import QueueFull
from repro.serving.server import (GeoServer, ServeConfig, ServeResult,
                                  _Ticket)

__all__ = ["AsyncGeoServer", "FrontendConfig"]


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Static front-end knobs (threading shape + flush policy)."""

    n_submitters: int = 4        # client-facing enqueue pool
    n_replicas: int = 1          # engine workers draining the batcher
    flush_points: Optional[int] = None   # size trigger; None = top bucket
    max_delay_ms: float = 2.0    # deadline when ServeConfig has none
    idle_tick_s: float = 0.01    # flusher wakeup cadence when idle
    max_retries: int = 2         # per-ticket failed-flush budget
    put_timeout_s: float = 0.05  # blocked-put poll (shutdown liveness)


class _FutureTicket(_Ticket):
    """A ticket whose completion resolves a ``concurrent.futures.Future``
    — the async front-end's per-request handle.  ``retries`` counts the
    failed flushes this ticket has survived (see ``_recover_batch``)."""

    __slots__ = ("future", "retries")

    def __init__(self, n: int, t0: float, trace=None):
        super().__init__(n, t0, trace=trace)
        self.future: Future = Future()
        self.retries = 0
        if n == 0:                       # trivially complete, like sync
            self.future.set_result(self.result())

    def _completed(self) -> None:
        # A late part of an already-failed (retry-exhausted) ticket may
        # still serve; the future keeps its exception.
        if not self.future.done():
            self.future.set_result(self.result())

    def fail(self, exc: BaseException) -> None:
        if not self.future.done():
            self.future.set_exception(exc)
        if self.trace is not None:       # shed/failed requests still close
            self.trace.end(error=type(exc).__name__)  # — never orphans


class AsyncGeoServer(GeoServer):
    """Concurrent front-end over the GeoServer machinery (see module
    docstring).  Accepts the same engines/config as ``GeoServer`` plus a
    ``FrontendConfig``; serving starts immediately on construction and
    stops at ``close()`` (or context-manager exit)."""

    def __init__(self, engines, cfg: Optional[ServeConfig] = None, *,
                 covering=None, frontend: Optional[FrontendConfig] = None,
                 tracer=None):
        super().__init__(engines, cfg, covering=covering, tracer=tracer)
        f = frontend or FrontendConfig()
        if f.n_submitters < 1 or f.n_replicas < 1:
            raise ValueError(f"n_submitters and n_replicas must be >= 1, "
                             f"got {f.n_submitters}/{f.n_replicas}")
        self.fcfg = f
        self._flush_points = (int(f.flush_points) if f.flush_points
                              else self.cfg.buckets[-1])
        self._deadline_ms = (self.cfg.max_delay_ms
                             if self.cfg.max_delay_ms is not None
                             else f.max_delay_ms)
        self._stop = threading.Event()        # no new submits / puts
        self._flush_stop = threading.Event()  # flusher exit (after drain)
        self._outstanding = 0                 # accepted, unresolved tickets
        self._idle = threading.Condition()
        self._dispatch_lock = threading.Lock()
        self._seq = 0                         # round-robin batch counter
        self._submitters = ThreadPoolExecutor(
            f.n_submitters, thread_name_prefix="geo-submit")
        self._replica_queues: list[queue.Queue] = \
            [queue.Queue() for _ in range(f.n_replicas)]
        self._replicas = [
            threading.Thread(target=self._replica_loop, args=(ix,),
                             name=f"geo-replica-{ix}", daemon=True)
            for ix in range(f.n_replicas)]
        for t in self._replicas:
            t.start()
        self._flusher = threading.Thread(target=self._flush_loop,
                                         name="geo-flush", daemon=True)
        self._flusher.start()

    @classmethod
    def build(cls, census: CensusMap, strategy: str = "fast",
              cfg: Optional[ServeConfig] = None,
              engine_cfg: Optional[EngineConfig] = None,
              frontend: Optional[FrontendConfig] = None,
              tracer=None) -> "AsyncGeoServer":
        """Single-region convenience, mirroring ``GeoServer.build``."""
        engine = GeoEngine.build(census, strategy,
                                 engine_cfg or EngineConfig())
        return cls(engine, cfg, frontend=frontend, tracer=tracer)

    # -- client surface ----------------------------------------------------

    def submit_async(self, points) -> Future:
        """Queue one request; returns a Future resolving to its
        ``ServeResult``.  Never blocks the caller: backpressure either
        waits inside a submitter thread ("block") or fails the future
        with ``QueueFull`` ("shed").  Raises RuntimeError after
        ``close()``."""
        if self._stop.is_set():
            raise RuntimeError("AsyncGeoServer is closed")
        points = np.asarray(points, np.float32).reshape(-1, 2)
        t0 = time.perf_counter()
        ticket = _FutureTicket(len(points), t0,
                               trace=self._start_trace(t0))
        self.metrics.inc("requests")
        self.metrics.inc("points_in", len(points))
        with self._idle:
            self._outstanding += 1
        ticket.future.add_done_callback(self._request_resolved)
        if len(points):
            self._submitters.submit(self._enqueue_async, ticket, points)
        return ticket.future

    def submit(self, points, timeout: Optional[float] = None
               ) -> ServeResult:
        """Synchronous round trip through the concurrent pipeline."""
        return self.submit_async(points).result(timeout)

    def enqueue(self, points):
        raise NotImplementedError(
            "AsyncGeoServer is future-based: use submit_async()/submit() "
            "(the sync GeoServer keeps enqueue/flush/poll)")

    # -- lifecycle ---------------------------------------------------------

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every accepted request has resolved (served, shed,
        or failed); False if ``timeout`` elapsed first.  Nudges the
        flusher so sub-deadline stragglers go out immediately."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        with self._idle:
            while self._outstanding:
                if len(self.batcher):
                    self._dispatch_flush()
                remaining = 0.05 if deadline is None \
                    else min(0.05, deadline - time.perf_counter())
                if remaining <= 0:
                    return False
                self._idle.wait(remaining)
        return True

    def close(self, timeout: float = 30.0) -> None:
        """Stop accepting work, serve everything queued, stop the
        threads.  Idempotent.  Requests still waiting for queue room
        when close() lands fail with QueueFull."""
        if self._stop.is_set():
            return
        self._stop.set()                  # reject new submits; unblock puts
        self._submitters.shutdown(wait=True)
        self._flush_stop.set()            # flusher: final drain, then exit
        self._flusher.join(timeout)
        for q in self._replica_queues:    # sentinel after all dispatches
            q.put(None)
        for t in self._replicas:
            t.join(timeout)

    def __enter__(self) -> "AsyncGeoServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- pipeline threads --------------------------------------------------

    def _request_resolved(self, fut: Future) -> None:
        with self._idle:
            self._outstanding -= 1
            self._idle.notify_all()

    def _enqueue_async(self, ticket: _FutureTicket,
                       points: np.ndarray) -> None:
        """Submitter-pool body: blocking put with shutdown liveness."""
        try:
            # The submit span's end is stamped BEFORE the put: once the
            # put lands, the flusher may serve and close the trace ahead
            # of this thread resuming, and a post-put timestamp could
            # fall outside the root interval (child-nests-in-parent is
            # the exported invariant).  The blocked-put wait itself is
            # queue_wait's job, not submit's.
            t_put = time.perf_counter()
            while not self.batcher.put(ticket, points, wait=True,
                                       timeout=self.fcfg.put_timeout_s):
                if self._stop.is_set():
                    raise QueueFull("AsyncGeoServer closed while waiting "
                                    "for queue room")
                t_put = time.perf_counter()
        except QueueFull as e:
            self.metrics.inc("shed_requests")
            self.metrics.inc("shed_points", len(points))
            ticket.fail(e)
        except BaseException as e:        # never lose a future
            ticket.fail(e)
        else:
            if ticket.trace is not None:  # submit = client call -> queued
                ticket.trace.span("submit", ticket._t0, t_put,
                                  n_points=len(points))
            self._update_queue_gauges()

    def _flush_loop(self) -> None:
        while not self._flush_stop.is_set():
            if not self.batcher.wait_for_work(
                    timeout=self.fcfg.idle_tick_s):
                continue
            age_ms = self.batcher.oldest_age_s() * 1e3
            if self.batcher.queued_points >= self._flush_points:
                self._dispatch_flush()
            elif age_ms >= self._deadline_ms:
                self.metrics.inc("deadline_flushes")
                self._dispatch_flush()
            else:                         # coalesce until a trigger fires
                wait_s = min((self._deadline_ms - age_ms) / 1e3,
                             self.fcfg.idle_tick_s)
                time.sleep(max(wait_s, 1e-4))
        self._dispatch_flush()            # close(): serve the leftovers

    def _dispatch_flush(self) -> int:
        """Drain + host stage (in order) + round-robin dispatch; returns
        micro-batches dispatched.  Serialized so two callers (flusher +
        drain()/flush()) cannot interleave the host stage — arrival-order
        cache determinism is the bit-identity contract."""
        with self._dispatch_lock:
            batches = self.batcher.drain()
            for mb in batches:
                work = self._prepare_batch(mb)
                q = self._replica_queues[
                    self._seq % len(self._replica_queues)]
                self._seq += 1
                q.put(work)
        if batches:
            self._update_queue_gauges()
        return len(batches)

    def flush(self) -> int:
        """Force-dispatch everything queued (does not wait for the
        replicas to finish — ``drain()`` does)."""
        return self._dispatch_flush()

    def poll(self) -> int:
        """Deadline tick, for symmetry with the sync server (the flusher
        thread already does this continuously)."""
        if not len(self.batcher) \
                or self.batcher.oldest_age_s() * 1e3 < self._deadline_ms:
            return 0
        self.metrics.inc("deadline_flushes")
        return self._dispatch_flush()

    def _replica_loop(self, ix: int) -> None:
        q = self._replica_queues[ix]
        while True:
            work = q.get()
            if work is None:
                return
            try:
                self._complete_batch(work)
            except Exception as exc:      # device/engine failure
                self._recover_batch(work, exc)
            finally:
                if any(r.cache is not None for r in self.regions):
                    self.metrics.observe_cache(self.cache_snapshot())

    def _recover_batch(self, work, exc: Exception) -> None:
        """The async spelling of the sync server's requeue-on-failure:
        every slice of the failed batch goes back to the queue FRONT in
        order — unless its ticket has exhausted ``max_retries``, in
        which case that request's future fails with the engine's
        exception (a poisoned batch must not crash-loop the replica)."""
        self.metrics.inc("failed_flushes")
        entries, dead, bumped = [], [], set()
        for (t, ro, bo, ln) in work.mb.parts:
            if id(t) not in bumped:
                bumped.add(id(t))
                t.retries += 1
                t.attempt = t.retries     # later spans carry the attempt
                if t.retries > self.fcfg.max_retries:
                    dead.append(t)
                elif t.trace is not None:
                    t.trace.event("retry", attempt=t.attempt)
            if t.retries <= self.fcfg.max_retries:
                entries.append((t, work.mb.points[bo:bo + ln], ro))
        for t in dead:
            self.metrics.inc("failed_requests")
            t.fail(exc)                   # fail() also closes the trace
        if entries:
            self.batcher.requeue(entries)
