"""Counters / histograms registry for GeoServer (DESIGN.md §10).

One registry per server accumulates everything the ROADMAP's serving item
asks to surface: the per-request ``GeoStats``/``ResolveStats`` counters
(``phase2_miss`` front and centre — a non-zero value says the two-phase
PIP's cap2 is undersized for live traffic — plus overflow and boundary
fraction), cache hit/miss traffic, queue depth, batch-fill ratio (valid
rows / padded slots — how much of the bucket ladder's padding is waste),
deadline-triggered flushes (``deadline_flushes`` — how often the
``max_delay_ms`` SLO clock, not the size trigger, forced a batch out),
request latency percentiles over a sliding sample window, and per-region
index memory footprints (edge-pool bytes / block sizes — gauges set at
server construction from ``GeoIndexSet.memory_footprint``).

``snapshot()`` renders the whole registry as one JSON-ready dict:

    {"counters": {...},                 # monotonic sums
     "gauges": {...},                   # last-set values (queue depth)
     "derived": {"cache_hit_rate", "batch_fill_ratio",
                 "boundary_fraction", ...},
     "latency_ms": {"count", "p50", "p90", "p99", "max"}}

Scrapers diff counters between snapshots; the derived block is recomputed
from counters at snapshot time so it is always self-consistent.

**Thread safety** (DESIGN.md §14): the registry is written from submitter
threads, the flusher, and every replica worker at once, so ``inc`` (a
read-modify-write that would silently lose updates), gauge sets, and the
latency window all run under one registry lock; ``snapshot`` takes the
same lock so a scrape never sees a half-applied GeoStats fold.  The
latency window has its own lock because it is exported standalone.
"""
from __future__ import annotations

import json
import threading
from collections import deque

import numpy as np


class LatencyWindow:
    """Sliding window of the most recent N latency samples; percentiles
    are exact over the window (a serving-loop-friendly stand-in for a
    streaming sketch).  Observe/snapshot are lock-guarded: percentiles
    are taken over a stable copy, never a deque mid-append."""

    def __init__(self, window: int = 4096):
        self._samples: deque = deque(maxlen=int(window))
        self._lock = threading.Lock()
        self.count = 0

    def observe(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))
            self.count += 1

    def snapshot_ms(self) -> dict:
        with self._lock:
            if not self._samples:
                return {"count": 0, "p50": None, "p90": None, "p99": None,
                        "max": None}
            s = np.asarray(self._samples) * 1e3
            count = self.count
        return {"count": count,
                "p50": float(np.percentile(s, 50)),
                "p90": float(np.percentile(s, 90)),
                "p99": float(np.percentile(s, 99)),
                "max": float(s.max())}


class ServerMetrics:
    """The registry (see module docstring)."""

    def __init__(self, latency_window: int = 4096):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.latency = LatencyWindow(latency_window)
        # RLock: observe_geo/observe_cache/observe_footprint compose the
        # primitive inc/set under one holder.
        self._lock = threading.RLock()

    def inc(self, name: str, value=1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + value

    def set_gauge(self, name: str, value) -> None:
        with self._lock:
            self.gauges[name] = value

    def observe_latency(self, seconds: float) -> None:
        self.latency.observe(seconds)

    def observe_geo(self, stats) -> None:
        """Fold one micro-batch's GeoStats into ``geo_*`` counters
        (``as_dict`` flattens phase2_miss / overflow / boundary count
        uniformly across strategies).  One lock hold for the whole fold:
        a concurrent snapshot sees all of a batch's counters or none."""
        with self._lock:
            for key, value in stats.as_dict().items():
                self.inc(f"geo_{key}", value)

    def observe_footprint(self, prefix: str, footprint: dict) -> None:
        """Record an index artifact's device-memory footprint
        (``GeoIndexSet.memory_footprint``: edge-pool bytes/blocks and
        the chosen pool block size) as ``<prefix>``-namespaced gauges.
        Set, not summed — the footprint is a property of the built
        index, refreshed whenever the server re-observes it."""
        with self._lock:
            for key, value in footprint.items():
                self.set_gauge(f"{prefix}{key}", value)

    def observe_cache(self, snap: dict) -> None:
        """Absorb a HotCellCache snapshot.  Cache counters are absolute
        (the cache owns them), so they are *set*, not summed — the server
        refreshes them on every snapshot without double-counting."""
        with self._lock:
            for key in ("hits", "misses", "insertions", "evictions",
                        "entries"):
                self.counters[f"cache_{key}"] = snap[key]

    # -- rendering ---------------------------------------------------------

    def _derived(self) -> dict:
        c = self.counters.get
        d = {}
        probes = c("cache_hits", 0) + c("cache_misses", 0)
        d["cache_hit_rate"] = c("cache_hits", 0) / probes if probes else 0.0
        slots = c("padded_slots", 0)
        d["batch_fill_ratio"] = c("valid_slots", 0) / slots if slots else 0.0
        served = c("points_served", 0)
        d["boundary_fraction"] = \
            c("geo_n_boundary", 0) / served if served else 0.0
        d["pip_per_point"] = c("geo_n_pip", 0) / served if served else 0.0
        return d

    def snapshot(self) -> dict:
        with self._lock:
            snap = {"counters": dict(self.counters),
                    "gauges": dict(self.gauges),
                    "derived": self._derived()}
        snap["latency_ms"] = self.latency.snapshot_ms()
        return snap

    def to_json(self, indent=None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
