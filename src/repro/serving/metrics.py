"""Counters / histograms registry for GeoServer (DESIGN.md §10, §15).

One registry per server accumulates everything the ROADMAP's serving item
asks to surface: the per-request ``GeoStats``/``ResolveStats`` counters
(``phase2_miss`` front and centre — a non-zero value says the two-phase
PIP's cap2 is undersized for live traffic — plus overflow and boundary
fraction), cache hit/miss traffic, queue depth, batch-fill ratio (valid
rows / padded slots — how much of the bucket ladder's padding is waste),
deadline-triggered flushes (``deadline_flushes`` — how often the
``max_delay_ms`` SLO clock, not the size trigger, forced a batch out),
request latency percentiles over a sliding sample window, per-region
index memory footprints (edge-pool bytes / block sizes — gauges set at
server construction from ``GeoIndexSet.memory_footprint``), and —
DESIGN.md §15 — **per-stage latency histograms** (``queue_wait`` /
``host_prepare`` / ``device_assign`` / ``merge`` / ``request``:
log-bucketed, mergeable, always on) so an SLO breach attributes to a
stage, not just to "the server".

``snapshot()`` renders the whole registry as one JSON-ready dict:

    {"counters": {...},                 # monotonic sums
     "gauges": {...},                   # last-set values (queue depth,
                                        # cache absolutes)
     "derived": {"cache_hit_rate", "batch_fill_ratio",
                 "boundary_fraction", ...},
     "stages": {"queue_wait": {"count", "p50", "p90", "p99", "mean",
                               "max"}, ...},
     "latency_ms": {"count_total", "count_window", "p50", ...}}

Scrapers diff counters between snapshots — which is exactly why cache
absolutes live in ``gauges``: the cache owns its totals and a clear or
restart would rewind a counter, producing phantom negative deltas.  The
monotonic serving-side twins (``cache_hits_total`` & co.) are
incremented at the observation sites in ``server.py`` and never rewind.
The derived block is recomputed from the registry at snapshot time so
it is always self-consistent.

``expose_text()`` renders the same registry as Prometheus-style text
exposition (counters with a ``_total`` suffix, gauges, and per-stage
``stage_latency_seconds`` histograms with cumulative ``le`` buckets) —
``GeoServer.metrics_text()`` refreshes and returns it, ready to serve
from a ``/metrics`` endpoint.

**Thread safety** (DESIGN.md §14): the registry is written from submitter
threads, the flusher, and every replica worker at once, so ``inc`` (a
read-modify-write that would silently lose updates), gauge sets, and the
latency window all run under one registry lock; ``snapshot`` takes the
same lock so a scrape never sees a half-applied GeoStats fold.  The
latency window and each stage histogram have their own locks because
they are exported standalone.
"""
from __future__ import annotations

import json
import re
import threading
from collections import deque

import numpy as np

from repro.obs.hist import LatencyHistogram

# The serve-path stages every server observes (servers may add more —
# the dict is open); kept in pipeline order for rendering.
STAGES = ("queue_wait", "host_prepare", "device_assign", "merge",
          "request")

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _metric_name(name: str) -> str:
    """Prometheus-legal metric name (best effort)."""
    name = _NAME_RE.sub("_", str(name))
    if not name or not (name[0].isalpha() or name[0] in "_:"):
        name = "_" + name
    return name


def _fmt_num(value) -> str:
    """Exposition number formatting: integers bare, floats via %g."""
    f = float(value)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return format(f, "g")


class LatencyWindow:
    """Sliding window of the most recent N latency samples; percentiles
    are exact over the window.  This is deliberately NOT a streaming
    sketch — the repo's one streaming-quantile implementation is
    ``repro.obs.hist.LatencyHistogram`` (log-bucketed, mergeable), which
    ``ServerMetrics.observe_latency`` feeds in parallel with this
    window; the streaming *distinct-count* story is
    ``repro.analytics.sketch.DistinctSketch``.  Keep this class a plain
    exact window: it answers "recent-p99" with zero bucketing error,
    and the histogram answers everything long-horizon.  Observe/snapshot
    are lock-guarded: percentiles are taken over a stable copy, never a
    deque mid-append.

    ``snapshot_ms`` reports **both** counts: ``count_total`` (lifetime
    observations) and ``count_window`` (samples the percentiles are
    actually computed over) — a dashboard must never read a
    4096-sample p99 as covering millions of requests."""

    def __init__(self, window: int = 4096):
        self._samples: deque = deque(maxlen=int(window))  # guarded-by: _lock
        self._lock = threading.Lock()
        self.count = 0                 # guarded-by: _lock

    def observe(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))
            self.count += 1

    def snapshot_ms(self) -> dict:
        with self._lock:
            if not self._samples:
                return {"count_total": self.count, "count_window": 0,
                        "p50": None, "p90": None, "p99": None,
                        "max": None}
            s = np.asarray(self._samples) * 1e3
            count = self.count
        return {"count_total": count, "count_window": len(s),
                "p50": float(np.percentile(s, 50)),
                "p90": float(np.percentile(s, 90)),
                "p99": float(np.percentile(s, 99)),
                "max": float(s.max())}


class ServerMetrics:
    """The registry (see module docstring)."""

    def __init__(self, latency_window: int = 4096):
        self.counters: dict[str, float] = {}  # guarded-by: _lock
        self.gauges: dict[str, float] = {}    # guarded-by: _lock
        self.latency = LatencyWindow(latency_window)
        # Per-stage histograms, created lazily so custom stages are
        # first-class; the well-known serve stages are in STAGES.
        self._stages: dict[str, LatencyHistogram] = {}  # guarded-by: _lock
        # RLock: observe_geo/observe_cache/observe_footprint compose the
        # primitive inc/set under one holder.
        self._lock = threading.RLock()

    def inc(self, name: str, value=1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + value

    def set_gauge(self, name: str, value) -> None:
        with self._lock:
            self.gauges[name] = value

    def stage(self, name: str) -> LatencyHistogram:
        """The named stage's histogram (created on first use)."""
        with self._lock:
            hist = self._stages.get(name)
            if hist is None:
                hist = self._stages[name] = LatencyHistogram()
            return hist

    def observe_stage(self, name: str, seconds: float) -> None:
        self.stage(name).observe(seconds)

    def observe_latency(self, seconds: float) -> None:
        """End-to-end request latency: feeds both the exact sliding
        window and the mergeable ``request`` stage histogram."""
        self.latency.observe(seconds)
        self.observe_stage("request", seconds)

    def observe_geo(self, stats) -> None:
        """Fold one micro-batch's GeoStats into ``geo_*`` counters
        (``as_dict`` flattens phase2_miss / overflow / boundary count
        uniformly across strategies).  One lock hold for the whole fold:
        a concurrent snapshot sees all of a batch's counters or none."""
        with self._lock:
            for key, value in stats.as_dict().items():
                self.inc(f"geo_{key}", value)

    def observe_footprint(self, prefix: str, footprint: dict) -> None:
        """Record an index artifact's device-memory footprint
        (``GeoIndexSet.memory_footprint``: edge-pool bytes/blocks and
        the chosen pool block size) as ``<prefix>``-namespaced gauges.
        Set, not summed — the footprint is a property of the built
        index, refreshed whenever the server re-observes it."""
        with self._lock:
            for key, value in footprint.items():
                self.set_gauge(f"{prefix}{key}", value)

    def observe_cache(self, snap: dict) -> None:
        """Absorb a HotCellCache snapshot.  Cache counters are absolute
        (the cache owns them, and a cache clear/restart rewinds them),
        so they are **gauges** — set, never summed: a scraper diffing
        ``counters`` must not see phantom negative deltas.  The
        monotonic ``cache_*_total`` twins are incremented at the
        observation sites in ``server.py`` and count per-*point*
        traffic (the cache's own numbers count deduplicated per-batch
        probes, so traffic >= probes)."""
        with self._lock:
            for key in ("hits", "misses", "insertions", "evictions",
                        "entries"):
                self.gauges[f"cache_{key}"] = snap[key]

    # -- rendering ---------------------------------------------------------

    def _derived(self) -> dict:
        c = self.counters.get
        g = self.gauges.get
        d = {}
        # Hit rate from the cache's own absolutes (gauges): exactly the
        # cache's lifetime ratio, immune to scrape timing.
        probes = g("cache_hits", 0) + g("cache_misses", 0)
        d["cache_hit_rate"] = g("cache_hits", 0) / probes if probes else 0.0
        slots = c("padded_slots", 0)
        d["batch_fill_ratio"] = c("valid_slots", 0) / slots if slots else 0.0
        served = c("points_served", 0)
        d["boundary_fraction"] = \
            c("geo_n_boundary", 0) / served if served else 0.0
        d["pip_per_point"] = c("geo_n_pip", 0) / served if served else 0.0
        return d

    def snapshot(self) -> dict:
        with self._lock:
            snap = {"counters": dict(self.counters),
                    "gauges": dict(self.gauges),
                    "derived": self._derived()}
            stages = dict(self._stages)
        snap["stages"] = {name: hist.snapshot_ms()
                          for name, hist in stages.items()}
        snap["latency_ms"] = self.latency.snapshot_ms()
        return snap

    def to_json(self, indent=None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def expose_text(self) -> str:
        """Prometheus-style text exposition of the whole registry:

            requests_total 42
            queue_depth_points 0
            stage_latency_seconds_bucket{stage="queue_wait",le="..."} 7

        Counters get a ``_total`` suffix (monotonic by construction);
        gauges render bare; every stage histogram renders cumulative
        ``le`` buckets (truncated after the bucket holding every
        sample — the all-equal tail), ``+Inf``, ``_sum`` and
        ``_count``.  Deterministic ordering (sorted names) so the
        output is golden-testable and diff-friendly."""
        with self._lock:
            counters = dict(self.counters)
            gauges = dict(self.gauges)
            stages = dict(self._stages)
        lines = []
        for name in sorted(counters):
            mname = _metric_name(name)
            if not mname.endswith("_total"):
                mname += "_total"
            lines.append(f"# TYPE {mname} counter")
            lines.append(f"{mname} {_fmt_num(counters[name])}")
        for name in sorted(gauges):
            mname = _metric_name(name)
            lines.append(f"# TYPE {mname} gauge")
            lines.append(f"{mname} {_fmt_num(gauges[name])}")
        if stages:
            lines.append("# TYPE stage_latency_seconds histogram")
            for name in sorted(stages):
                hist = stages[name]
                label = f'stage="{_metric_name(name)}"'
                for upper, cum in hist.cumulative():
                    lines.append(
                        f'stage_latency_seconds_bucket{{{label},'
                        f'le="{format(upper, "g")}"}} {cum}')
                with hist._lock:
                    count, total = hist.count, hist.sum
                lines.append(f'stage_latency_seconds_bucket{{{label},'
                             f'le="+Inf"}} {count}')
                lines.append(f'stage_latency_seconds_sum{{{label}}} '
                             f'{format(total, "g")}')
                lines.append(f'stage_latency_seconds_count{{{label}}} '
                             f'{count}')
        return "\n".join(lines) + "\n"
