"""GeoServer: the streaming geo-assignment serving facade (DESIGN.md §10).

Turns one or more ``GeoEngine``s into an online service over a request
stream:

    server = GeoServer.build(census, strategy="hybrid")
    server.warm()                         # pre-pay every bucket's JIT
    res = server.submit(points)           # [n, 2] -> ServeResult
    print(server.metrics.to_json())       # live counters / latency

The pieces (each its own module, composable without the facade):

  * ``batcher.MicroBatcher``  — bounded FIFO queue; coalesces requests
    into micro-batches padded up the bucket ladder so each strategy
    compiles once per bucket, with block/shed backpressure.  Flushes
    fire on ``submit``, on the size trigger (block policy), and — when
    ``ServeConfig.max_delay_ms`` is set — on a time deadline
    (``poll()``), so latency SLOs hold under trickle traffic;
  * ``cache.HotCellCache``    — exact host-side hot-cell shortcut for
    interior-cell traffic, full-engine fallback for everything else;
  * ``metrics.ServerMetrics`` — counters/gauges/latency registry
    (``phase2_miss`` et al. surfaced per the ROADMAP serving item).

**Multi-region routing**: pass a list of engines (one per regional index
— the production shape where no single host holds the national index)
and ``submit`` routes each point to its owning region via the engines'
extent masks (PR 2's ``extent_mask``, exposed through
``GeoEngine.extent_contains``).  Ownership is deterministic: the first
region (list order) whose extent contains the point wins, so a point on
a shared border resolves identically on every submit.  Points in no
region's extent come back -1 with ``region == -1`` (true for the
single-engine server too — extents cover all map geometry, so the
engine's own answer for such points is -1 anyway and they skip the
device).  Results merge back in input order whatever the routing.

Bit-identity contract: with the cache off, every served point's
(state, county, block) equals a direct ``engine.assign`` on the owning
engine — padding is FAR-neutralized, coalescing never reorders results.
With the cache on the same holds for every exact engine configuration
(see cache.py for the interior-cell argument and the overflow caveat).

This facade's serving loop is synchronous and single-threaded — the unit
of concurrency here is the device batch.  The concurrent front-end is
``frontend.AsyncGeoServer`` (DESIGN.md §14): it reuses this class's
regions/batcher/metrics and the two-stage serve path below
(``_prepare_batch`` — routing + cache, ordered; ``_complete_batch`` —
engine assigns, dispatchable to replica workers), so sync and async
serving share one code path and stay bit-identical.

**Cold start**: ``GeoServer.from_artifact(path)`` serves a
``GeoIndexSet`` saved with ``indices.save(path)`` (core/artifact.py) —
the covering BFS comes off disk, device indices rebuild bit-identically,
and ``strategy="auto"`` replans for the current device.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics import AnalyticsConfig, WindowedAggregator
from repro.core.cells import build_cell_covering
from repro.core.engine import EngineConfig, GeoEngine
from repro.core.geometry import CensusMap, polygon_areas
from repro.core.resolve import GeoStats
from repro.serving.batcher import (DEFAULT_BUCKETS, MicroBatch,
                                   MicroBatcher, QueueFull, bucket_for,
                                   pad_points)
from repro.core.fast import np_extent_mask, np_quantize_codes
from repro.obs import profile as obs_profile
from repro.obs.trace import Tracer
from repro.serving.cache import CellTable, HotCellCache
from repro.serving.metrics import ServerMetrics


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving knobs."""

    buckets: tuple = DEFAULT_BUCKETS   # micro-batch padding ladder
    max_queue_points: int = 1 << 16    # backpressure bound
    policy: str = "block"              # "block" | "shed" (batcher.py)
    cache: bool = True                 # hot-cell cache (cache.py)
    cache_capacity: int = 1 << 16      # LRU entries per region
    latency_window: int = 4096         # latency percentile sample window
    max_delay_ms: Optional[float] = None  # flush deadline: oldest queued
    #                                       request older than this
    #                                       triggers a flush (enqueue
    #                                       checks it; timers call
    #                                       ``poll()``) so trickle
    #                                       traffic still meets latency
    #                                       SLOs instead of waiting for
    #                                       the size trigger.  None =
    #                                       size/submit-driven only.
    trace_device: bool = False         # wrap device-stage assigns in
    #                                    jax.profiler.TraceAnnotation so
    #                                    a captured device trace
    #                                    (start_profile/stop_profile)
    #                                    names each region/bucket range
    #                                    (DESIGN.md §15).
    analytics: Optional[AnalyticsConfig] = None  # opt-in windowed
    #                                    streaming analytics: every served
    #                                    batch also feeds a per-region
    #                                    WindowedAggregator (occupancy /
    #                                    encounters / k-anon suppression —
    #                                    DESIGN.md §16); read via
    #                                    ``snapshot_analytics()``.


@dataclasses.dataclass
class ServeResult:
    """Per-request outcome, rows in input order.  ``region`` is the index
    of the owning engine (-1 = in no region's extent); ids are that
    region's local (state, county, block) ids, -1 = not on its map."""

    state: np.ndarray
    county: np.ndarray
    block: np.ndarray
    region: np.ndarray
    latency_s: float


class _Ticket:
    """One in-flight request: preallocated result arrays filled as its
    micro-batch parts complete (a request can span batches — and under
    the async front-end those batches can complete on different replica
    threads, so the remaining-count bookkeeping is lock-guarded and
    ``fill`` reports completion atomically: exactly one filler sees
    True).  Different parts write disjoint row ranges, so the array
    writes themselves need no lock.

    Tracing rides on the ticket (DESIGN.md §15): ``trace`` is the
    request's ``RequestTrace`` (None = unsampled — the whole request
    records nothing), ``enqueue_ts`` is the queue-wait clock the
    batcher re-stamps on every put/requeue (``mark_enqueued``), and
    ``attempt`` counts failed-flush retries so a retried request's
    spans stay distinguishable."""

    __slots__ = ("state", "county", "block", "region", "_remaining",
                 "_t0", "_lock", "latency_s", "trace", "enqueue_ts",
                 "attempt", "seq")

    # Process-wide request sequence: the analytics layer's *source
    # identity* — two points from the same submit share a seq, so
    # per-block distinct-source counts read "distinct requests", the
    # encounter/co-location unit (DESIGN.md §16).
    _seq = itertools.count()

    def __init__(self, n: int, t0: float, trace=None):
        self.seq = next(_Ticket._seq)
        self.state = np.full(n, -1, np.int32)
        self.county = np.full(n, -1, np.int32)
        self.block = np.full(n, -1, np.int32)
        self.region = np.full(n, -1, np.int32)
        self._remaining = n            # guarded-by: _lock
        self._t0 = t0
        self._lock = threading.Lock()
        self.latency_s = 0.0 if n == 0 else None  # guarded-by: _lock
        self.trace = trace
        self.enqueue_ts = t0
        self.attempt = 0
        if n == 0 and trace is not None:   # trivially complete
            trace.end(t0, n_points=0)

    def mark_enqueued(self) -> None:
        """Batcher hook: the ticket just (re-)entered the queue — its
        queue-wait interval starts now."""
        self.enqueue_ts = time.perf_counter()

    def fill(self, req_off: int, length: int, sid, cid, bid,
             region) -> bool:
        """Write one served part; True exactly once, when this part
        completes the request (the caller owning that True observes the
        latency / resolves the future)."""
        sl = slice(req_off, req_off + length)
        self.state[sl] = sid
        self.county[sl] = cid
        self.block[sl] = bid
        self.region[sl] = region
        with self._lock:
            self._remaining -= length
            if self._remaining != 0:
                return False
            self.latency_s = time.perf_counter() - self._t0
        self._completed()
        return True

    def _completed(self) -> None:
        """Completion hook — the async front-end's future ticket resolves
        its Future here; the sync ticket needs nothing."""

    @property
    def done(self) -> bool:
        with self._lock:
            return self._remaining == 0

    def result(self) -> ServeResult:
        if not self.done:
            raise RuntimeError("request not fully served yet — flush()")
        return ServeResult(self.state, self.county, self.block,
                           self.region, self.latency_s)


@dataclasses.dataclass
class _Region:
    """One hosted engine plus its host-side serving companions (quant
    and parent tables snapshotted once at construction — the routing /
    cache-hit hot paths never touch the device)."""

    engine: GeoEngine
    quant: np.ndarray                     # [4] f32, host snapshot
    max_level: int
    block_parent: np.ndarray
    county_parent: np.ndarray
    cache: Optional[HotCellCache]
    analytics: Optional[WindowedAggregator] = None  # ServeConfig.analytics
    stats: Optional[GeoStats] = None      # guarded-by: lock
    # Guards the stats merge — replica workers can finish two of this
    # region's batches at once (GeoStats.merge is a sum, so merge order
    # never matters, only merge atomicity).
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    def host_parents_of(self, bid: np.ndarray):
        """(state, county) from block ids — cache hits only: hits are
        interior cells, so bid >= 0 and the derivation is complete.
        Engine misses keep the engine's own state/county instead (the
        cascade can resolve a state yet lose the block — see
        _serve_region)."""
        cid = np.where(bid >= 0,
                       self.block_parent[np.clip(bid, 0, None)], -1)
        sid = np.where(cid >= 0,
                       self.county_parent[np.clip(cid, 0, None)], -1)
        return sid.astype(np.int32), cid.astype(np.int32)


@dataclasses.dataclass
class _BatchWork:
    """One micro-batch between the host stage and the device stage:
    routing + cache hits already resolved (in arrival order), engine
    work still pending.  The async front-end's unit of dispatch."""

    mb: MicroBatch
    owner: np.ndarray               # [n] i32 owning region per point
    sid: np.ndarray                 # [n] i32, cache hits filled, else -1
    cid: np.ndarray
    bid: np.ndarray
    device: list                    # [(region_ix, sel rows, miss rows)]
    ats: float = 0.0                # analytics event time, stamped in
    #                                 the (ordered) host stage
    src: Optional[np.ndarray] = None  # [n] i64 source id (request seq)
    #                                 per point, None = analytics off


class GeoServer:
    """Streaming serving facade over one or more GeoEngines (see module
    docstring)."""

    def __init__(self, engines: Union[GeoEngine, Sequence[GeoEngine]],
                 cfg: Optional[ServeConfig] = None, *, covering=None,
                 tracer: Optional[Tracer] = None):
        """``covering`` optionally provides the covering(s) the hot-cell
        cache needs (one, or one per engine) — for engines without one
        (strategy "simple") it is otherwise built from the engine's
        census, a one-time host BFS.  ``tracer`` (obs/trace.py) opts the
        server into per-request span recording at the tracer's sample
        rate; the per-stage latency histograms in ``metrics`` are
        always on, tracer or not."""
        self.cfg = cfg or ServeConfig()
        self.tracer = tracer
        if isinstance(engines, GeoEngine):
            engines = [engines]
        if not engines:
            raise ValueError("GeoServer needs at least one engine")
        coverings = covering if isinstance(covering, (list, tuple)) \
            else [covering] * len(engines)
        if len(coverings) != len(engines):
            raise ValueError("covering list must match engines")
        self._analytics_on = self.cfg.analytics is not None
        self.regions = [self._make_region(e, c)
                        for e, c in zip(engines, coverings)]
        self.metrics = ServerMetrics(self.cfg.latency_window)
        # Surface each region's built index footprint (edge-pool bytes,
        # chosen pool block size, ...) so operators see what the tile
        # autotune actually costs in device memory.
        for r_ix, region in enumerate(self.regions):
            self.metrics.observe_footprint(
                f"region{r_ix}_",
                region.engine.indices.memory_footprint())
        self.batcher = MicroBatcher(self.cfg.buckets,
                                    self.cfg.max_queue_points,
                                    self.cfg.policy)

    def _make_region(self, engine: GeoEngine, covering) -> _Region:
        block_parent, county_parent = engine.host_parents()
        cache = None
        if self.cfg.cache:
            cov = covering if covering is not None else engine.covering
            if cov is None:
                if engine.census is None:
                    raise ValueError(
                        "the hot-cell cache needs a covering: pass "
                        "covering=, build the engine from a census, or "
                        "serve with ServeConfig(cache=False)")
                cov = build_cell_covering(engine.census,
                                          max_level=engine.cfg.max_level,
                                          max_cand=engine.cfg.max_cand)
            cache = HotCellCache(CellTable.from_covering(cov),
                                 self.cfg.cache_capacity)
        quant, max_level = engine.extent_quant()
        analytics = None
        if self._analytics_on:
            areas = polygon_areas(engine.census.blocks) \
                if engine.census is not None else None
            analytics = WindowedAggregator(len(block_parent),
                                           self.cfg.analytics, areas)
        return _Region(engine, quant, max_level, block_parent,
                       county_parent, cache, analytics=analytics)

    @classmethod
    def build(cls, census: CensusMap, strategy: str = "fast",
              cfg: Optional[ServeConfig] = None,
              engine_cfg: Optional[EngineConfig] = None) -> "GeoServer":
        """Single-region convenience: build the engine and serve it
        (``strategy="auto"`` lets the planner choose — see
        core/plan.py)."""
        engine = GeoEngine.build(census, strategy,
                                 engine_cfg or EngineConfig())
        return cls(engine, cfg)

    @classmethod
    def from_artifact(cls, path: str, strategy: str = "auto",
                      cfg: Optional[ServeConfig] = None,
                      engine_cfg: Optional[EngineConfig] = None
                      ) -> "GeoServer":
        """Cold start from a saved ``GeoIndexSet`` artifact
        (core/artifact.py): the covering BFS is read from disk instead of
        rebuilt, device indices are re-derived bit-identically, and the
        served assignments match the engine that saved the artifact.
        ``strategy="auto"`` replans against the loaded capabilities."""
        from repro.core.artifact import GeoIndexSet
        indices = GeoIndexSet.load(path)
        engine = GeoEngine.from_index_set(indices, strategy, engine_cfg)
        return cls(engine, cfg, covering=indices.covering)

    # -- lifecycle ---------------------------------------------------------

    def warm(self, buckets: Optional[Sequence[int]] = None) -> dict:
        """Pre-compile every (bucket, engine) pair the ladder can emit by
        running an all-padding batch through each; returns bucket ->
        wall seconds (compile time on first call, ~0 after).  Call before
        taking traffic so no live request pays an XLA compile."""
        times = {}
        for bucket in buckets or self.cfg.buckets:
            t0 = time.perf_counter()
            zeros = jnp.zeros((int(bucket), 2), jnp.float32)
            for region in self.regions:
                jax.block_until_ready(
                    region.engine.assign_padded(zeros, 0).block)
            times[int(bucket)] = time.perf_counter() - t0
            self.metrics.inc("warm_batches")
        return times

    # -- request path ------------------------------------------------------

    def _start_trace(self, t0: float):
        """Head-sampled RequestTrace for a new request (None = tracer
        absent or this request not sampled)."""
        return None if self.tracer is None else self.tracer.start_trace(t0)

    def enqueue(self, points) -> _Ticket:
        """Queue one request ([n, 2] lon/lat); returns its ticket.  Under
        the "shed" policy a full queue raises QueueFull (counted); under
        "block" it triggers an inline flush to make room."""
        points = np.asarray(points, np.float32).reshape(-1, 2)
        t0 = time.perf_counter()
        ticket = _Ticket(len(points), t0, trace=self._start_trace(t0))
        self.metrics.inc("requests")
        self.metrics.inc("points_in", len(points))
        if len(points) == 0:
            return ticket                  # trivially complete
        try:
            accepted = self.batcher.put(ticket, points)
        except QueueFull:
            self.metrics.inc("shed_requests")
            self.metrics.inc("shed_points", len(points))
            if ticket.trace is not None:   # shed atomically: root closes,
                ticket.trace.end(error="QueueFull")  # no orphan children
            raise
        if not accepted:                   # "block": serve-now, then queue
            self.flush()
            self.batcher.put(ticket, points)
        if ticket.trace is not None:
            ticket.trace.span("submit", t0, time.perf_counter(),
                              n_points=len(points))
        self._update_queue_gauges()
        # Deadline trigger rides the arrival path too: a trickle of tiny
        # requests must not wait for the size trigger (idle gaps are the
        # timer's job — ``poll()``).
        self.poll()
        return ticket

    def submit(self, points) -> ServeResult:
        """Synchronous round trip: enqueue + flush + result."""
        ticket = self.enqueue(points)
        if not ticket.done:
            self.flush()
        return ticket.result()

    def poll(self) -> int:
        """Deadline tick (``ServeConfig.max_delay_ms``): flush when the
        oldest queued request has waited past the deadline; returns
        micro-batches served (0 = nothing due).  ``enqueue`` calls this
        on every arrival; an async front-end or timer loop calls it in
        idle gaps so the last trickle request is never stranded.
        Deadline-triggered flushes are counted in ``deadline_flushes``
        (metrics) so SLO pressure is visible next to the size trigger."""
        if self.cfg.max_delay_ms is None or not len(self.batcher):
            return 0
        if self.batcher.oldest_age_s() * 1e3 < self.cfg.max_delay_ms:
            return 0
        self.metrics.inc("deadline_flushes")
        return self.flush()

    def flush(self) -> int:
        """Drain the queue through the engines; returns micro-batches
        served.  Flushing an empty queue is a no-op.  If serving dies
        mid-flush (device error in one engine), every drained-but-
        unserved batch — including the failed one, whose tickets are
        untouched until the batch completes — is requeued at the front
        of the queue, so no request is lost: the exception propagates
        and a later flush() retries."""
        batches = self.batcher.drain()
        served = 0
        try:
            for mb in batches:
                self._serve_batch(mb)
                served += 1
        finally:
            if served < len(batches):
                entries = [(t, mb.points[bo:bo + ln], ro)
                           for mb in batches[served:]
                           for (t, ro, bo, ln) in mb.parts]
                self._note_retries(t for t, _, _ in entries)
                self.batcher.requeue(entries)
                self.metrics.inc("failed_flushes")
            if served and any(r.cache is not None for r in self.regions):
                # Keep cache_* counters fresh so metrics.snapshot()/
                # to_json() is accurate without GeoServer.snapshot().
                self.metrics.observe_cache(self.cache_snapshot())
            self._update_queue_gauges()
        return len(batches)

    def _update_queue_gauges(self) -> None:
        self.metrics.set_gauge("queue_depth_points",
                               self.batcher.queued_points)
        self.metrics.set_gauge("queue_depth_requests", len(self.batcher))

    def _note_retries(self, tickets) -> None:
        """Bump every distinct ticket's attempt counter and record a
        linked ``retry`` span (parent = the request's root) — a retried
        request's later spans carry the new attempt number, so its
        timeline reads attempt-by-attempt."""
        seen = set()
        for t in tickets:
            if id(t) in seen:
                continue
            seen.add(id(t))
            t.attempt += 1
            if t.trace is not None:
                t.trace.event("retry", attempt=t.attempt)

    # -- serving internals -------------------------------------------------

    def _route(self, pts: np.ndarray) -> np.ndarray:
        """Owning region per point: first region (list order) whose
        extent contains it — deterministic on shared/overlapping borders;
        -1 when no extent matches (single- and multi-region alike, so
        ``region == -1`` always means "in no region's extent").  Unowned
        points skip the device and answer -1 directly — result-identical
        to asking an engine, since the extent covers all of its map
        geometry and every strategy rejects off-extent points (PR 2)."""
        owner = np.full(len(pts), -1, np.int32)
        for r_ix, region in enumerate(self.regions):
            inside = np_extent_mask(region.quant, region.max_level, pts)
            owner = np.where((owner < 0) & inside, r_ix, owner)
        return owner

    def _serve_batch(self, mb: MicroBatch) -> None:
        self._complete_batch(self._prepare_batch(mb))

    def _prepare_batch(self, mb: MicroBatch) -> "_BatchWork":
        """HOST stage, run in arrival order: route every point to its
        region, resolve cache hits, and *learn* the eligible miss codes
        — learning needs only the covering table, never the engine
        result, so it can (and must, for determinism) happen here.  The
        async front-end runs this stage single-threaded in its flusher,
        which is what keeps the cache's hit/miss/learn sequence — and
        with it the set of device-served points and the merged GeoStats
        — identical to the synchronous server's for the same request
        order (DESIGN.md §14).

        Observability (§15): the stage interval feeds the
        ``host_prepare``/``queue_wait`` histograms per batch, and every
        *sampled* ticket in the batch gets queue_wait + host_prepare
        spans (children: route, per-region cache_lookup/cache_learn) —
        the whole batch shares one timing, each sampled request records
        its own copy so per-request timelines stay self-contained."""
        tp0 = time.perf_counter()
        pts = mb.points
        n = len(pts)
        owner = self._route(pts)
        tr1 = time.perf_counter()
        sid = np.full(n, -1, np.int32)
        cid = np.full(n, -1, np.int32)
        bid = np.full(n, -1, np.int32)
        device = []
        sub = [("route", tp0, tr1, {})]    # host_prepare sub-intervals
        for r_ix, region in enumerate(self.regions):
            sel = np.nonzero(owner == r_ix)[0]
            if not sel.size:
                continue
            rs, rc, rb, mi, rsub = self._host_stage(region, pts[sel],
                                                    r_ix)
            sub += rsub
            sid[sel], cid[sel], bid[sel] = rs, rc, rb
            if mi.size:
                device.append((r_ix, sel, mi))
        tp1 = time.perf_counter()
        self.metrics.observe_stage("host_prepare", tp1 - tp0)
        seen = set()
        for ticket, _, _, _ in mb.parts:
            if id(ticket) in seen:
                continue
            seen.add(id(ticket))
            # Snapshot the clock once: a concurrent requeue (another
            # part of this ticket failing on a replica) may restamp
            # enqueue_ts past tp0 — clamp so the interval stays valid.
            enq = min(ticket.enqueue_ts, tp0)
            self.metrics.observe_stage("queue_wait", tp0 - enq)
            trace = ticket.trace
            if trace is None:
                continue
            attrs = {"attempt": ticket.attempt} if ticket.attempt else {}
            trace.span("queue_wait", enq, tp0, **attrs)
            host = trace.span("host_prepare", tp0, tp1, **attrs)
            for name, s0, s1, sattrs in sub:
                trace.span(name, s0, s1, parent=host, **sattrs, **attrs)
        ats, src = 0.0, None
        if self._analytics_on:
            # Analytics event time + source ids are stamped HERE, in the
            # host stage — sync flush and the async dispatcher both run
            # this stage serialized in arrival order, so a batch's window
            # membership is decided before replica threads race on
            # completion; the window folds themselves commute
            # (DESIGN.md §16).
            ats = self.cfg.analytics.clock()
            src = np.empty(n, np.int64)
            for ticket, _, batch_off, length in mb.parts:
                src[batch_off:batch_off + length] = ticket.seq
        return _BatchWork(mb, owner, sid, cid, bid, device, ats, src)

    def _host_stage(self, region: _Region, pts: np.ndarray, r_ix: int):
        """Cache lookup + learn for one region's slice of a batch;
        returns (state, county, block, miss_rows, sub_intervals) with
        hit rows filled and miss rows -1.  Off-extent points stay
        misses: the engine answers them -1, and their border-clipped
        codes must never touch the cache.  Cache hits are interior
        cells (block always >= 0), so the host parent tables give the
        complete exact answer.

        ``sub_intervals`` are (name, t0, t1, attrs) rows — the
        cache_lookup/cache_learn children of the batch's host_prepare
        span.  The monotonic ``cache_*_total`` counters increment here,
        at the observation site (per-point hits, per-eligible-probe
        misses, learn-returned insertions), so scrapers can diff them
        across cache clears without phantom negative deltas."""
        m = len(pts)
        sid = np.full(m, -1, np.int32)
        cid = np.full(m, -1, np.int32)
        bid = np.full(m, -1, np.int32)
        miss = np.ones(m, bool)
        if region.cache is None:
            return sid, cid, bid, np.nonzero(miss)[0], []
        tl0 = time.perf_counter()
        codes = np_quantize_codes(region.cache.table.quant,
                                  region.cache.table.max_level, pts)
        eligible = np_extent_mask(region.cache.table.quant,
                                  region.cache.table.max_level, pts)
        n_hit = 0
        n_eligible = int(eligible.sum())
        if n_eligible:
            el = np.nonzero(eligible)[0]
            cbid, hit = region.cache.lookup(codes[el])
            hit_rows = el[hit]
            n_hit = int(hit_rows.size)
            bid[hit_rows] = cbid[hit]
            sid[hit_rows], cid[hit_rows] = \
                region.host_parents_of(bid[hit_rows])
            miss[hit_rows] = False
        tl1 = time.perf_counter()
        self.metrics.inc("cache_hits_total", n_hit)
        self.metrics.inc("cache_misses_total", n_eligible - n_hit)
        sub = [("cache_lookup", tl0, tl1,
                {"region": r_ix, "rows": m, "hits": n_hit})]
        mi = np.nonzero(miss)[0]
        learnable = mi[eligible[mi]]
        if learnable.size:
            # The learned value comes from the covering's interior table,
            # not the engine — exact by the interior invariant, so
            # learning before the device assign changes nothing but
            # makes the host stage self-contained.
            inserted = region.cache.learn(codes[learnable])
            tn1 = time.perf_counter()
            self.metrics.inc("cache_insertions_total", inserted)
            sub.append(("cache_learn", tl1, tn1,
                        {"region": r_ix, "inserted": inserted}))
        return sid, cid, bid, mi, sub

    def _complete_batch(self, work: "_BatchWork") -> None:
        """DEVICE stage + result scatter: engine-assign every region's
        cache-miss rows, then fill tickets.  Order-free: the arrays it
        writes are disjoint per part and the stats/metrics folds are
        sums, so the async front-end dispatches whole ``_BatchWork``s to
        replica workers round-robin and results stay bit-identical
        whatever the completion order.

        Observability (§15): each region's padded assign feeds the
        ``device_assign`` histogram and — since a ticket only fills
        after *every* region of its batch served — each sampled ticket
        records every device interval of the batch.  The completing
        part additionally records the ``merge`` span and closes the
        request's root."""
        pts = work.mb.points
        dev = []                           # (t0, t1, attrs) per region
        for r_ix, sel, mi in work.device:
            region = self.regions[r_ix]
            td0 = time.perf_counter()
            rs, rc, rb = self._device_stage(region, pts[sel], mi)
            td1 = time.perf_counter()
            self.metrics.observe_stage("device_assign", td1 - td0)
            dev.append((td0, td1,
                        {"region": r_ix, "rows": int(mi.size),
                         "bucket": bucket_for(mi.size, self.cfg.buckets)}))
            work.sid[sel[mi]] = rs
            work.cid[sel[mi]] = rc
            work.bid[sel[mi]] = rb
        self.metrics.inc("batches")
        self.metrics.inc("points_served", len(pts))
        if work.src is not None:
            # Feed the windowed analytics before tickets fill: a synced
            # submit (or an async drain) then implies this batch's rows
            # are already folded into the aggregator — the served-vs-
            # direct equality tests hinge on that ordering.  Cache hits
            # and device answers feed alike; -1 rows count as off_map.
            ta0 = time.perf_counter()
            n_obs = 0
            for r_ix, region in enumerate(self.regions):
                if region.analytics is None:
                    continue
                sel = work.owner == r_ix
                if sel.any():
                    n_obs += region.analytics.observe(
                        work.ats, work.bid[sel], work.src[sel])
            self.metrics.inc("analytics_points", n_obs)
            self.metrics.observe_stage("analytics_observe",
                                       time.perf_counter() - ta0)
        if dev:
            seen = set()
            for ticket, _, _, _ in work.mb.parts:
                if ticket.trace is None or id(ticket) in seen:
                    continue
                seen.add(id(ticket))
                attrs = {"attempt": ticket.attempt} if ticket.attempt \
                    else {}
                for td0, td1, dattrs in dev:
                    ticket.trace.span("device_assign", td0, td1,
                                      **dattrs, **attrs)
        tm0 = time.perf_counter()
        for ticket, req_off, batch_off, length in work.mb.parts:
            bsl = slice(batch_off, batch_off + length)
            if ticket.fill(req_off, length, work.sid[bsl], work.cid[bsl],
                           work.bid[bsl], work.owner[bsl]):
                self.metrics.observe_latency(ticket.latency_s)
                if ticket.trace is not None:
                    done = time.perf_counter()
                    ticket.trace.span("merge", tm0, done)
                    ticket.trace.end(done, n_points=len(ticket.block))
        self.metrics.observe_stage("merge", time.perf_counter() - tm0)

    def _device_stage(self, region: _Region, pts: np.ndarray,
                      mi: np.ndarray):
        """One region's padded engine assign over its miss rows; returns
        (state, county, block) [len(mi)] i32.

        Miss rows keep the engine's own state/county — NOT a re-derivation
        from the block id: the cascade can resolve a point's state yet
        lose it at the county/block level (bbox gap, capacity overflow),
        and that partial answer must survive serving bit-identically."""
        bucket = bucket_for(mi.size, self.cfg.buckets)
        padded = pad_points(pts[mi], bucket)
        # Slot accounting at the device edge: this is the padding the
        # engine actually computes, post-cache and post-routing —
        # batch_fill_ratio measures real ladder waste.
        self.metrics.inc("padded_slots", bucket)
        self.metrics.inc("valid_slots", mi.size)
        if self.cfg.trace_device:
            # Named profiler range so a captured device trace
            # (start_profile/stop_profile) attributes kernels to the
            # serving stage that launched them (DESIGN.md §15).
            with obs_profile.device_annotation(
                    f"geo_device_assign/b{bucket}"):
                res = region.engine.assign_padded(jnp.asarray(padded),
                                                  mi.size)
        else:
            res = region.engine.assign_padded(jnp.asarray(padded),
                                              mi.size)
        with region.lock:
            region.stats = res.stats if region.stats is None \
                else region.stats.merge(res.stats)
        self.metrics.observe_geo(res.stats)
        return (np.asarray(res.state)[:mi.size],
                np.asarray(res.county)[:mi.size],
                np.asarray(res.block)[:mi.size])

    # -- introspection -----------------------------------------------------

    @property
    def stats(self) -> list:
        """Per-region merged GeoStats (None until that region served)."""
        return [r.stats for r in self.regions]

    def cache_snapshot(self) -> dict:
        """Aggregate hot-cell cache counters over all regions."""
        agg = {"entries": 0, "capacity": 0, "hits": 0, "misses": 0,
               "insertions": 0, "evictions": 0}
        for region in self.regions:
            if region.cache is not None:
                snap = region.cache.snapshot()
                for key in agg:
                    agg[key] += snap[key]
        probes = agg["hits"] + agg["misses"]
        agg["hit_rate"] = agg["hits"] / probes if probes else 0.0
        return agg

    def snapshot_analytics(self) -> Optional[dict]:
        """Per-region windowed-analytics snapshots (None = analytics
        off).  Also refreshes the ``analytics_*`` gauges, so a metrics
        scrape right after sees the same state.  Schema per region:
        ``WindowedAggregator.snapshot()`` (DESIGN.md §16)."""
        if not self._analytics_on:
            return None
        snaps = [r.analytics.snapshot() if r.analytics is not None
                 else None for r in self.regions]
        live = [s for s in snaps if s is not None]
        for gauge, key in (("analytics_open_panes", "open_panes"),
                           ("analytics_windows_finalized",
                            "finalized_total"),
                           ("analytics_late_dropped", "late_dropped"),
                           ("analytics_off_map_points", "off_map")):
            self.metrics.set_gauge(gauge, sum(s[key] for s in live))
        suppressed = 0
        for s in live:
            win = s["open"] or (s["finalized"][-1] if s["finalized"]
                                else None)
            if win is not None:
                suppressed += win["suppressed_blocks"]
        self.metrics.set_gauge("analytics_suppressed_blocks", suppressed)
        return {"regions": snaps}

    def snapshot(self) -> dict:
        """The live-metrics JSON snapshot (refreshes cache counters)."""
        self.metrics.observe_cache(self.cache_snapshot())
        self._update_queue_gauges()
        self.snapshot_analytics()
        return self.metrics.snapshot()

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of the live registry
        (refreshes cache/queue/analytics gauges first) — ready to serve
        from a ``/metrics`` endpoint (DESIGN.md §15)."""
        if any(r.cache is not None for r in self.regions):
            self.metrics.observe_cache(self.cache_snapshot())
        self._update_queue_gauges()
        self.snapshot_analytics()
        return self.metrics.expose_text()

    def start_profile(self, logdir: str) -> bool:
        """Begin a JAX device-trace capture into ``logdir`` (True if it
        started); pair with ``stop_profile``.  With
        ``ServeConfig(trace_device=True)`` each padded assign shows up
        as a named range in the capture."""
        return obs_profile.start_profile(logdir)

    def stop_profile(self) -> bool:
        """End the active device-trace capture (True if one stopped)."""
        return obs_profile.stop_profile()
