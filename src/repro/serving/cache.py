"""Exact hot-cell result cache for GeoServer (DESIGN.md §10).

Serving traffic is heavily repeated in space — the same venues, road
segments, and home cells recur across requests (the mContain deployment's
hot-spot pattern).  This cache short-circuits that traffic on the host:
points whose quantized leaf code is already known resolve to their block
id from a hash map without touching the accelerator at all.

Exactness, not heuristics: an entry is learned **only** for leaf codes
that fall inside an *interior* covering cell — a cell fully contained in
one block polygon (core/cells.py), the paper's "true hit".  Any point in
such a cell belongs to that block, so the cached answer equals what every
exact strategy computes for it (the fast path reads the same cell value;
the simple cascade PIPs its way to the same polygon).  Boundary cells and
off-extent points are never cached — they always take the correctness
fallback: the full cascade/engine on device.  The one caveat: a
capacity-overflowed engine can answer an interior point *less* exactly
than the cache (overflow keeps the bbox select); the cache stays right,
bit-identity with a degraded engine does not — size caps generously.

Keys are leaf codes from the same fp32 quantization the device applies
(``fast.np_quantize_codes``, the bit-exact host mirror of
``fast.quantize_codes``).  Off-extent points are masked with the
companion ``fast.np_extent_mask`` before lookup *and* learn:
quantization clips onto the grid border, and without the mask a far-away
point would hit a border cell's cache line (the PR 2 extent bug, serving
edition).

The LRU holds only the hot subset: at production scale the full interior
table is the 90 GiB device index — the host map is the small, traffic-
selected shadow of it, with hit/miss/insert/evict accounting for the
metrics registry.

**Thread safety** (DESIGN.md §14): one RLock serializes
``lookup``/``learn``/``snapshot``.  The compound LRU operations
(probe-then-move_to_end, insert-then-evict) are not atomic at the
OrderedDict level, so unlocked concurrent callers could over-evict past
capacity, lose inserts, or corrupt the hit/miss counters (lost
read-modify-write updates).  A *stale* entry is impossible by
construction even without the lock — an interior cell's block id never
changes — so the lock's job is purely structural integrity plus honest
accounting.  Values are immutable ints: there is no torn-read risk once
the dict itself is consistent.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import numpy as np

from repro.core.cells import CellCovering
from repro.core.fast import (np_extent_mask, np_quantize_codes,
                             quant_for_extent)

__all__ = ["CellTable", "HotCellCache", "np_extent_mask",
           "np_quantize_codes"]


@dataclasses.dataclass
class CellTable:
    """Host copies of the covering intervals — the cache's safety oracle:
    is this code in an interior cell, and of which block?"""

    lo: np.ndarray              # [n_cells] i32 sorted interval starts
    hi: np.ndarray              # [n_cells] i32 inclusive ends
    val: np.ndarray             # [n_cells] i32 (>= 0 interior block id)
    quant: np.ndarray           # [4] f32 (x0, y0, sx, sy)
    max_level: int

    @classmethod
    def from_covering(cls, cov: CellCovering) -> "CellTable":
        return cls(lo=np.asarray(cov.lo), hi=np.asarray(cov.hi),
                   val=np.asarray(cov.val),
                   quant=quant_for_extent(cov.extent, cov.max_level),
                   max_level=cov.max_level)

    def interior_value(self, codes: np.ndarray) -> np.ndarray:
        """[N] i32 — the owning block id where ``codes`` fall inside an
        interior covering cell, else -1 (boundary cell, covering gap)."""
        if len(self.lo) == 0:
            return np.full(len(codes), -1, np.int32)
        ix = np.clip(np.searchsorted(self.lo, codes, side="right") - 1,
                     0, len(self.lo) - 1)
        in_cell = (self.lo[ix] <= codes) & (codes <= self.hi[ix])
        v = self.val[ix]
        return np.where(in_cell & (v >= 0), v, -1).astype(np.int32)

class HotCellCache:
    """LRU leaf-code -> block-id map with hit/miss accounting (see module
    docstring for the exactness contract)."""

    def __init__(self, table: CellTable, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.table = table
        self.capacity = int(capacity)
        self._map: OrderedDict[int, int] = OrderedDict()  # guarded-by: _lock
        self._lock = threading.RLock()
        self.hits = 0                  # guarded-by: _lock
        self.misses = 0                # guarded-by: _lock
        self.insertions = 0            # guarded-by: _lock
        self.evictions = 0             # guarded-by: _lock

    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def lookup(self, codes: np.ndarray):
        """[N] codes -> (bid [N] i32 with -1 on miss, hit [N] bool).
        Deduplicates per batch: each distinct code is probed (and counted,
        and LRU-touched) once."""
        uniq, inv = np.unique(codes, return_inverse=True)
        ubid = np.full(len(uniq), -1, np.int32)
        with self._lock:
            m = self._map
            for i, code in enumerate(uniq.tolist()):
                v = m.get(code)
                if v is not None:
                    m.move_to_end(code)
                    ubid[i] = v
                    self.hits += 1
                else:
                    self.misses += 1
        bid = ubid[inv]
        return bid, bid >= 0

    def learn(self, codes: np.ndarray) -> int:
        """Insert the interior-safe subset of ``codes`` (value = the
        owning block from the covering — the exact answer by the interior
        invariant); LRU-evicts beyond capacity.  Returns insert count.
        The insert-then-evict pair runs under the cache lock, so entries
        never exceed capacity however many threads learn at once."""
        uniq = np.unique(codes)
        safe = self.table.interior_value(uniq)
        inserted = 0
        with self._lock:
            m = self._map
            for code, bid in zip(uniq.tolist(), safe.tolist()):
                if bid < 0 or code in m:
                    continue
                m[code] = bid
                inserted += 1
                if len(m) > self.capacity:
                    m.popitem(last=False)
                    self.evictions += 1
            self.insertions += inserted
        return inserted

    def snapshot(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"entries": len(self._map), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "insertions": self.insertions,
                    "evictions": self.evictions,
                    "hit_rate": self.hits / total if total else 0.0}
