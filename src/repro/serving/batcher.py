"""Micro-batching request queue for GeoServer (DESIGN.md §10).

Streaming serving sees requests of every shape: one point from a mobile
check-in, thousands from a bulk upload.  jit-compiling per request shape
would thrash the XLA cache, so device batches are padded up a small
geometric ladder of **bucket sizes** (default 256 / 1k / 4k / 16k): each
strategy compiles at most once per bucket, ever, and ``GeoServer.warm()``
can pre-pay all of them before traffic arrives.  The batcher coalesces
queued requests FIFO into micro-batches capped at the top bucket; the
*padding* itself (``bucket_for`` + ``pad_points``, defined here) is
applied by the server at the device edge — after cache hits and region
routing have shrunk the batch — so padded-slot accounting reflects what
the engine actually computes.  Pad rows are neutralized downstream by
``GeoEngine.assign_padded`` (FAR rewrite — they cannot perturb results or
stats), so over-padding costs only lane-aligned compute, never accuracy.

Backpressure is a bounded queue (``max_queue_points``) with two policies:

  * ``block`` — an arriving request that would overflow the bound makes
    the caller flush first (serve-now semantics in the synchronous loop);
  * ``shed``  — the request is refused with ``QueueFull`` and counted, the
    load-shedding answer when latency matters more than completeness.

The batcher is deliberately dumb about *what* a request is: it queues
(ticket, points) pairs and hands back ``MicroBatch`` objects whose
``parts`` say which slice of which ticket each batch row belongs to — the
server owns result assembly, metrics, and caching.

**Thread safety** (DESIGN.md §14): every public method runs under one
internal condition variable, so N producer threads can race ``put``
against a flusher's ``drain``/``requeue`` without losing or duplicating
a ticket, and FIFO order survives a requeue under contention (the
requeue's extendleft is atomic).  ``put(wait=True)`` turns the "block"
policy's caller-must-flush handshake into a real block: the producer
sleeps on the condition until a drain frees room — the async front-end's
backpressure.  ``wait_for_work`` is the flusher side: sleep until the
queue goes non-empty.  The single-threaded serving loop pays one
uncontended lock acquire per call, which is noise next to a device batch.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Optional

import numpy as np

DEFAULT_BUCKETS = (256, 1024, 4096, 16384)


class QueueFull(RuntimeError):
    """Raised under the ``shed`` policy when the queue bound is hit."""


def bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest ladder bucket >= n (callers split anything larger than
    the top bucket, so it also answers for oversized n)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _stamp(ticket: Any) -> None:
    """Tell a ticket it just (re-)entered the queue — the per-request
    queue-wait clock (DESIGN.md §15).  Duck-typed so the batcher stays
    ticket-agnostic: anything without ``mark_enqueued`` (tests use bare
    strings) is silently skipped."""
    mark = getattr(ticket, "mark_enqueued", None)
    if mark is not None:
        mark()


def pad_points(points: np.ndarray, bucket: int) -> np.ndarray:
    """[n, 2] -> [bucket, 2] f32, zero-padded (the pad *value* is
    irrelevant — ``assign_padded`` rewrites pad rows to FAR)."""
    out = np.zeros((bucket, 2), np.float32)
    out[:len(points)] = points
    return out


@dataclasses.dataclass
class MicroBatch:
    """One coalesced batch (unpadded — the server pads each engine
    sub-batch up the ladder at the device edge, after cache hits and
    routing have shrunk it) plus the bookkeeping to scatter results
    back: ``parts`` rows are (ticket, req_off, batch_off, length)."""

    points: np.ndarray          # [n, 2] f32, n <= top bucket
    parts: list


class MicroBatcher:
    """Bounded FIFO request queue that drains into bucket-padded
    micro-batches (see module docstring)."""

    def __init__(self, buckets=DEFAULT_BUCKETS,
                 max_queue_points: int = 1 << 16, policy: str = "block"):
        buckets = tuple(int(b) for b in buckets)
        if not buckets or any(b <= 0 for b in buckets) \
                or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending positive ints, "
                             f"got {buckets!r}")
        if policy not in ("block", "shed"):
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             f"expected 'block' or 'shed'")
        self.buckets = buckets
        self.max_queue_points = int(max_queue_points)
        self.policy = policy
        # (ticket, points [n, 2] f32, base_off): base_off is the slice's
        # offset inside its original request — 0 for fresh puts, > 0 for
        # requeued tails of split requests (see ``requeue``).
        self._q: deque = deque()       # guarded-by: _cond
        self.queued_points = 0         # guarded-by: _cond
        # perf_counter of the oldest queued arrival — the deadline-flush
        # clock (GeoServer's ``max_delay_ms``).  Armed when the queue
        # goes non-empty, cleared on drain; a requeue after a failed
        # flush RE-ARMS it (see ``requeue``), so the deadline bounds the
        # wait since the last serve attempt, not since first arrival.
        self._oldest_ts: Optional[float] = None  # guarded-by: _cond
        # One condition guards every mutation: producers wait on it for
        # room (``put(wait=True)``), the flusher waits on it for work
        # (``wait_for_work``); drain/requeue notify both sides.
        self._cond = threading.Condition()

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    def oldest_age_s(self) -> float:
        """Seconds the oldest queued request has been waiting (0.0 when
        the queue is empty).  Monotone non-decreasing while the queue
        stays non-empty: later puts never reset the clock."""
        with self._cond:
            if self._oldest_ts is None:
                return 0.0
            return time.perf_counter() - self._oldest_ts

    def _has_room(self, n: int) -> bool:
        # An empty queue always accepts (a single request larger than
        # the bound must still be servable — it just flushes alone).
        return (not self._q
                or self.queued_points + n <= self.max_queue_points)

    def put(self, ticket: Any, points: np.ndarray, *, wait: bool = False,
            timeout: Optional[float] = None) -> bool:
        """Enqueue one request.  Returns False when the ``block`` policy
        wants the caller to flush first; raises QueueFull under ``shed``.

        ``wait=True`` (the threaded front-end's spelling of "block")
        sleeps on the internal condition until a drain frees room instead
        of returning False — returning False only if ``timeout`` elapses
        first.  ``shed`` raises immediately either way: load-shedding
        must not stall the producer."""
        points = np.asarray(points, np.float32)
        n = len(points)
        with self._cond:
            if not self._has_room(n):
                if self.policy == "shed":
                    raise QueueFull(
                        f"queue holds {self.queued_points} points, request "
                        f"of {n} exceeds "
                        f"max_queue_points={self.max_queue_points}")
                if not wait:
                    return False
                if not self._cond.wait_for(lambda: self._has_room(n),
                                           timeout):
                    return False
            self._q.append((ticket, points, 0))
            _stamp(ticket)                 # queue-wait clock starts here
            self.queued_points += n
            if self._oldest_ts is None:
                self._oldest_ts = time.perf_counter()
            self._cond.notify_all()        # wake a flusher waiting for work
            return True

    def wait_for_work(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is non-empty (True) or ``timeout``
        elapses (False) — the flusher loop's idle sleep."""
        with self._cond:
            return self._cond.wait_for(lambda: bool(self._q), timeout)

    def requeue(self, entries) -> None:
        """Push (ticket, points, base_off) slices back to the FRONT of
        the queue, preserving their order — the server's recovery path
        when a flush dies mid-serve, so drained-but-unserved work is
        never lost (it simply serves on the next flush).  Requeued work
        is by definition the oldest in the queue: the deadline clock
        restarts at the requeue (the original arrival time left with
        ``drain``), so a crash-looping flush still re-arms the deadline
        rather than firing it on every retry.  Atomic under the batcher
        lock, so concurrent puts can neither interleave into the requeued
        run nor observe it half-inserted — FIFO order survives
        contention."""
        with self._cond:
            if entries and self._oldest_ts is None:
                self._oldest_ts = time.perf_counter()
            self._q.extendleft(reversed(entries))
            for ticket, _, _ in entries:   # re-arm per-ticket wait clocks
                _stamp(ticket)
            self.queued_points += sum(len(p) for _, p, _ in entries)
            if entries:
                self._cond.notify_all()

    def drain(self) -> list:
        """Coalesce every queued request, FIFO, into micro-batches of at
        most the top bucket.  Requests pack together until the top bucket
        is full; a request longer than the remaining room is split across
        batches (its parts record the request-side offsets).  Atomic: a
        put racing a drain lands either wholly in this drain's batches or
        wholly in the queue for the next one — never split between."""
        top = self.buckets[-1]
        batches: list[MicroBatch] = []
        chunks: list[np.ndarray] = []
        parts: list = []
        fill = 0

        def close():
            nonlocal chunks, parts, fill
            if fill:
                batches.append(
                    MicroBatch(np.concatenate(chunks, axis=0), parts))
            chunks, parts, fill = [], [], 0

        with self._cond:
            while self._q:
                ticket, pts, base = self._q.popleft()
                off = 0
                while off < len(pts):
                    take = min(len(pts) - off, top - fill)
                    if take == 0:
                        close()
                        continue
                    chunks.append(pts[off:off + take])
                    parts.append((ticket, base + off, fill, take))
                    fill += take
                    off += take
            close()
            self.queued_points = 0
            self._oldest_ts = None
            self._cond.notify_all()        # room freed: wake blocked puts
        return batches
