"""Strategy protocol + registry: the engine's pluggable dispatch surface
(DESIGN.md §11).

``GeoEngine`` used to hard-code its strategy choice in if/elif chains —
every new execution plan (a different PIP schedule, a sharded layout, a
learned router) meant editing engine code.  This module replaces that
with a registry: a strategy is an object implementing the ``Strategy``
protocol, registered under a name with declared *capability flags*, and
the engine resolves names through ``get_strategy`` only.  Third-party
strategies register with the decorator and are immediately buildable,
plannable, and servable::

    from repro.core.registry import Strategy, register_strategy

    @register_strategy("my-strategy", needs=("fast",),
                       needs_edge_pool=True)
    class MyStrategy(Strategy):
        def assign(self, indices, points, cfg):
            ...  # -> AssignResult, bottoming out in resolve_candidates

Capability flags answer the three questions the engine, the artifact
builder (core/artifact.py) and the planner (core/plan.py) ask *before*
any trace runs:

  * ``needs``            — which ``GeoIndexSet`` components the strategy
                           reads ("simple", "fast", "covering");
  * ``needs_edge_pool``  — whether ``cfg.fused`` requires blocked-CSR
                           edge pools on those components (strategies may
                           refine per-config via ``pool_components``);
  * ``supports_sharded`` — implements ``assign_sharded`` (mesh lookup);
  * ``supports_padded``  — safe under ``GeoEngine.assign_padded``'s FAR
                           padding convention (the serving layer requires
                           it).

``Strategy.validate`` turns those declarations into loud *build-time*
errors: a fused config meeting a pool-less index fails when the engine is
constructed, not on the first ``assign`` (which used to be a trace-time
surprise deep inside jit).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

COMPONENTS = ("simple", "fast", "covering")


@dataclasses.dataclass(frozen=True)
class StrategyCaps:
    """Declared capabilities of a registered strategy (see module doc)."""

    needs: Tuple[str, ...] = ()
    needs_edge_pool: bool = False
    supports_sharded: bool = False
    supports_padded: bool = True


class Strategy:
    """Base class for registered strategies.

    Subclasses implement ``assign`` (and ``assign_sharded`` when
    ``caps.supports_sharded``); everything else has capability-driven
    defaults.  ``name`` and ``caps`` are attached by
    ``register_strategy``.
    """

    name: str = "?"
    caps: StrategyCaps = StrategyCaps()

    # -- capability queries (engine / artifact / planner, pre-trace) -------

    def required_components(self, cfg) -> Tuple[str, ...]:
        """GeoIndexSet components this strategy reads under ``cfg``."""
        return self.caps.needs

    def pool_components(self, cfg) -> Tuple[str, ...]:
        """Components whose blocked-CSR edge pools ``cfg`` requires —
        empty unless the config routes candidate PIP through the fused
        gather-PIP kernel.  Default: every index component in ``needs``
        when ``cfg.fused`` and the strategy declares ``needs_edge_pool``;
        strategies with config-dependent pool use override this (e.g.
        fast-approx never PIPs)."""
        if not (self.caps.needs_edge_pool and getattr(cfg, "fused", False)):
            return ()
        return tuple(c for c in self.caps.needs if c != "covering")

    def validate(self, indices, cfg) -> None:
        """Raise ValueError if ``indices`` lacks a component or pool this
        strategy needs under ``cfg`` — called at engine construction so
        capability gaps surface at build/plan time, never at the first
        ``assign`` (DESIGN.md §11).  A strategy with no single-mesh
        ``assign`` at all (e.g. the sharded-only plugin) is rejected
        here too — an engine is an assign surface."""
        if type(self).assign is Strategy.assign:
            kind = ("sharded-only" if self.caps.supports_sharded
                    else "abstract")
            raise ValueError(
                f"strategy {self.name!r} implements no single-mesh "
                f"assign ({kind}) — build the engine with an "
                f"assign-capable strategy; engine.assign_sharded routes "
                f"to sharded plugins by itself")
        caps = indices.capabilities()
        for comp in self.required_components(cfg):
            if not caps.get(comp, False):
                raise ValueError(
                    f"strategy {self.name!r} needs a {comp}_index"
                    if comp != "covering" else
                    f"strategy {self.name!r} needs a cell covering "
                    f"(build the engine from a census)")
        for comp in self.pool_components(cfg):
            if not caps.get(f"{comp}_pool", False):
                raise ValueError(
                    f"strategy {self.name!r} with fused=True needs the "
                    f"{comp} index built with_pool(s)=True — rebuild via "
                    f"GeoIndexSet/GeoEngine.build, which size pools from "
                    f"the config, or drop fused")

    # -- execution ----------------------------------------------------------

    def assign(self, indices, points, cfg):
        """[N, 2] points -> AssignResult against ``indices``."""
        raise NotImplementedError(
            f"strategy {self.name!r} does not implement single-mesh "
            f"assign")

    def assign_sharded(self, indices, points, mesh, cfg):
        """Sharded lookup over ``mesh`` (only when supports_sharded)."""
        raise NotImplementedError(
            f"strategy {self.name!r} does not support sharded assign")


_REGISTRY: dict[str, Strategy] = {}


def register_strategy(name: str, *, needs: Tuple[str, ...] = (),
                      needs_edge_pool: bool = False,
                      supports_sharded: bool = False,
                      supports_padded: bool = True):
    """Class decorator: instantiate and register ``cls`` under ``name``
    with the declared capability flags.  Re-registering a name replaces
    the previous entry (last registration wins — deliberate, so tests and
    downstream packages can shadow built-ins)."""
    unknown = set(needs) - set(COMPONENTS)
    if unknown:
        raise ValueError(f"unknown index components {sorted(unknown)}; "
                         f"expected a subset of {COMPONENTS}")

    def deco(cls):
        inst = cls()
        inst.name = name
        inst.caps = StrategyCaps(needs=tuple(needs),
                                 needs_edge_pool=needs_edge_pool,
                                 supports_sharded=supports_sharded,
                                 supports_padded=supports_padded)
        _REGISTRY[name] = inst
        return cls

    return deco


def get_strategy(name: str) -> Strategy:
    """Resolve a registered strategy by name (ValueError on unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; expected one of "
                         f"{available_strategies()} (or 'auto')") from None


def available_strategies() -> Tuple[str, ...]:
    """Registered strategy names, registration order."""
    return tuple(_REGISTRY)


def sharded_strategies() -> Tuple[str, ...]:
    """Names of strategies that implement ``assign_sharded``."""
    return tuple(n for n, s in _REGISTRY.items()
                 if s.caps.supports_sharded)
