"""O(N) stable compaction (cumsum + scatter) replacing argsort.

``compact_indices(mask, cap)`` returns (idx [cap], valid [cap]): the first
``cap`` indices where mask is True, in order, plus a validity mask for
unfilled slots.  An argsort-based compaction is O(N log N) and measured as
the dominant cost of the exact fast path (§Perf geo iteration 4); prefix
sums make it O(N).

``capacity_for`` is the one place static buffer capacities are sized; every
strategy routes its ``cap_*`` config fractions through it so caps are
always lane-aligned and bounded by the batch (see core/resolve.py for the
consumer).
"""
from __future__ import annotations

import jax.numpy as jnp


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def capacity_for(n: int, frac: float, *, floor: int = 256,
                 quantum: int = 256, ceiling: int | None = None) -> int:
    """Static compaction capacity for a batch of ``n``: ``n * frac``,
    raised to ``floor``, rounded up to a ``quantum`` multiple (TPU lane
    alignment), and clamped to ``ceiling`` (default ``n``)."""
    cap = round_up(max(int(n * frac), floor), quantum)
    return min(cap, n if ceiling is None else ceiling)


def scatter_filled(prior: jnp.ndarray, idx: jnp.ndarray,
                   slot_ok: jnp.ndarray, values: jnp.ndarray):
    """Write ``values`` back through compacted slots, dropping unfilled
    ones.

    Unfilled slots from ``compact_indices`` all alias row 0 (zero-init),
    so an unmasked duplicate-index scatter lets a stale write race the
    real row-0 update (last write wins).  Rerouting unfilled slots to the
    out-of-bounds sentinel with mode="drop" keeps every surviving write
    unique.  This is the ONLY sanctioned write-back for compacted buffers.
    """
    n = prior.shape[0]
    return prior.at[jnp.where(slot_ok, idx, n)].set(values, mode="drop")


def compact_indices(mask: jnp.ndarray, cap: int):
    n = mask.shape[0]
    k = mask.astype(jnp.int32)
    pos = jnp.cumsum(k) - 1                       # slot among True entries
    dest = jnp.where(mask, pos, cap)              # False -> dropped sentinel
    idx = jnp.zeros((cap + 1,), jnp.int32).at[jnp.minimum(dest, cap)].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:cap]
    total = jnp.sum(k)
    valid = jnp.arange(cap, dtype=jnp.int32) < total
    return idx, valid
