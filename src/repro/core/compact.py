"""O(N) stable compaction (cumsum + scatter) replacing argsort.

``compact_indices(mask, cap)`` returns (idx [cap], valid [cap]): the first
``cap`` indices where mask is True, in order, plus a validity mask for
unfilled slots.  An argsort-based compaction is O(N log N) and measured as
the dominant cost of the exact fast path (§Perf geo iteration 4); prefix
sums make it O(N).
"""
from __future__ import annotations

import jax.numpy as jnp


def compact_indices(mask: jnp.ndarray, cap: int):
    n = mask.shape[0]
    k = mask.astype(jnp.int32)
    pos = jnp.cumsum(k) - 1                       # slot among True entries
    dest = jnp.where(mask, pos, cap)              # False -> dropped sentinel
    idx = jnp.zeros((cap + 1,), jnp.int32).at[jnp.minimum(dest, cap)].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:cap]
    total = jnp.sum(k)
    valid = jnp.arange(cap, dtype=jnp.int32) < total
    return idx, valid
