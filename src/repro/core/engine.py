"""GeoEngine: plan-and-execute facade over registered mapping strategies
(DESIGN.md §3, §11).

The engine composes three replaceable layers:

  * a **strategy registry** (core/registry.py + core/strategies.py):
    simple | fast | fast_onepass | hybrid | sharded ship as registered
    plugins over the
    shared resolution core, and third-party strategies register without
    touching engine code;
  * a **unified index artifact** (core/artifact.py): one ``GeoIndexSet``
    owns every index + edge pool a strategy can need, builds components
    lazily from declared capability flags, and persists to disk
    (versioned npz + manifest) so services cold-start without re-running
    the covering BFS;
  * an **auto-planner** (core/plan.py): ``build(census, strategy="auto")``
    inspects device kind, batch-size hints, index capabilities, and the
    measured boundary fraction to choose an explainable ``GeoPlan`` —
    ``engine.explain()`` says what was chosen and why.

Entry points:

  * ``engine.assign(points)``               — single-mesh lookup;
  * ``engine.assign_padded(points, n)``     — shape-stable serving batches;
  * ``engine.assign_sharded(points, mesh)`` — the cell table Morton-
    sharded over the mesh's "model" axis via the registered "sharded"
    plugin (points routed to their owning shard through the MoE dispatch
    primitive, distributed/dispatch.py).

Typical use::

    eng = GeoEngine.build(census, strategy="auto")
    eng.explain()                     # {"strategy": ..., "reasons": [...]}
    res = eng.assign(points)          # AssignResult
    res.block                         # [N] i32 block ids (-1 = off-map)

    eng.indices.save("artifacts/map")              # persist the artifact
    eng2 = GeoEngine.from_index_set(               # cold start
        GeoIndexSet.load("artifacts/map"), strategy="auto")

The legacy explicit form ``GeoEngine.build(census, strategy="fast",
cfg=EngineConfig(...))`` keeps working unchanged — it is now a thin
wrapper that pins the plan instead of asking the planner.

Everything in ``EngineConfig`` is static (part of the jit cache key);
``fused=True`` swaps the candidate PIP data path for the fused gather-PIP
Pallas kernel (kernels/gather_pip.py) in every strategy — results are
identical, only the memory traffic changes (DESIGN.md §9).
``fused="onepass"`` goes one further on the exact fast path: the whole
quantize -> cell lookup -> bbox filter -> PIP pipeline runs in ONE kernel
with double-buffered edge DMA (kernels/cascade.py, DESIGN.md §13); the
``"fast_onepass"`` strategy name pins the same plan.  Capability gaps (a
fused config over a pool-less index, a missing index) surface as
ValueError at *construction*, never at the first assign.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core import strategies as _strategies  # noqa: F401  (registers
#                                                  the built-in plugins)
from repro.core.artifact import GeoIndexSet
from repro.core import fast as fast_mod
from repro.core.geometry import CensusMap
from repro.core.registry import available_strategies, get_strategy
from repro.core.resolve import AssignResult
from repro.core.simple import SimpleConfig
from repro.core.fast import FastConfig
from repro.kernels import ops

# Names an explicit ``GeoEngine.build(strategy=...)`` accepts (the
# registry may hold more — anything registered works through the
# constructor; "auto" additionally asks the planner).
STRATEGIES = ("simple", "fast", "fast_onepass", "hybrid")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs (part of every jit cache key).

    The per-strategy configs (SimpleConfig / FastConfig) are derived from
    this one surface so callers tune a single object.
    """

    backend: str | None = None   # kernel backend override
    k_cand: int = 4              # cascade PIP candidates per level
    cap_state: float = 0.25      # cascade compaction fractions
    cap_county: float = 0.5
    cap_block: float = 0.5
    mode: str = "exact"          # fast boundary handling: exact | approx
    cap_boundary: float = 0.25   # fast/hybrid boundary compaction fraction
    max_level: int = 9           # covering depth (fast/hybrid)
    gbits: int = 4               # top-grid bits (fast/hybrid)
    max_cand: int = 8            # boundary candidate list width
    cap_shard: float = 2.0       # sharded assign: capacity factor vs N/S
    fused: bool | str = False    # False | True | "onepass".  True routes
    #                              candidate PIP through the fused
    #                              gather-PIP kernel (kernels/gather_pip.py)
    #                              in every strategy; results identical,
    #                              the gathered [R, E, 4] HBM buffer gone.
    #                              "onepass" additionally fuses the whole
    #                              exact fast path into the single-kernel
    #                              cascade (kernels/cascade.py) — other
    #                              strategies treat it as True.

    def simple_cfg(self) -> SimpleConfig:
        return SimpleConfig(k_cand=self.k_cand, cap_state=self.cap_state,
                            cap_county=self.cap_county,
                            cap_block=self.cap_block, backend=self.backend,
                            fused=bool(self.fused))

    def fast_cfg(self) -> FastConfig:
        return FastConfig(mode=self.mode, cap_boundary=self.cap_boundary,
                          backend=self.backend, fused=self.fused)

    def hybrid_cascade_cfg(self) -> SimpleConfig:
        # The cascade only sees the (already compacted) boundary buffer, so
        # run it at full capacity — the buffer IS the capacity limit.
        return SimpleConfig(k_cand=self.k_cand, cap_state=1.0,
                            cap_county=1.0, cap_block=1.0,
                            backend=self.backend, fused=bool(self.fused))


class GeoEngine:
    """Facade: plan once, build once, assign many (see module docstring)."""

    def __init__(self, strategy: str, cfg: Optional[EngineConfig] = None, *,
                 indices: Optional[GeoIndexSet] = None,
                 simple_index=None, fast_index=None,
                 covering=None, census: Optional[CensusMap] = None,
                 plan: Optional[plan_mod.GeoPlan] = None):
        """Wrap already-built indices.  ``indices`` is the unified
        artifact; the ``simple_index``/``fast_index``/``covering``/
        ``census`` keywords are the legacy spelling and are folded into
        one.  Capability validation (missing index, fused without pools)
        happens HERE — a misconfigured engine never constructs."""
        self.cfg = cfg or EngineConfig()
        self._impl = get_strategy(strategy)      # ValueError on unknown
        self.strategy = strategy
        if indices is None:
            indices = GeoIndexSet(census=census, covering=covering,
                                  simple=simple_index, fast=fast_index,
                                  max_level=self.cfg.max_level,
                                  gbits=self.cfg.gbits,
                                  max_cand=self.cfg.max_cand)
        self.indices = indices
        self._impl.validate(indices, self.cfg)
        self.plan = plan if plan is not None \
            else plan_mod.explicit_plan(strategy, self.cfg)
        # Optional observability hook (DESIGN.md §15): when set to a
        # callable ``f(stage, seconds, batch=b)``, every padded assign is
        # timed to completion (block_until_ready) and reported.  Off by
        # default — the hot path must not pay a device sync unasked.
        self.stage_timer = None

    @classmethod
    def build(cls, census: CensusMap, strategy: str = "simple",
              cfg: Optional[EngineConfig] = None,
              covering=None) -> "GeoEngine":
        """Build the indices ``strategy`` needs from a host-side census.

        ``strategy="auto"`` asks the planner (core/plan.py): the covering
        is built first (it is both an index component and the planner's
        boundary-fraction measurement), a ``GeoPlan`` is chosen, and the
        engine is built to that plan — ``explain()`` tells you what
        happened.  Any registered strategy name pins the plan instead.
        """
        cfg = cfg or EngineConfig()
        indices = GeoIndexSet(census=census, covering=covering,
                              max_level=cfg.max_level, gbits=cfg.gbits,
                              max_cand=cfg.max_cand)
        plan = None
        if strategy == "auto":
            indices.ensure("covering")
            plan = plan_mod.plan_for(cfg, covering=indices.covering,
                                     tuning=indices.tuning)
            cfg = plan.apply(cfg)
            strategy = plan.strategy
        impl = get_strategy(strategy)
        for comp in impl.required_components(cfg):
            indices.ensure(comp)
        for comp in impl.pool_components(cfg):
            indices.ensure(comp, pool=True)
        return cls(strategy, cfg, indices=indices, plan=plan)

    @classmethod
    def from_index_set(cls, indices: GeoIndexSet, strategy: str = "auto",
                       cfg: Optional[EngineConfig] = None) -> "GeoEngine":
        """Build over an existing artifact (typically ``GeoIndexSet.load``
        — the serving cold-start path).  The artifact's build parameters
        (max_level / gbits / max_cand) override the config's so device
        components rebuild exactly as saved; ``strategy="auto"`` plans
        against the artifact's capabilities."""
        cfg = dataclasses.replace(cfg or EngineConfig(),
                                  max_level=indices.max_level,
                                  gbits=indices.gbits,
                                  max_cand=indices.max_cand)
        plan = None
        if strategy == "auto":
            if indices.census is not None:
                indices.ensure("covering")
            plan = plan_mod.plan_for(cfg, covering=indices.covering,
                                     capabilities=indices.capabilities(),
                                     tuning=indices.tuning)
            cfg = plan.apply(cfg)
            strategy = plan.strategy
        impl = get_strategy(strategy)
        if indices.census is not None:
            for comp in impl.required_components(cfg):
                indices.ensure(comp)
            for comp in impl.pool_components(cfg):
                indices.ensure(comp, pool=True)
        return cls(strategy, cfg, indices=indices, plan=plan)

    # -- index views (legacy attribute spelling) ----------------------------

    @property
    def simple_index(self):
        return self.indices.simple

    @property
    def fast_index(self):
        return self.indices.fast

    @property
    def covering(self):
        return self.indices.covering

    @property
    def census(self):
        return self.indices.census

    # -- planning introspection ---------------------------------------------

    def explain(self, n_points: Optional[int] = None) -> dict:
        """The engine's plan as a JSON-ready dict.  With no argument:
        the plan this engine was built under (the planner's choice for
        ``"auto"`` builds, the pinned explicit plan otherwise).  With a
        batch-size hint: what the planner would choose for that batch
        against this engine's *built* capabilities — e.g. whether a
        sharded route or a different strategy would win — without
        touching the engine."""
        if n_points is None:
            return self.plan.as_dict()
        return plan_mod.plan_for(
            self.cfg, covering=self.indices.covering,
            capabilities=self.indices.capabilities(),
            n_points=n_points, tuning=self.indices.tuning).as_dict()

    # -- single-mesh assign ------------------------------------------------

    def assign(self, points: jnp.ndarray) -> AssignResult:
        """Map [N, 2] (lon, lat) points -> AssignResult.

        The result's ``.state/.county/.block`` are [N] i32 ids (-1 = not
        on the map: outside the extent, in no state bbox, or dropped by a
        capacity overflow).  ``.stats`` is a GeoStats whose three core
        counters are comparable across strategies; the strategy's native
        breakdown (per-level dicts for simple, ``n_boundary``/
        ``phase2_miss`` for fast/hybrid) rides in ``stats.extra``.
        """
        return self._impl.assign(self.indices, points, self.cfg)

    def assign_padded(self, points: jnp.ndarray,
                      n_valid) -> AssignResult:
        """Shape-stable assign over a padded batch: rows >= ``n_valid``
        are padding and must not perturb results or stats.

        The serving layer pads every micro-batch up to a small ladder of
        bucket sizes so each strategy JIT-compiles once per bucket instead
        of once per request shape (DESIGN.md §10).  Pad rows are rewritten
        to ``ops.FAR`` before dispatch — a FAR point is outside every
        extent, bbox, and polygon by the padding convention (DESIGN.md §9),
        so it resolves to -1 without entering any ``need`` mask, candidate
        compaction, or PIP call: the returned ``GeoStats`` counters are
        identical to an unpadded ``assign`` over ``points[:n_valid]``
        (capacities permitting — caps are sized from the padded batch, so
        a padded call can only see *less* overflow, never more).  Pad rows
        come back -1 in all three id arrays.
        """
        if not self._impl.caps.supports_padded:
            raise ValueError(f"strategy {self.strategy!r} does not "
                             f"support padded batches")
        b = points.shape[0]
        timer = self.stage_timer
        t0 = time.perf_counter() if timer is not None else 0.0
        valid = jnp.arange(b, dtype=jnp.int32) < n_valid
        masked = jnp.where(valid[:, None], points.astype(jnp.float32),
                           jnp.float32(ops.FAR))
        res = self.assign(masked)
        neg = jnp.int32(-1)
        out = AssignResult(jnp.where(valid, res.state, neg),
                           jnp.where(valid, res.county, neg),
                           jnp.where(valid, res.block, neg), res.stats)
        if timer is not None:
            # Sync so the reported interval covers the device work, not
            # just the async dispatch — this is the engine-side truth the
            # serving layer's host-observed device_assign brackets.
            jax.block_until_ready(out.block)
            timer("assign_padded", time.perf_counter() - t0, batch=b)
        return out

    # -- index / extent handles (serving layer) ----------------------------

    def extent_quant(self) -> tuple[np.ndarray, int]:
        """(quant [4] f32 = (x0, y0, sx, sy), max_level) — the quantization
        handle serving-layer routers and caches key on.  Taken from the
        fast index when one exists (bit-identical to the device lookup);
        derived from the census extent otherwise, with the same formula
        ``FastIndex.from_covering`` uses."""
        if self.fast_index is not None:
            return (np.asarray(self.fast_index.quant),
                    self.fast_index.max_level)
        if self.census is None:
            raise ValueError("extent_quant needs a fast index or a census "
                             "(engine built via GeoEngine.build)")
        return (fast_mod.quant_for_extent(self.census.extent,
                                          self.cfg.max_level),
                self.cfg.max_level)

    def extent_contains(self, points) -> np.ndarray:
        """[N] bool (host) — True where the point lies inside this
        engine's map extent; the serving router's ownership test.  Pure
        numpy (``fast.np_extent_mask``, the bit-exact host mirror of the
        ``extent_mask`` every strategy applies internally) — it runs per
        micro-batch on the serving hot path, so no device round trip."""
        quant, max_level = self.extent_quant()
        return fast_mod.np_extent_mask(quant, max_level, points)

    def host_parents(self) -> tuple[np.ndarray, np.ndarray]:
        """(block_parent [Nb], county_parent [Nc]) as host arrays, so the
        serving cache can derive county/state ids without a device trip —
        the same tables ``parents_of`` gathers on device."""
        index = self.fast_index if self.fast_index is not None \
            else self.simple_index
        return (np.asarray(index.block_parent),
                np.asarray(index.county_parent))

    # -- sharded assign ----------------------------------------------------

    def assign_sharded(self, points: jnp.ndarray, mesh) -> AssignResult:
        """Sharded lookup over ``mesh``'s "model" axis, routed through the
        registered "sharded" strategy plugin (or the engine's own
        strategy, if it declares ``supports_sharded``) — see
        core/strategies.py for capacity and drop accounting."""
        impl = self._impl if self._impl.caps.supports_sharded \
            else get_strategy("sharded")
        return impl.assign_sharded(self.indices, points, mesh, self.cfg)


__all__ = ["EngineConfig", "GeoEngine", "GeoIndexSet", "STRATEGIES",
           "available_strategies"]
