"""GeoEngine: one facade over every mapping strategy (DESIGN.md §3).

``GeoEngine.build(census, strategy=..., cfg=...)`` constructs whatever
indices the strategy needs and exposes two entry points:

  * ``engine.assign(points)``            — single-mesh lookup;
  * ``engine.assign_sharded(points, mesh)`` — the cell table Morton-sharded
    over the mesh's "model" axis, with points *routed to their owning
    shard* through the capacity-bucketed dispatch primitive shared with the
    MoE layer (distributed/dispatch.py) — each shard then resolves only the
    points it owns instead of scanning the full batch.

Strategies:

  * ``simple`` — the paper's §III hierarchical bbox cascade.
  * ``fast``   — the paper's §IV true-hit-filter cell index
                 (cfg.mode picks exact / approx boundary handling).
  * ``hybrid`` — NEW: fast cell lookup for interior "true hits" (zero PIP
    tests, identical to fast), but boundary/overflow points are routed
    through the simple cascade's hierarchical PIP instead of the flat
    candidate-list fallback; only points the cascade cannot place (bbox
    grazing, capacity overflow) degrade to the centre-owner candidate.
    Strictly better accuracy than ``fast(approx)`` at a fraction of
    ``fast(exact)``'s candidate-PIP volume when boundary traffic is heavy.

All strategies bottom out in core/resolve.py — the engine adds no PIP or
compaction logic of its own, it only composes the drivers.

Typical use::

    eng = GeoEngine.build(census, strategy="fast",
                          cfg=EngineConfig(mode="exact", fused=True))
    res = eng.assign(points)          # AssignResult
    res.block                         # [N] i32 block ids (-1 = off-map)
    res.stats.n_pip                   # candidate PIP tests issued

Everything in ``EngineConfig`` is static (part of the jit cache key);
``fused=True`` swaps the candidate PIP data path for the fused gather-PIP
Pallas kernel (kernels/gather_pip.py) in every strategy — results are
identical, only the memory traffic changes (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fast as fast_mod
from repro.core import simple as simple_mod
from repro.core.cells import build_cell_covering
from repro.core.compact import (capacity_for, compact_indices,
                                scatter_filled)
from repro.core.distributed import (ShardedFastIndex, local_lookup,
                                    shard_covering)
from repro.core.fast import (FastConfig, FastIndex, cell_values, parents_of,
                             quantize_codes)
from repro.core.geometry import CensusMap
from repro.core.resolve import AssignResult, GeoStats
from repro.core.simple import SimpleConfig, SimpleIndex
from repro.distributed.dispatch import (plan_routes, scatter_to_buckets,
                                        slot_tables)
from repro.kernels import ops
from repro.launch.mesh import shard_map

STRATEGIES = ("simple", "fast", "hybrid")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs (part of every jit cache key).

    The per-strategy configs (SimpleConfig / FastConfig) are derived from
    this one surface so callers tune a single object.
    """

    backend: str | None = None   # kernel backend override
    k_cand: int = 4              # cascade PIP candidates per level
    cap_state: float = 0.25      # cascade compaction fractions
    cap_county: float = 0.5
    cap_block: float = 0.5
    mode: str = "exact"          # fast boundary handling: exact | approx
    cap_boundary: float = 0.25   # fast/hybrid boundary compaction fraction
    max_level: int = 9           # covering depth (fast/hybrid)
    gbits: int = 4               # top-grid bits (fast/hybrid)
    max_cand: int = 8            # boundary candidate list width
    cap_shard: float = 2.0       # sharded assign: capacity factor vs N/S
    fused: bool = False          # route candidate PIP through the fused
    #                              gather-PIP kernel (kernels/gather_pip.py)
    #                              in every strategy; results identical,
    #                              the gathered [R, E, 4] HBM buffer gone

    def simple_cfg(self) -> SimpleConfig:
        return SimpleConfig(k_cand=self.k_cand, cap_state=self.cap_state,
                            cap_county=self.cap_county,
                            cap_block=self.cap_block, backend=self.backend,
                            fused=self.fused)

    def fast_cfg(self) -> FastConfig:
        return FastConfig(mode=self.mode, cap_boundary=self.cap_boundary,
                          backend=self.backend, fused=self.fused)

    def hybrid_cascade_cfg(self) -> SimpleConfig:
        # The cascade only sees the (already compacted) boundary buffer, so
        # run it at full capacity — the buffer IS the capacity limit.
        return SimpleConfig(k_cand=self.k_cand, cap_state=1.0,
                            cap_county=1.0, cap_block=1.0,
                            backend=self.backend, fused=self.fused)


@functools.partial(jax.jit, static_argnames=("scfg", "cap_frac"))
def _assign_hybrid(findex: FastIndex, sindex: SimpleIndex,
                   points: jnp.ndarray, scfg: SimpleConfig,
                   cap_frac: float):
    """Hybrid strategy: interior true hits from the cell index; boundary
    points re-resolved through the hierarchical cascade."""
    n = points.shape[0]
    val = cell_values(findex, points)
    bid = jnp.where(val >= 0, val, -1)
    need = (val < 0) & (val > fast_mod.OUTSIDE)      # boundary cells
    n_boundary = jnp.sum(need.astype(jnp.int32))

    cap = capacity_for(n, cap_frac)
    idx, slot_ok = compact_indices(need, cap)
    sub_need = need[idx] & slot_ok
    # Unfilled compaction slots alias row 0; feed the cascade FAR points
    # there (and on non-boundary rows) so its stats count only real
    # boundary work — otherwise n_pip would scale with the capacity, and
    # a padded batch (assign_padded) would report different stats than
    # the unpadded call.  Result-identical: only sub_need rows' cascade
    # output is kept below.
    sub_pts = jnp.where(sub_need[:, None], points[idx],
                        jnp.float32(ops.FAR))
    _, _, sub_bid, sub_stats = simple_mod.cascade_assign(
        sindex, sub_pts, scfg)
    bid = scatter_filled(bid, idx, slot_ok,
                         jnp.where(sub_need & (sub_bid >= 0),
                                   sub_bid, bid[idx]))
    overflow = n_boundary - jnp.sum(sub_need.astype(jnp.int32))
    if findex.cand.shape[0] > 0:
        # Cascade misses + capacity overflow degrade to the centre-owner
        # candidate (the fast-approx answer) rather than staying lost.
        brow = jnp.clip(-(val + 1), 0, findex.cand.shape[0] - 1)
        bid = jnp.where(need & (bid < 0), findex.cand[brow, 0], bid)

    cid, sid = parents_of(findex, bid)
    n_pip = sum(lvl["n_pip"] for lvl in sub_stats.values())
    stats = {"n_boundary": n_boundary, "n_pip": n_pip,
             "overflow": overflow, "cascade": sub_stats}
    return sid, cid, bid, stats


def _sharded_assign(sidx: ShardedFastIndex, points: jnp.ndarray, mesh,
                    cfg: FastConfig, capacity: int, cap_pip: int):
    """Dispatch-routed sharded lookup: bucket points by owning Morton
    shard, scatter into per-shard capacity buffers, look up shard-locally
    under shard_map, gather results back by buffer slot."""
    n = points.shape[0]
    s = sidx.n_shards
    codes = quantize_codes(sidx.quant, sidx.max_level, points)
    owner = jnp.clip(
        jnp.searchsorted(sidx.range_lo, codes, side="right") - 1, 0, s - 1
    ).astype(jnp.int32)
    plan = plan_routes(owner, s, capacity)
    item_for_slot, _ = slot_tables(plan, s, capacity)        # [S*cap]
    ok = item_for_slot >= 0
    # Off-extent points carry border-clipped codes (see quantize_codes);
    # deactivate their slots so they come back -1, not a border block.
    ext = fast_mod.extent_mask(sidx.quant, sidx.max_level, points)
    slot_ext = ok & ext[jnp.clip(item_for_slot, 0, n - 1)]
    buf_pts = scatter_to_buckets(plan, points, s, capacity,
                                 item_for_slot=item_for_slot
                                 ).reshape(s, capacity, 2)
    buf_ok = slot_ext.reshape(s, capacity)
    pool = sidx.edge_pool if cfg.fused else None

    def body(pts_loc, ok_loc, lo, hi, val, cand):
        pts_loc, ok_loc = pts_loc[0], ok_loc[0]
        lo, hi, val, cand = lo[0], hi[0], val[0], cand[0]
        codes_loc = quantize_codes(sidx.quant, sidx.max_level, pts_loc)
        bid, rs = local_lookup(
            sidx.block_edges, lo, hi, val, cand, codes_loc, pts_loc,
            cfg.mode, cap_pip, cfg.backend, active=ok_loc,
            edge_pool=pool)
        return (bid[None], jax.lax.psum(rs.n_need, "model"),
                jax.lax.psum(rs.n_pip, "model"),
                jax.lax.psum(rs.overflow, "model"),
                jax.lax.psum(rs.phase2_miss, "model"))

    ps = jax.sharding.PartitionSpec
    bid_buf, n_need, n_pip, pip_of, p2_miss = shard_map(
        body, mesh=mesh,
        in_specs=(ps("model"), ps("model"), ps("model"), ps("model"),
                  ps("model"), ps("model")),
        out_specs=(ps("model"), ps(), ps(), ps(), ps()),
    )(buf_pts, buf_ok, sidx.cell_lo, sidx.cell_hi, sidx.cell_val,
      sidx.cand)

    dest = jnp.where(ok, item_for_slot, n)
    bid = jnp.full((n + 1,), -1, jnp.int32).at[dest].set(
        bid_buf.reshape(-1), mode="drop")[:n]
    cid, sid = parents_of(sidx, bid)
    stats = {"n_boundary": n_need, "n_pip": n_pip, "overflow": pip_of,
             "phase2_miss": p2_miss, "n_dropped": plan.n_dropped}
    return sid, cid, bid, stats


class GeoEngine:
    """Facade: build once, assign many (see module docstring)."""

    def __init__(self, strategy: str, cfg: Optional[EngineConfig] = None, *,
                 simple_index: Optional[SimpleIndex] = None,
                 fast_index: Optional[FastIndex] = None,
                 covering=None, census: Optional[CensusMap] = None):
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; "
                             f"expected one of {STRATEGIES}")
        self.strategy = strategy
        self.cfg = cfg or EngineConfig()
        self.simple_index = simple_index
        self.fast_index = fast_index
        self.covering = covering
        self.census = census
        self._sharded: dict[int, ShardedFastIndex] = {}
        if strategy in ("simple", "hybrid") and simple_index is None:
            raise ValueError(f"strategy {strategy!r} needs a simple_index")
        if strategy in ("fast", "hybrid") and fast_index is None:
            raise ValueError(f"strategy {strategy!r} needs a fast_index")

    @classmethod
    def build(cls, census: CensusMap, strategy: str = "simple",
              cfg: Optional[EngineConfig] = None,
              covering=None) -> "GeoEngine":
        """Build the indices ``strategy`` needs from a host-side census."""
        cfg = cfg or EngineConfig()
        simple_index = fast_index = None
        if strategy in ("simple", "hybrid"):
            simple_index = SimpleIndex.from_census(census,
                                                   with_pools=cfg.fused)
        if strategy in ("fast", "hybrid"):
            if covering is None:
                covering = build_cell_covering(census,
                                               max_level=cfg.max_level,
                                               max_cand=cfg.max_cand)
            # Only fast-exact runs candidate PIP on the fast index (hybrid
            # resolves boundaries through the cascade, approx never PIPs),
            # so only it needs the pool; assign_sharded builds its own.
            fast_index = FastIndex.from_covering(
                covering, census, gbits=cfg.gbits,
                with_pool=(cfg.fused and strategy == "fast"
                           and cfg.mode == "exact"))
        return cls(strategy, cfg, simple_index=simple_index,
                   fast_index=fast_index, covering=covering, census=census)

    # -- single-mesh assign ------------------------------------------------

    def assign(self, points: jnp.ndarray) -> AssignResult:
        """Map [N, 2] (lon, lat) points -> AssignResult.

        The result's ``.state/.county/.block`` are [N] i32 ids (-1 = not
        on the map: outside the extent, in no state bbox, or dropped by a
        capacity overflow).  ``.stats`` is a GeoStats whose three core
        counters are comparable across strategies; the strategy's native
        breakdown (per-level dicts for simple, ``n_boundary``/
        ``phase2_miss`` for fast/hybrid) rides in ``stats.extra``.
        """
        if self.strategy == "simple":
            sid, cid, bid, st = simple_mod.assign_simple(
                self.simple_index, points, self.cfg.simple_cfg())
            levels = ("state", "county", "block")
            return AssignResult(sid, cid, bid, GeoStats(
                n_need=sum(st[l]["n_multi"] for l in levels),
                n_pip=sum(st[l]["n_pip"] for l in levels),
                overflow=sum(st[l]["overflow"] for l in levels),
                extra=st))
        if self.strategy == "fast":
            sid, cid, bid, st = fast_mod.assign_fast(
                self.fast_index, points, self.cfg.fast_cfg())
        else:
            sid, cid, bid, st = _assign_hybrid(
                self.fast_index, self.simple_index, points,
                self.cfg.hybrid_cascade_cfg(), self.cfg.cap_boundary)
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=st["n_boundary"], n_pip=st["n_pip"],
            overflow=st["overflow"], extra=st))

    def assign_padded(self, points: jnp.ndarray,
                      n_valid) -> AssignResult:
        """Shape-stable assign over a padded batch: rows >= ``n_valid``
        are padding and must not perturb results or stats.

        The serving layer pads every micro-batch up to a small ladder of
        bucket sizes so each strategy JIT-compiles once per bucket instead
        of once per request shape (DESIGN.md §10).  Pad rows are rewritten
        to ``ops.FAR`` before dispatch — a FAR point is outside every
        extent, bbox, and polygon by the padding convention (DESIGN.md §9),
        so it resolves to -1 without entering any ``need`` mask, candidate
        compaction, or PIP call: the returned ``GeoStats`` counters are
        identical to an unpadded ``assign`` over ``points[:n_valid]``
        (capacities permitting — caps are sized from the padded batch, so
        a padded call can only see *less* overflow, never more).  Pad rows
        come back -1 in all three id arrays.
        """
        b = points.shape[0]
        valid = jnp.arange(b, dtype=jnp.int32) < n_valid
        masked = jnp.where(valid[:, None], points.astype(jnp.float32),
                           jnp.float32(ops.FAR))
        res = self.assign(masked)
        neg = jnp.int32(-1)
        return AssignResult(jnp.where(valid, res.state, neg),
                            jnp.where(valid, res.county, neg),
                            jnp.where(valid, res.block, neg), res.stats)

    # -- index / extent handles (serving layer) ----------------------------

    def extent_quant(self) -> tuple[np.ndarray, int]:
        """(quant [4] f32 = (x0, y0, sx, sy), max_level) — the quantization
        handle serving-layer routers and caches key on.  Taken from the
        fast index when one exists (bit-identical to the device lookup);
        derived from the census extent otherwise, with the same formula
        ``FastIndex.from_covering`` uses."""
        if self.fast_index is not None:
            return (np.asarray(self.fast_index.quant),
                    self.fast_index.max_level)
        if self.census is None:
            raise ValueError("extent_quant needs a fast index or a census "
                             "(engine built via GeoEngine.build)")
        return (fast_mod.quant_for_extent(self.census.extent,
                                          self.cfg.max_level),
                self.cfg.max_level)

    def extent_contains(self, points) -> np.ndarray:
        """[N] bool (host) — True where the point lies inside this
        engine's map extent; the serving router's ownership test.  Pure
        numpy (``fast.np_extent_mask``, the bit-exact host mirror of the
        ``extent_mask`` every strategy applies internally) — it runs per
        micro-batch on the serving hot path, so no device round trip."""
        quant, max_level = self.extent_quant()
        return fast_mod.np_extent_mask(quant, max_level, points)

    def host_parents(self) -> tuple[np.ndarray, np.ndarray]:
        """(block_parent [Nb], county_parent [Nc]) as host arrays, so the
        serving cache can derive county/state ids without a device trip —
        the same tables ``parents_of`` gathers on device."""
        index = self.fast_index if self.fast_index is not None \
            else self.simple_index
        return (np.asarray(index.block_parent),
                np.asarray(index.county_parent))

    # -- sharded assign ----------------------------------------------------

    def _sharded_index(self, n_shards: int) -> ShardedFastIndex:
        if n_shards not in self._sharded:
            if self.covering is None or self.census is None:
                raise ValueError("assign_sharded needs the engine built "
                                 "from a census with a cell covering "
                                 "(strategy 'fast' or 'hybrid')")
            self._sharded[n_shards] = shard_covering(
                self.covering, self.census, n_shards,
                with_pool=(self.cfg.fused and self.cfg.mode == "exact"))
        return self._sharded[n_shards]

    def assign_sharded(self, points: jnp.ndarray, mesh) -> AssignResult:
        """Sharded lookup over ``mesh``'s "model" axis (see module doc).

        Capacity per shard is ``cap_shard * N / n_shards`` — routing skew
        beyond that is dropped to bid -1 and counted in stats
        (extra["n_dropped"]), mirroring MoE token dropping.
        """
        if "model" not in mesh.axis_names:
            raise ValueError("assign_sharded expects a mesh with a "
                             "'model' axis")
        n = points.shape[0]
        n_shards = int(mesh.shape["model"])
        sidx = self._sharded_index(n_shards)
        capacity = capacity_for(n, self.cfg.cap_shard / n_shards)
        cap_pip = capacity_for(capacity, self.cfg.cap_boundary,
                               ceiling=capacity)
        sid, cid, bid, st = _sharded_assign(
            sidx, points, mesh, self.cfg.fast_cfg(), capacity, cap_pip)
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=st["n_boundary"], n_pip=st["n_pip"],
            overflow=st["overflow"] + st["n_dropped"], extra=st))
