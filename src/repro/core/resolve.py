"""Shared resolution core for every mapping strategy (DESIGN.md §3).

Each strategy in this repo — the simple cascade (paper §III), the fast
cell index (paper §IV), the engine's hybrid mode, and the Morton-sharded
distributed lookup — bottoms out in the same compute pattern:

    candidate filter -> fixed-capacity compaction -> crossing-number PIP
    against <= K candidate polygons -> fallback policy -> overflow-counted
    stats.

``resolve_candidates`` implements that pattern exactly once.  Strategy
modules stay thin drivers: they decide *which* points need resolution and
*which* candidates each point brings, then hand both to this primitive.

Two PIP schedules are provided (they return identical assignments — the
first matching candidate in slot order — and differ only in kernel-call
shape):

  * sequential  — K kernel calls over the full compacted buffer; right when
    K is small and the buffer large (the cascade levels).
  * two_phase   — slot 0 (the centre-owner / best candidate) for the whole
    buffer, then one batched call over the remaining K-1 candidates for the
    ~10 % of slot-0 misses (§Perf geo iterations 2-3).  Right when slot 0
    resolves most points (the boundary-cell fallback).

Backend strings are resolved here, once, via ``ops.resolve_backend`` —
callers pass the raw ``cfg.backend`` through and never touch kernel
dispatch themselves.

Candidate PIP has two data paths (identical results):

  * legacy  — gather ``edges_table[pid]`` into an [R, E, 4] HBM buffer,
    then the gathered crossing kernel (``ops.pip_gathered``);
  * fused   — pass ``edge_pool=`` (a blocked-CSR ``ops.EdgePool``) and the
    candidate ids go straight into the fused gather-PIP kernel
    (``ops.pip_candidates``): edge slices are prefetched HBM -> VMEM
    inside the kernel's grid loop and the [R, E, 4] gather is never
    materialized.  Strategies enable it with their ``fused`` config flag.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

from repro.core.compact import capacity_for, compact_indices, scatter_filled
from repro.kernels import ops

# Candidate table for N points: either a precomputed [N, K] id array or a
# callable evaluated *after* compaction — (idx [R], sub_pts [R, 2]) ->
# [R, K] — so strategies can defer expensive candidate gathering to the
# (much smaller) compacted buffer.
CandidateFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
Candidates = Union[jnp.ndarray, CandidateFn]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ResolveStats:
    """Per-resolve accounting (device scalars, all i32).

    n_need:      points that required candidate resolution.
    n_pip:       candidate PIP tests actually issued.
    overflow:    points dropped by the fixed-capacity compaction — counted,
                 never silent (callers re-run stragglers or size caps up).
    phase2_miss: two-phase schedule only — slot-0 misses that did not get
                 a phase-2 compaction slot and therefore degraded straight
                 to the fallback policy without testing slots 1..K-1.
                 Distinct from ``overflow``: these points still produce an
                 answer (the fallback), but a *less exact* one; a non-zero
                 value says ``cap2`` is undersized for the workload.
                 Always 0 for the sequential schedule.
    """

    n_need: Any
    n_pip: Any
    overflow: Any
    phase2_miss: Any

    def tree_flatten(self):
        return (self.n_need, self.n_pip, self.overflow,
                self.phase2_miss), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def as_dict(self) -> dict:
        return {"n_need": self.n_need, "n_pip": self.n_pip,
                "overflow": self.overflow, "phase2_miss": self.phase2_miss}

    def merge(self, other: "ResolveStats") -> "ResolveStats":
        """Counter-wise sum — aggregates resolves across micro-batches."""
        return ResolveStats(
            n_need=self.n_need + other.n_need,
            n_pip=self.n_pip + other.n_pip,
            overflow=self.overflow + other.overflow,
            phase2_miss=self.phase2_miss + other.phase2_miss)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GeoStats:
    """Unified cross-strategy stats (device scalars unless noted).

    n_need:   points that needed candidate resolution — bbox-ambiguous
              points for the cascade, boundary-cell hits for the cell
              index.  The paper's headline ratios (true-hit rate, PIP
              fraction) read straight off this.
    n_pip:    candidate PIP tests issued (0 for fast-approx).
    overflow: points whose resolution was dropped by a fixed-capacity
              compaction (plus routing drops for assign_sharded); they
              keep their best-effort id, and a non-zero value means the
              ``cap_*`` config fractions are undersized for the workload.
    extra:    the strategy's native breakdown — per-level dicts for the
              cascade, ``n_boundary``/``phase2_miss``/``cascade`` for the
              cell-index flavours, ``n_dropped`` for sharded routing.
    """

    n_need: Any
    n_pip: Any
    overflow: Any
    extra: Any = dataclasses.field(default_factory=dict)

    def tree_flatten(self):
        return (self.n_need, self.n_pip, self.overflow, self.extra), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def merge(self, other: "GeoStats") -> "GeoStats":
        """Counter-wise sum across micro-batches (serving aggregation).

        ``extra`` is summed leaf-wise, so both stats must come from the
        same strategy + config (identical extra tree structure) — the
        serving layer accumulates one running GeoStats per engine.
        """
        return GeoStats(
            n_need=self.n_need + other.n_need,
            n_pip=self.n_pip + other.n_pip,
            overflow=self.overflow + other.overflow,
            extra=jax.tree_util.tree_map(lambda a, b: a + b,
                                         self.extra, other.extra))

    def as_dict(self) -> dict:
        """Flat JSON-ready counters (python ints) for bench rows and
        serving metrics.  ``phase2_miss`` is summed over however the
        strategy nests it (top-level for fast, per-level for the cascade,
        under ``cascade`` for hybrid); ``n_boundary`` falls back to
        ``n_need`` for strategies without a cell index."""
        d = {"n_need": int(self.n_need), "n_pip": int(self.n_pip),
             "overflow": int(self.overflow),
             "phase2_miss": _sum_nested(self.extra, "phase2_miss")}
        if isinstance(self.extra, dict):
            d["n_boundary"] = int(self.extra.get("n_boundary", self.n_need))
            if "n_dropped" in self.extra:
                d["n_dropped"] = int(self.extra["n_dropped"])
        else:
            d["n_boundary"] = d["n_need"]
        return d


def _sum_nested(tree, key: str) -> int:
    """Sum every scalar leaf named ``key`` anywhere in a nested dict."""
    total = 0
    if isinstance(tree, dict):
        for k, v in tree.items():
            if isinstance(v, dict):
                total += _sum_nested(v, key)
            elif k == key:
                total += int(v)
    return total


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AssignResult:
    """(state, county, block) ids plus GeoStats; iterable for tuple-style
    unpacking parity with the legacy ``assign_*`` returns."""

    state: Any
    county: Any
    block: Any
    stats: Any

    def __iter__(self):
        return iter((self.state, self.county, self.block, self.stats))

    def tree_flatten(self):
        return (self.state, self.county, self.block, self.stats), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def onepass_stats(flags: jnp.ndarray, nrest: jnp.ndarray,
                  nskip: jnp.ndarray) -> dict:
    """Stats dict for the one-pass fused cascade (ops.assign_cascade),
    reproducing ``_pip_two_phase``'s accounting from the kernel's
    per-point outputs so ``fast_onepass`` is counter-identical to
    ``fast_exact`` whenever the two-phase caps are not overflowing:

      * n_pip = every boundary point pays its slot-0 test, and each
        slot-0 *miss* additionally counts all its valid slot-1..K-1
        candidates — exactly the phase-2 ``real2 & (rest >= 0)`` sum;
      * overflow / phase2_miss are structurally zero: the kernel walks
        candidates per point with no compaction buffer to overflow (the
        one-pass path is the *more* exact answer when the two-phase caps
        are undersized — the counters make that visible rather than
        papering over it);
      * bbox_skips rides in the strategy's native breakdown only (extra
        dict): candidate slots whose bbox rejected the point before any
        edge DMA — the filter stage's measured win.
    """
    boundary = (flags & 1) == 1
    slot0_hit = (flags & 2) == 2
    n_boundary = jnp.sum(boundary.astype(jnp.int32))
    n_pip = n_boundary + jnp.sum(
        jnp.where(boundary & ~slot0_hit, nrest, 0))
    return {"n_boundary": n_boundary, "n_pip": n_pip,
            "overflow": jnp.zeros((), jnp.int32),
            "phase2_miss": jnp.zeros((), jnp.int32),
            "bbox_skips": jnp.sum(jnp.where(boundary, nskip, 0))}


def first_k_candidates(mask: jnp.ndarray, k: int) -> jnp.ndarray:
    """Slots of the first min(k, C) set bits per row of a [R, C] mask
    (else -1); k is clamped so narrow candidate tables (tiny maps) work."""
    c = mask.shape[1]
    k = min(k, c)
    iota = jnp.arange(c, dtype=jnp.int32)[None, :]
    score = jnp.where(mask != 0, c - iota, 0)       # larger = earlier slot
    vals, _ = jax.lax.top_k(score, k)
    return jnp.where(vals > 0, c - vals, -1)        # [R, k] slot indices


def _pip_ids(points, pid, edges_table, edge_pool, backend):
    """Inside mask of each point vs its own candidate id (pid < 0 = never
    inside).  Fused CSR path when an edge pool is provided; the legacy
    gather-then-kernel flow otherwise.

    The fused call is made in candidate-id-sorted order: the gather-PIP
    kernel skips the HBM->VMEM block DMA when consecutive grid rows map
    to the same pool block, so sorting amortizes edge traffic to near
    zero on repeated candidates (ROADMAP PR 2 item).  The permutation is
    local to this function — rows are inverse-permuted before returning,
    and each row's crossing count depends only on its own (point, id) —
    so every caller sees results bit-identical to the unsorted order,
    including the two-phase schedule's inner compaction.
    """
    if edge_pool is not None:
        order = jnp.argsort(
            jnp.where(pid >= 0, pid, jnp.int32(2**31 - 1)), stable=True)
        inside = ops.pip_candidates(points[order], pid[order], edge_pool,
                                    backend=backend)
        return jnp.zeros_like(inside).at[order].set(inside)
    edges = edges_table[jnp.clip(pid, 0, edges_table.shape[0] - 1)]
    return ops.pip_gathered(points, edges, backend=backend) & (pid >= 0)


def _pip_sequential(points, cand_ids, edges_table, need, backend,
                    edge_pool=None):
    """First matching candidate in slot order, K sequential kernel calls.

    Returns (assign [R] i32 with -1 = no candidate matched, n_pip [] i32,
    phase2_miss [] i32 == 0).
    """
    k = cand_ids.shape[1]
    assign = jnp.full(points.shape[0], -1, jnp.int32)
    n_pip = jnp.zeros((), jnp.int32)
    for kk in range(k):
        pid = cand_ids[:, kk]
        active = need & (pid >= 0) & (assign < 0)
        inside = _pip_ids(points, pid, edges_table, edge_pool, backend)
        assign = jnp.where(active & inside, pid, assign)
        n_pip = n_pip + jnp.sum(active.astype(jnp.int32))
    return assign, n_pip, jnp.zeros((), jnp.int32)


def _pip_two_phase(points, cand_ids, edges_table, need, backend, cap2,
                   edge_pool=None):
    """Same assignment as ``_pip_sequential`` in two batched phases:
    slot 0 for everyone, then the remaining K-1 slots for the ``cap2``
    compacted slot-0 misses.  Misses beyond cap2 degrade to the caller's
    fallback policy (they are not counted as overflow — same contract as
    capacity overflow, the answer is the fallback, not a drop — but they
    ARE counted in phase2_miss so the degradation is visible)."""
    kk = cand_ids.shape[1]
    pid0 = cand_ids[:, 0]
    in0 = _pip_ids(points, pid0, edges_table, edge_pool, backend)
    in0 = in0 & (pid0 >= 0) & need
    n_pip = jnp.sum(need.astype(jnp.int32))
    assign = jnp.where(in0, pid0, -1)
    if kk == 1:
        return assign, n_pip, jnp.zeros((), jnp.int32)

    miss = need & ~in0
    n_miss = jnp.sum(miss.astype(jnp.int32))
    idx2, ok2 = compact_indices(miss, cap2)
    # Unfilled phase-2 slots alias row 0; guard the counter with ok2 so a
    # row-0 miss doesn't phantom-count PIP tests for them (it would make
    # n_pip depend on which row the compaction's buffer order put first).
    real2 = miss[idx2] & ok2
    phase2_miss = n_miss - jnp.sum(real2.astype(jnp.int32))
    rest = cand_ids[idx2, 1:]                        # [R2, K-1]
    flat_pid = rest.reshape(-1)
    pts_rep = jnp.repeat(points[idx2], kk - 1, axis=0)
    in_r = _pip_ids(pts_rep, flat_pid, edges_table, edge_pool, backend)
    in_r = (in_r & (flat_pid >= 0)).reshape(-1, kk - 1)
    n_pip = n_pip + jnp.sum((real2[:, None]
                             & (rest >= 0)).astype(jnp.int32))
    score = jnp.where(in_r, kk - jnp.arange(1, kk)[None, :], 0)
    best = jnp.argmax(score, axis=1)
    hit2 = jnp.any(in_r, axis=1) & miss[idx2] & ok2
    val2 = jnp.take_along_axis(rest, best[:, None], axis=1)[:, 0]
    assign = scatter_filled(assign, idx2, ok2,
                            jnp.where(hit2, val2, assign[idx2]))
    return assign, n_pip, phase2_miss


def resolve_candidates(points: jnp.ndarray, cand_ids: Candidates,
                       edges_table: jnp.ndarray, need: jnp.ndarray, *,
                       cap: int, k: int | None = None,
                       backend: str | None = None,
                       prior: jnp.ndarray | None = None,
                       fallback: str = "prior",
                       two_phase: bool = False,
                       cap2: int | None = None,
                       edge_pool=None):
    """THE compaction + candidate-PIP + fallback primitive.

    Args:
      points:      [N, 2] query points (full batch).
      cand_ids:    [N, K] candidate polygon ids (-1 = empty slot), or a
                   callable gathering them post-compaction (see Candidates).
      edges_table: [P, E, 4] edge table the candidate ids index into.
      need:        [N] bool — points requiring resolution.
      cap:         static compaction capacity (see compact.capacity_for).
      k:           optional truncation of the candidate list to its first k
                   slots.
      backend:     kernel backend override (resolved once, here).
      prior:       [N] i32 assignment so far; rows outside ``need`` (and
                   rows whose resolution fails, under fallback="prior")
                   keep it.  Defaults to all -1.
      fallback:    what a needed-but-unmatched point gets:
                     "prior" — its prior value (cascade: the bbox select);
                     "first" — its slot-0 candidate (cell index: the
                     centre owner, error bounded by the leaf diagonal).
      two_phase:   PIP schedule (see module docstring).
      cap2:        two-phase only — capacity of the phase-2 (slot-0 miss)
                   compaction; defaults to a quarter of ``cap`` (the
                   centre-owner hit rate makes misses the minority).
      edge_pool:   optional blocked-CSR ``ops.EdgePool`` over the same
                   polygons as ``edges_table``; when given, candidate PIP
                   runs through the fused gather-PIP kernel instead of
                   gather + ``pip_gathered`` (see module docstring).

    Returns:
      (assign [N] i32, ResolveStats).  Capacity overflow leaves ``prior``
      untouched and is counted in stats.overflow; phase-2 capacity misses
      degrade to ``fallback`` and are counted in stats.phase2_miss.
    """
    n = points.shape[0]
    backend = ops.resolve_backend(backend)
    if prior is None:
        prior = jnp.full((n,), -1, jnp.int32)
    idx, slot_ok = compact_indices(need, cap)
    sub_pts = points[idx]
    sub_need = need[idx] & slot_ok
    sub_cand = cand_ids(idx, sub_pts) if callable(cand_ids) \
        else cand_ids[idx]
    if k is not None:
        sub_cand = sub_cand[:, :k]
    if two_phase:
        if cap2 is None:
            cap2 = capacity_for(cap, 0.25, ceiling=cap)
        resolved, n_pip, p2_miss = _pip_two_phase(
            sub_pts, sub_cand, edges_table, sub_need, backend, cap2,
            edge_pool=edge_pool)
    else:
        resolved, n_pip, p2_miss = _pip_sequential(
            sub_pts, sub_cand, edges_table, sub_need, backend,
            edge_pool=edge_pool)
    if fallback == "first":
        fb = jnp.where(sub_cand[:, 0] >= 0, sub_cand[:, 0], -1)
    elif fallback == "prior":
        fb = prior[idx]
    else:
        raise ValueError(f"unknown fallback policy: {fallback!r}")
    new_val = jnp.where(sub_need,
                        jnp.where(resolved >= 0, resolved, fb),
                        prior[idx])
    assign = scatter_filled(prior, idx, slot_ok, new_val)
    n_need = jnp.sum(need.astype(jnp.int32))
    overflow = n_need - jnp.sum(sub_need.astype(jnp.int32))
    return assign, ResolveStats(n_need=n_need, n_pip=n_pip,
                                overflow=overflow, phase2_miss=p2_miss)
