"""GeoPlan: the explainable auto-planner behind ``strategy="auto"``
(DESIGN.md §11).

The paper's core observation is that the *same* projection problem wants
different execution plans in different regimes: the simple cascade when
an index isn't worth building, the cell index when true hits dominate,
the hybrid split when boundary traffic is heavy, the sharded layout when
the index outgrows one device.  The deployment follow-up (Samuel et al.,
arXiv:2108.11525) shows those regimes shifting live — so the choice
belongs in a planner, not in caller code.

``plan_for`` inspects four signals and emits a ``GeoPlan``:

  * **device kind** (``jax.default_backend()``) — the fused gather-PIP
    kernel is a TPU bandwidth win; on CPU the ref path is faster;
  * **batch size hint** — a batch smaller than ``SMALL_BATCH`` doesn't
    amortize the covering BFS if no covering exists yet;
  * **index capabilities** (``GeoIndexSet.capabilities()``) — replanning
    against an already-built artifact never picks a plan the artifact
    cannot execute (no simple index -> no hybrid; no pool -> no fused);
  * **measured boundary fraction** — the area share of boundary cells in
    the covering (``covering_boundary_fraction``).  For uniform traffic
    this is the expected fraction of points that pay candidate PIP; above
    ``HYBRID_BOUNDARY_FRAC`` the hybrid cascade's hierarchical PIP beats
    the fast path's flat candidate lists;
  * **recorded autotune** (``GeoIndexSet.tuning``, written by
    ``geo_perf --autotune``) — when the artifact carries a measured
    winner for this device kind, that measurement overrides the
    threshold heuristics above: a recorded ``fast_onepass`` win routes
    straight to the one-pass fused cascade at its tuned edge-pool block
    size.

Every decision appends a human-readable reason, so
``GeoEngine.explain()`` answers *why* a plan was chosen, and bench rows
(``geo_perf`` / ``serve_perf``) can record the plan next to the numbers
it produced.  Thresholds are module constants on purpose: the ROADMAP's
"pick a crossover heuristic" follow-ups land here, in one place.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import numpy as np

# Planner thresholds (see DESIGN.md §11 for the rationale and how to
# retune them from bench rows).
HYBRID_BOUNDARY_FRAC = 0.35   # boundary area share above which the
#                               cascade resolves boundaries cheaper than
#                               flat candidate lists.  Below it the
#                               two-phase schedule (§Perf geo 2-3) puts
#                               ~90 % of boundary points through ONE
#                               slot-0 PIP, which no 3-level cascade can
#                               beat; above it candidate lists saturate
#                               (max_cand) and hierarchical pruning wins.
#                               Measured on the CPU bench map (bf 0.28:
#                               fast_exact 4.5x hybrid) — the auto bench
#                               row records plan-vs-winner so this stays
#                               retunable from history.
SMALL_BATCH = 1024            # below this, a covering BFS is not worth
#                               building for a one-shot batch
SHARD_MIN_POINTS = 1 << 17    # batch size where multi-device routing
#                               beats replicated lookup (CPU-sim measured
#                               crossover is above this; see ROADMAP)


@dataclasses.dataclass(frozen=True)
class GeoPlan:
    """One chosen execution plan, with its inputs and reasons.

    ``strategy``/``mode``/``fused`` feed straight into the engine build;
    ``sharded``/``n_shards`` are a routing recommendation (honored by
    callers that hold a mesh — ``assign`` itself stays single-mesh).
    ``auto`` is False for plans that merely record an explicit request.
    """

    strategy: str
    mode: str = "exact"
    fused: Union[bool, str] = False   # False | True | "onepass"
    sharded: bool = False
    n_shards: int = 1
    device_kind: str = "cpu"
    n_points: Optional[int] = None
    boundary_fraction: Optional[float] = None
    auto: bool = True
    reasons: Tuple[str, ...] = ()

    def as_dict(self) -> dict:
        """JSON-ready rendering (bench rows, ``GeoEngine.explain``)."""
        return {
            "strategy": self.strategy, "mode": self.mode,
            "fused": self.fused, "sharded": self.sharded,
            "n_shards": self.n_shards, "device_kind": self.device_kind,
            "n_points": (None if self.n_points is None
                         else int(self.n_points)),
            "boundary_fraction": (None if self.boundary_fraction is None
                                  else float(self.boundary_fraction)),
            "auto": self.auto, "reasons": list(self.reasons),
        }

    def apply(self, cfg):
        """Fold the plan into an EngineConfig (replaces mode + fused)."""
        return dataclasses.replace(cfg, mode=self.mode, fused=self.fused)


def covering_boundary_fraction(covering) -> float:
    """Area share of the covering owned by boundary cells: the sum of
    boundary-cell leaf spans over the total covered span.  Under uniform
    on-map traffic this is the expected candidate-PIP fraction — the
    planner's one *measured* (not configured) input."""
    lo = np.asarray(covering.lo, np.int64)
    hi = np.asarray(covering.hi, np.int64)
    val = np.asarray(covering.val)
    span = hi - lo + 1
    total = int(span.sum())
    if total == 0:
        return 0.0
    return float(span[val < 0].sum() / total)


def explicit_plan(strategy: str, cfg, device_kind: str = None) -> GeoPlan:
    """The degenerate plan recording a caller-pinned strategy, so
    ``engine.explain()`` has one answer shape whether or not the planner
    ran."""
    return GeoPlan(strategy=strategy, mode=cfg.mode,
                   # fast_onepass pins the one-pass kernel regardless of
                   # what the config says — record what actually runs.
                   fused=("onepass" if strategy == "fast_onepass"
                          else cfg.fused),
                   device_kind=device_kind or jax.default_backend(),
                   auto=False, reasons=("explicit strategy request",))


def plan_for(cfg, *, covering=None, capabilities: Optional[dict] = None,
             n_points: Optional[int] = None,
             device_kind: Optional[str] = None,
             n_devices: Optional[int] = None,
             tuning: Optional[dict] = None) -> GeoPlan:
    """Choose an execution plan (see module docstring).

    ``capabilities=None`` means "planning a fresh build — anything is
    buildable from the census"; a dict (``GeoIndexSet.capabilities()``)
    constrains the plan to what an existing artifact can execute.
    ``tuning`` is the artifact's recorded autotune block
    (``GeoIndexSet.tuning``) — a measured winner there beats the
    threshold heuristics.
    """
    device_kind = device_kind or jax.default_backend()
    n_devices = n_devices if n_devices is not None \
        else jax.local_device_count()
    fresh = capabilities is None
    caps = capabilities or {}
    reasons = []

    bf = None
    if covering is not None:
        bf = covering_boundary_fraction(covering)

    has_cell_index = fresh or covering is not None or caps.get("fast")
    can_cascade = fresh or caps.get("simple") or caps.get("census")
    # The fast index's edge pool is usable when built OR buildable (an
    # artifact carrying its census rebuilds pools on demand).
    fast_pool_ok = (fresh or caps.get("fast_pool", False)
                    or caps.get("census", False))
    tune = dict(tuning or {})
    # A recorded autotune win only transfers within its measurement
    # context: same device kind (a CPU-recorded winner says nothing
    # about TPU DMA behaviour, and vice versa).
    tuned_onepass = (tune.get("winner") == "fast_onepass"
                     and tune.get("device_kind", device_kind)
                     == device_kind)

    # -- strategy -----------------------------------------------------------
    if not has_cell_index:
        strategy = "simple"
        reasons.append("no covering or fast index available: only the "
                       "cascade can run")
    elif (n_points is not None and n_points < SMALL_BATCH
          and covering is None and not caps.get("fast")):
        strategy = "simple"
        reasons.append(f"batch hint {n_points} < {SMALL_BATCH}: the "
                       f"covering BFS would dominate a one-shot batch")
    elif tuned_onepass and cfg.mode == "exact" and fast_pool_ok:
        strategy = "fast_onepass"
        reasons.append(
            f"recorded autotune on {device_kind!r} measured fast_onepass "
            f"fastest (be={tune.get('be')}, "
            f"{tune.get('pts_per_sec', 0):.3g} pts/s): measurement "
            f"overrides threshold heuristics")
    elif bf is not None and bf >= HYBRID_BOUNDARY_FRAC and can_cascade:
        strategy = "hybrid"
        reasons.append(f"measured boundary fraction {bf:.3f} >= "
                       f"{HYBRID_BOUNDARY_FRAC}: cascade PIP beats flat "
                       f"candidate lists on heavy boundary traffic")
    else:
        strategy = "fast"
        if bf is not None:
            reasons.append(f"measured boundary fraction {bf:.3f} < "
                           f"{HYBRID_BOUNDARY_FRAC}: true hits dominate")
        else:
            reasons.append("no covering to measure boundary traffic yet; "
                           "cell index is the paper's default winner")

    # -- mode ---------------------------------------------------------------
    mode = cfg.mode
    if mode == "approx":
        reasons.append("approx mode kept from config (error bounded by "
                       "the leaf cell diagonal)")

    # -- fused kernel -------------------------------------------------------
    runs_candidate_pip = (strategy in ("simple", "hybrid")
                          or (strategy in ("fast", "fast_onepass")
                              and mode == "exact"))
    pool_cap = {"simple": "simple_pool", "hybrid": "simple_pool",
                "fast": "fast_pool",
                "fast_onepass": "fast_pool"}[strategy]
    # A pool is usable when built OR buildable: an artifact that carries
    # its census rebuilds pools on demand (GeoIndexSet.ensure, which
    # from_index_set runs after planning) — a TPU cold start must not be
    # condemned to the gather path just because device-side pools are
    # never serialized.
    pool_available = (fresh or caps.get(pool_cap, False)
                      or caps.get("census", False))
    onepass_ok = (strategy in ("fast", "fast_onepass")
                  and mode == "exact" and pool_available)
    if strategy == "fast_onepass":
        fused = "onepass"
        reasons.append("fast_onepass pins the one-pass fused cascade "
                       "kernel (kernels/cascade.py)")
    elif cfg.fused == "onepass":
        if onepass_ok:
            fused = "onepass"
            reasons.append("one-pass fused cascade requested by config")
        else:
            fused = bool(runs_candidate_pip and pool_available)
            reasons.append(
                "onepass requested but it needs the exact fast path with "
                "an edge pool: "
                + ("kept the two-kernel fused path" if fused
                   else "dropped (no candidate PIP or no edge pool)"))
    elif cfg.fused:
        fused = runs_candidate_pip and pool_available
        reasons.append("fused requested by config"
                       if fused else
                       "fused requested but unusable here (no candidate "
                       "PIP or no edge pool built): dropped")
    elif device_kind == "tpu" and runs_candidate_pip and pool_available:
        fused = True
        reasons.append("TPU device: fused gather-PIP removes the "
                       "gathered-edges HBM round trip")
    else:
        fused = False
        if runs_candidate_pip and device_kind == "tpu":
            reasons.append("TPU device but no edge pool built for this "
                           "index: fused unusable, running the gather "
                           "path")
        elif runs_candidate_pip:
            reasons.append(f"device {device_kind!r}: the legacy gather "
                           f"path wins off-TPU")

    # -- sharding recommendation --------------------------------------------
    sharded = False
    n_shards = 1
    if (n_devices > 1 and n_points is not None
            and n_points >= SHARD_MIN_POINTS and has_cell_index):
        sharded = True
        n_shards = n_devices
        reasons.append(f"{n_devices} devices and batch hint {n_points} >= "
                       f"{SHARD_MIN_POINTS}: route via assign_sharded")

    return GeoPlan(strategy=strategy, mode=mode, fused=fused,
                   sharded=sharded, n_shards=n_shards,
                   device_kind=device_kind, n_points=n_points,
                   boundary_fraction=bf, auto=True,
                   reasons=tuple(reasons))
