"""Geo enrichment operator: the paper's technique as a pipeline stage.

``enrich(index, cfg, xy, *, n_feature_tokens)`` maps a batch of (lon, lat)
locations onto census blocks with the fast index and returns
(block_id, county_id, state_id, feature_token) — jit-able, shardable on the
batch axis, and cheap enough to fuse into a host->device prefetch stage.

This is where "projecting billions of locations onto census polygons"
(paper §I) meets the training framework: demographic features join the
token stream at data-pipeline rate, not in a separate offline job.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.fast import FastConfig, FastIndex, assign_fast


@functools.partial(jax.jit, static_argnames=("cfg", "n_feature_tokens"))
def enrich(index: FastIndex, xy: jnp.ndarray,
           cfg: FastConfig = FastConfig(),
           n_feature_tokens: int = 1024):
    """xy [N, 2] (lon, lat) -> dict of per-point census features."""
    sid, cid, bid, stats = assign_fast(index, xy, cfg)
    feature = (jnp.maximum(bid, 0) % n_feature_tokens).astype(jnp.int32)
    feature = jnp.where(bid >= 0, feature, n_feature_tokens)  # OOV bucket
    return {"state": sid, "county": cid, "block": bid,
            "feature_token": feature, "stats": stats}
