"""GeoIndexSet: the unified index artifact behind every strategy
(DESIGN.md §11).

One object owns everything a ``GeoEngine`` (or a registered third-party
strategy) can look points up against:

  * the host census geometry (``CensusMap``) and the quadtree cell
    covering (``CellCovering``) — the expensive-to-build host artifacts;
  * the device indices derived from them: ``SimpleIndex`` (cascade),
    ``FastIndex`` (cell lookup), ``ShardedFastIndex`` per shard count —
    each with or without the blocked-CSR edge pools the fused gather-PIP
    kernel needs;
  * a capability snapshot (``capabilities()``) the registry's build-time
    validation and the planner read, so a fused config meeting a
    pool-less index fails at construction, never at the first assign.

Components build lazily through ``ensure`` — strategies declare what
they need (registry capability flags) and the engine ensures exactly
that, so nothing is built twice and nothing unused is built at all.

**Persistence** (``save``/``load``): the artifact serializes its *host*
primitives — census polygon soups and covering arrays — as one
compressed npz beside a JSON manifest (schema-version checked).  Device
indices are deliberately NOT serialized: they are cheap, deterministic
functions of the saved arrays (``SimpleIndex.from_census``,
``FastIndex.from_covering``), so a reload followed by ``ensure``
reconstructs them bit-identically while the artifact on disk stays
small, portable across jax versions, and independent of device layout.
What cold start actually buys is skipping the covering BFS — the one
build step that scales with map complexity rather than array size.

    idx = GeoIndexSet.build(census, components=("fast",), gbits=4)
    idx.save("artifacts/national")
    ...
    idx = GeoIndexSet.load("artifacts/national")
    eng = GeoEngine.from_index_set(idx, strategy="auto")
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

import numpy as np

from repro.core.cells import CellCovering, build_cell_covering
from repro.core.distributed import ShardedFastIndex, shard_covering
from repro.core.fast import FastIndex
from repro.core.geometry import CensusMap, PolygonSoup
from repro.core.simple import SimpleIndex
from repro.kernels import ops

# v2 adds the ``tuning`` manifest block (autotuned one-pass kernel
# config, DESIGN.md §13); v1 artifacts load with empty tuning.
SCHEMA_VERSION = 2
ACCEPTED_SCHEMA_VERSIONS = (1, 2)
MANIFEST_NAME = "manifest.json"
ARRAYS_NAME = "arrays.npz"
FORMAT_NAME = "geo-index-set"

_SOUP_FIELDS = ("verts", "n_verts", "bbox", "parent", "fips")
_COVER_FIELDS = ("lo", "hi", "val", "level", "cand")
_LEVELS = ("states", "counties", "blocks")


@dataclasses.dataclass
class GeoIndexSet:
    """Unified, lazily-built index artifact (see module docstring).

    ``max_level`` / ``gbits`` / ``max_cand`` are the covering/index build
    parameters (the same knobs ``EngineConfig`` carries); they are fixed
    per artifact so every component agrees on quantization.
    """

    census: Optional[CensusMap] = None
    covering: Optional[CellCovering] = None
    simple: Optional[SimpleIndex] = None
    fast: Optional[FastIndex] = None
    sharded: Dict[int, ShardedFastIndex] = \
        dataclasses.field(default_factory=dict)
    max_level: int = 9
    gbits: int = 4
    max_cand: int = 8
    # Autotune record (benchmarks/geo_perf.py --autotune): winning
    # strategy + edge-pool block size + the measurement context.  Rides
    # in the manifest (schema v2) so a reloaded artifact plans from
    # recorded measurements, not hard-coded thresholds.  Keys (all
    # optional): "winner", "be", "device_kind", "pts_per_sec",
    # "roofline_fraction", "recorded".
    tuning: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, census: CensusMap, components=(), pools=(), *,
              max_level: int = 9, gbits: int = 4, max_cand: int = 8,
              covering: Optional[CellCovering] = None) -> "GeoIndexSet":
        """Build the requested ``components`` ("simple" | "fast" |
        "covering") from a host census; ``pools`` names the components
        that additionally need their blocked-CSR edge pools (the fused
        gather-PIP path)."""
        self = cls(census=census, covering=covering, max_level=max_level,
                   gbits=gbits, max_cand=max_cand)
        for comp in components:
            self.ensure(comp)
        for comp in pools:
            self.ensure(comp, pool=True)
        return self

    def ensure(self, component: str, pool: bool = False) -> None:
        """Build ``component`` if missing (and its edge pool, when
        ``pool``).  Pools attach to an already-built index in place —
        bit-identical to building with pools up front, since both paths
        pack the same edge arrays through ``ops.build_edge_pool``."""
        if component == "covering":
            if self.covering is None:
                self._need_census("the cell covering")
                self.covering = build_cell_covering(
                    self.census, max_level=self.max_level,
                    max_cand=self.max_cand)
        elif component == "simple":
            if self.simple is None:
                self._need_census("the simple (cascade) index")
                self.simple = SimpleIndex.from_census(self.census,
                                                      with_pools=False)
            if pool and self.simple.state_pool is None:
                be = self.pool_be()
                self.simple = dataclasses.replace(
                    self.simple,
                    state_pool=ops.build_edge_pool(
                        np.asarray(self.simple.state_edges), be=be),
                    county_pool=ops.build_edge_pool(
                        np.asarray(self.simple.county_edges), be=be),
                    block_pool=ops.build_edge_pool(
                        np.asarray(self.simple.block_edges), be=be))
        elif component == "fast":
            if self.fast is None:
                self._need_census("the fast (cell) index")
                self.ensure("covering")
                self.fast = FastIndex.from_covering(
                    self.covering, self.census, gbits=self.gbits,
                    with_pool=False)
            if pool and self.fast.edge_pool is None:
                self.fast = dataclasses.replace(
                    self.fast,
                    edge_pool=ops.build_edge_pool(
                        np.asarray(self.fast.block_edges),
                        be=self.pool_be()))
        else:
            raise ValueError(f"unknown index component {component!r}; "
                             f"expected 'simple', 'fast', or 'covering'")

    def _need_census(self, what: str) -> None:
        if self.census is None:
            raise ValueError(f"building {what} needs a census "
                             f"(GeoIndexSet built from arrays only?)")

    def sharded_index(self, n_shards: int,
                      with_pool: bool = False) -> ShardedFastIndex:
        """The Morton-sharded index for ``n_shards``, built once per
        shard count (pool attached on demand, like ``ensure``)."""
        if n_shards not in self.sharded:
            if self.covering is None or self.census is None:
                raise ValueError("assign_sharded needs the engine built "
                                 "from a census with a cell covering "
                                 "(strategy 'fast' or 'hybrid')")
            self.sharded[n_shards] = shard_covering(
                self.covering, self.census, n_shards, with_pool=False)
        if with_pool and self.sharded[n_shards].edge_pool is None:
            sidx = self.sharded[n_shards]
            self.sharded[n_shards] = dataclasses.replace(
                sidx, edge_pool=ops.build_edge_pool(
                    np.asarray(sidx.block_edges), be=self.pool_be()))
        return self.sharded[n_shards]

    # -- autotune record ----------------------------------------------------

    def pool_be(self) -> int:
        """Edge-pool block size (edges per CSR block): the autotuned
        value when one is recorded, ``ops.DEF_BE`` otherwise.  Every
        pool this artifact attaches (simple / fast / sharded) is packed
        at this size, so the one-pass kernel's DMA granularity matches
        the recorded winner."""
        return int(self.tuning.get("be") or 0) or ops.DEF_BE

    def record_tuning(self, tuning: Dict[str, Any]) -> None:
        """Merge an autotune result into the artifact (persisted by
        ``save``).  When the recorded ``be`` differs from the pools
        already built, the built pools are dropped so the next
        ``ensure(..., pool=True)`` repacks at the tuned size."""
        old_be = self.pool_be()
        self.tuning = {**self.tuning, **tuning}
        if self.pool_be() != old_be:
            if self.fast is not None and self.fast.edge_pool is not None:
                self.fast = dataclasses.replace(self.fast, edge_pool=None)
            if self.simple is not None \
                    and self.simple.state_pool is not None:
                self.simple = dataclasses.replace(
                    self.simple, state_pool=None, county_pool=None,
                    block_pool=None)
            for n, sidx in list(self.sharded.items()):
                if sidx.edge_pool is not None:
                    self.sharded[n] = dataclasses.replace(
                        sidx, edge_pool=None)

    def memory_footprint(self) -> Dict[str, int]:
        """Flat numeric snapshot of the built device-index memory (bytes
        + chosen tile sizes), for serving metrics gauges.  Only counts
        what is built right now — a lazy artifact reports 0s."""
        fp = {"pool_be": self.pool_be(), "edge_pool_bytes": 0,
              "edge_pool_blocks": 0, "edge_pool_max_blocks": 0,
              "index_bytes": 0}
        if self.fast is not None:
            for leaf in (self.fast.cell_lo, self.fast.cell_hi,
                         self.fast.cell_val, self.fast.top_start,
                         self.fast.cand, self.fast.block_bbox):
                if leaf is not None:
                    fp["index_bytes"] += int(np.asarray(leaf).nbytes)
            pool = self.fast.edge_pool
            if pool is not None:
                fp["edge_pool_bytes"] = int(
                    np.asarray(pool.blocks).nbytes
                    + np.asarray(pool.first).nbytes
                    + np.asarray(pool.count).nbytes)
                fp["edge_pool_blocks"] = int(pool.blocks.shape[0])
                fp["edge_pool_max_blocks"] = int(pool.max_blocks)
        return fp

    # -- capability snapshot (registry validation, planner) -----------------

    def capabilities(self) -> Dict[str, Any]:
        """What is built right now — the dict the registry's build-time
        validation and the planner's capability-constrained replanning
        read (keys: census, covering, simple, fast, simple_pool,
        fast_pool, sharded: list of shard counts)."""
        return {
            "census": self.census is not None,
            "covering": self.covering is not None,
            "simple": self.simple is not None,
            "fast": self.fast is not None,
            "simple_pool": (self.simple is not None
                            and self.simple.state_pool is not None),
            "fast_pool": (self.fast is not None
                          and self.fast.edge_pool is not None),
            "sharded": sorted(self.sharded),
        }

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> str:
        """Write the artifact under directory ``path`` (created if
        missing): ``manifest.json`` + ``arrays.npz``.  Saves the host
        primitives (census soups, covering intervals) — see the module
        docstring for why device indices are derived, not stored."""
        if self.census is None:
            raise ValueError("GeoIndexSet.save needs at least a census")
        os.makedirs(path, exist_ok=True)
        arrays: Dict[str, np.ndarray] = {}
        for lvl in _LEVELS:
            soup = getattr(self.census, lvl)
            for f in _SOUP_FIELDS:
                arrays[f"census_{lvl}_{f}"] = np.asarray(getattr(soup, f))
        # Extent rides in the npz (float64, exact) — the quant-vector
        # formula (fast.quant_for_extent) must see bit-identical bounds
        # after a reload or host/device cache keys fork.
        arrays["extent"] = np.asarray(self.census.extent, np.float64)
        components = ["census"]
        if self.covering is not None:
            for f in _COVER_FIELDS:
                arrays[f"covering_{f}"] = np.asarray(
                    getattr(self.covering, f))
            components.append("covering")
        manifest = {
            "format": FORMAT_NAME,
            "schema_version": SCHEMA_VERSION,
            "components": components,
            "max_level": int(self.max_level),
            "gbits": int(self.gbits),
            "max_cand": int(self.max_cand),
            "counts": {
                "states": self.census.states.n_poly,
                "counties": self.census.counties.n_poly,
                "blocks": self.census.blocks.n_poly,
                "cells": (0 if self.covering is None
                          else int(len(self.covering.lo))),
            },
            # Informational only — load() re-derives device indices.
            "built": self.capabilities(),
            # Autotune record (schema v2): round-trips verbatim so a
            # reloaded artifact plans from recorded measurements.
            "tuning": self.tuning,
        }
        np.savez_compressed(os.path.join(path, ARRAYS_NAME), **arrays)
        with open(os.path.join(path, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "GeoIndexSet":
        """Reload an artifact directory; ValueError on a missing/foreign/
        newer-schema manifest.  Device indices rebuild lazily via
        ``ensure`` (bit-identical — see ``save``)."""
        mpath = os.path.join(path, MANIFEST_NAME)
        if not os.path.exists(mpath):
            raise ValueError(f"no {MANIFEST_NAME} under {path!r} — not a "
                             f"saved GeoIndexSet")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format") != FORMAT_NAME:
            raise ValueError(f"manifest format {manifest.get('format')!r} "
                             f"is not {FORMAT_NAME!r}")
        version = manifest.get("schema_version")
        if version not in ACCEPTED_SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported schema_version {version!r} (this build "
                f"reads versions {sorted(ACCEPTED_SCHEMA_VERSIONS)}); "
                f"re-save the artifact with a matching build")
        with np.load(os.path.join(path, ARRAYS_NAME)) as z:
            arrays = {k: z[k] for k in z.files}
        extent = tuple(float(v) for v in arrays["extent"])
        soups = {}
        for lvl in _LEVELS:
            soups[lvl] = PolygonSoup(
                **{f: arrays[f"census_{lvl}_{f}"] for f in _SOUP_FIELDS})
        census = CensusMap(states=soups["states"],
                           counties=soups["counties"],
                           blocks=soups["blocks"], extent=extent)
        covering = None
        if "covering" in manifest.get("components", ()):
            val = arrays["covering_val"]
            covering = CellCovering(
                **{f: arrays[f"covering_{f}"] for f in _COVER_FIELDS},
                max_level=int(manifest["max_level"]), extent=extent,
                n_interior=int((val >= 0).sum()),
                n_boundary=int((val < 0).sum()))
        return cls(census=census, covering=covering,
                   max_level=int(manifest["max_level"]),
                   gbits=int(manifest["gbits"]),
                   max_cand=int(manifest["max_cand"]),
                   # v1 manifests predate the tuning block: empty record.
                   tuning=dict(manifest.get("tuning") or {}))
