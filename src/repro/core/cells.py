"""Host-side quadtree cell covering builder (paper §IV, TPU-adapted).

Builds the *true-hit-filter* index: a non-overlapping hierarchical cell
covering of the census map where each cell either

  * lies fully inside one block polygon  -> interior cell (value = block id),
  * or touches >= 1 polygon boundaries   -> boundary cell (candidate list,
    centre-owner first), emitted only at ``max_level``.

Unlike the paper's per-polygon S2 coverings, we build ONE global covering
top-down (the census map is a partition, so cells never belong to two
interiors).  Each BFS node carries the candidate polygon ids and boundary
edge ids that survive its parent — the build is O(total cells visited), not
O(polygons x cells).

Cells are identified by Morton (Z-order) codes over a 2^L x 2^L grid in the
map's normalized [0,1)^2 coordinates.  A cell at level l with Morton prefix m
covers leaf codes [m << 2(L-l), (m+1) << 2(L-l)); the index is the sorted
array of these intervals — the TPU-native replacement for the paper's radix
trie (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.geometry import CensusMap, point_in_polygon_host


def part1by1_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.int64) & 0x0000FFFF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def morton_np(ix: np.ndarray, iy: np.ndarray) -> np.ndarray:
    return (part1by1_np(iy) << 1) | part1by1_np(ix)


def _seg_rect_intersect(x1, y1, x2, y2, rx0, rx1, ry0, ry1):
    """Vectorized segment-vs-rect intersection (Liang-Barsky clip).

    Endpoints on the rect boundary count as intersecting (conservative:
    over-marking a cell as boundary only costs a PIP test, never wrongness).
    """
    dx = x2 - x1
    dy = y2 - y1
    t0 = np.zeros_like(x1)
    t1 = np.ones_like(x1)
    ok = np.ones_like(x1, dtype=bool)
    for p, q in (((-dx), (x1 - rx0)), ((dx), (rx1 - x1)),
                 ((-dy), (y1 - ry0)), ((dy), (ry1 - y1))):
        r = np.where(p != 0, q / np.where(p == 0, 1.0, p), 0.0)
        # p == 0: parallel; reject iff the segment lies outside this slab.
        ok &= ~((p == 0) & (q < 0))
        is_entry = p < 0
        t0 = np.where((p != 0) & is_entry, np.maximum(t0, r), t0)
        t1 = np.where((p != 0) & ~is_entry, np.minimum(t1, r), t1)
    return ok & (t0 <= t1)


@dataclasses.dataclass
class CellCovering:
    """Flat covering arrays (host, numpy), sorted by ``lo``."""

    lo: np.ndarray          # [n_cells] int32 — leaf-code interval start
    hi: np.ndarray          # [n_cells] int32 — inclusive interval end
    val: np.ndarray         # [n_cells] int32 — >=0 block id, <0 -(cand_row+1)
    level: np.ndarray       # [n_cells] int8 — quadtree level of the cell
    cand: np.ndarray        # [n_boundary, max_cand] int32, -1 padded
    max_level: int
    extent: tuple           # (x0, x1, y0, y1) of the map
    n_interior: int
    n_boundary: int

    def nbytes(self) -> int:
        return (self.lo.nbytes + self.hi.nbytes + self.val.nbytes
                + self.level.nbytes + self.cand.nbytes)

    def validate_partition(self) -> None:
        """Intervals must be sorted, disjoint, and within [0, 4^max_level)."""
        assert np.all(self.lo[1:] > self.lo[:-1])
        assert np.all(self.hi >= self.lo)
        assert np.all(self.hi[:-1] < self.lo[1:])
        assert self.lo[0] >= 0 and self.hi[-1] < (1 << (2 * self.max_level))


def build_cell_covering(census: CensusMap, max_level: int = 9,
                        max_cand: int = 8,
                        min_split_level: int = 2) -> CellCovering:
    """Build the global covering over the census *block* level."""
    assert max_level <= 15, "leaf codes must fit int32"
    x0, x1, y0, y1 = census.extent
    sx, sy = 1.0 / (x1 - x0), 1.0 / (y1 - y0)
    blocks = census.blocks

    # Normalized edge soup of all block polygons.
    verts = blocks.verts.astype(np.float64).copy()
    verts[..., 0] = (verts[..., 0] - x0) * sx
    verts[..., 1] = (verts[..., 1] - y0) * sy
    e1 = verts[:, :-1, :]
    e2 = verts[:, 1:, :]
    # Drop degenerate padding edges.
    keep = ~np.all(e1 == e2, axis=-1)
    poly_of_edge = np.broadcast_to(
        np.arange(blocks.n_poly, dtype=np.int32)[:, None], keep.shape)[keep]
    ex1, ey1 = e1[keep][:, 0], e1[keep][:, 1]
    ex2, ey2 = e2[keep][:, 0], e2[keep][:, 1]

    nbb = blocks.bbox.astype(np.float64).copy()
    nbb[:, 0:2] = (nbb[:, 0:2] - x0) * sx
    nbb[:, 2:4] = (nbb[:, 2:4] - y0) * sy

    rings_n = [verts[p, :blocks.n_verts[p]] for p in range(blocks.n_poly)]

    def center_owner(cx, cy, cand_polys):
        for p in cand_polys:
            if point_in_polygon_host(np.array([cx]), np.array([cy]),
                                     rings_n[p])[0]:
                return int(p)
        return -1

    out_lo, out_hi, out_val, out_lvl = [], [], [], []
    cand_rows: list[np.ndarray] = []

    all_polys = np.arange(blocks.n_poly, dtype=np.int32)
    all_edges = np.arange(len(ex1), dtype=np.int32)
    # BFS stack: (level, ix, iy, candidate polys, candidate edges)
    stack = [(0, 0, 0, all_polys, all_edges)]
    while stack:
        l, ix, iy, cpolys, cedges = stack.pop()
        size = 1.0 / (1 << l)
        rx0, ry0 = ix * size, iy * size
        rx1, ry1 = rx0 + size, ry0 + size
        # Prune candidates to this cell.
        keep_p = ~((nbb[cpolys, 1] < rx0) | (nbb[cpolys, 0] > rx1) |
                   (nbb[cpolys, 3] < ry0) | (nbb[cpolys, 2] > ry1))
        cpolys = cpolys[keep_p]
        if len(cpolys) == 0:
            continue  # outside the map
        hit = _seg_rect_intersect(ex1[cedges], ey1[cedges], ex2[cedges],
                                  ey2[cedges], rx0, rx1, ry0, ry1)
        cedges = cedges[hit]
        shift = 2 * (max_level - l)
        m = int(morton_np(np.array([ix]), np.array([iy]))[0])
        if len(cedges) == 0 and l >= min_split_level:
            owner = center_owner((rx0 + rx1) / 2, (ry0 + ry1) / 2, cpolys)
            if owner < 0:
                continue  # cell fully outside the map
            out_lo.append(m << shift)
            out_hi.append(((m + 1) << shift) - 1)
            out_val.append(owner)
            out_lvl.append(l)
        elif l == max_level:
            # Boundary cell: candidates = polys owning any crossing edge,
            # plus the centre owner (listed first for approximate mode).
            touch = np.unique(poly_of_edge[cedges])
            owner = center_owner((rx0 + rx1) / 2, (ry0 + ry1) / 2, cpolys)
            cands = [owner] if owner >= 0 else []
            cands += [int(p) for p in touch if p != owner]
            cands = cands[:max_cand]
            if not cands:
                continue
            row = np.full(max_cand, -1, np.int32)
            row[:len(cands)] = cands
            out_lo.append(m << shift)
            out_hi.append(((m + 1) << shift) - 1)
            out_val.append(-(len(cand_rows) + 1))
            out_lvl.append(l)
            cand_rows.append(row)
        else:
            for dy in (0, 1):
                for dx in (0, 1):
                    stack.append((l + 1, 2 * ix + dx, 2 * iy + dy,
                                  cpolys, cedges))

    order = np.argsort(np.asarray(out_lo))
    lo = np.asarray(out_lo, np.int32)[order]
    hi = np.asarray(out_hi, np.int32)[order]
    val = np.asarray(out_val, np.int32)[order]
    lvl = np.asarray(out_lvl, np.int8)[order]
    cand = (np.stack(cand_rows) if cand_rows
            else np.zeros((0, max_cand), np.int32))
    cov = CellCovering(lo=lo, hi=hi, val=val, level=lvl, cand=cand,
                       max_level=max_level, extent=census.extent,
                       n_interior=int((val >= 0).sum()),
                       n_boundary=len(cand_rows))
    return cov
