"""Built-in mapping strategies as registered plugins (DESIGN.md §11).

The execution plans the engine ships — the paper's simple cascade
(§III), the fast cell index (§IV), its one-pass fused-cascade variant
(kernels/cascade.py), the hybrid interior/cascade split, and
the dispatch-routed Morton-sharded lookup — registered through
``core.registry`` exactly like a third-party strategy would be.  The
engine holds no strategy-specific code at all: it resolves names via
``get_strategy`` and calls the protocol.

Each plugin stays a thin driver over ``core.resolve.resolve_candidates``
(the compaction + candidate-PIP + fallback primitive); what differs is
which points need resolution and which candidates they bring.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import fast as fast_mod
from repro.core import simple as simple_mod
from repro.core.compact import capacity_for, compact_indices, scatter_filled
from repro.core.distributed import ShardedFastIndex, local_lookup
from repro.core.fast import (FastConfig, FastIndex, cell_values, parents_of,
                             quantize_codes)
from repro.core.registry import Strategy, register_strategy
from repro.core.resolve import AssignResult, GeoStats
from repro.core.simple import SimpleConfig, SimpleIndex
from repro.distributed.dispatch import (plan_routes, scatter_to_buckets,
                                        slot_tables)
from repro.kernels import ops
from repro.compat import shard_map


@register_strategy("simple", needs=("simple",), needs_edge_pool=True)
class SimpleStrategy(Strategy):
    """The paper's §III hierarchical bbox cascade."""

    def assign(self, indices, points, cfg) -> AssignResult:
        sid, cid, bid, st = simple_mod.assign_simple(
            indices.simple, points, cfg.simple_cfg())
        levels = ("state", "county", "block")
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=sum(st[l]["n_multi"] for l in levels),
            n_pip=sum(st[l]["n_pip"] for l in levels),
            overflow=sum(st[l]["overflow"] for l in levels),
            extra=st))


@register_strategy("fast", needs=("fast",), needs_edge_pool=True)
class FastStrategy(Strategy):
    """The paper's §IV true-hit-filter cell index (cfg.mode picks exact /
    approx boundary handling)."""

    def pool_components(self, cfg):
        # Only exact mode runs candidate PIP on the fast index (approx
        # accepts the centre owner), so only it needs the edge pool.
        return ("fast",) if cfg.fused and cfg.mode == "exact" else ()

    def assign(self, indices, points, cfg) -> AssignResult:
        sid, cid, bid, st = fast_mod.assign_fast(
            indices.fast, points, cfg.fast_cfg())
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=st["n_boundary"], n_pip=st["n_pip"],
            overflow=st["overflow"], extra=st))


@register_strategy("fast_onepass", needs=("fast",), needs_edge_pool=True)
class FastOnepassStrategy(FastStrategy):
    """The one-pass fused cascade (kernels/cascade.py): the whole
    quantize -> cell lookup -> bbox filter -> PIP pipeline in a single
    kernel with double-buffered edge-block DMA.  Semantically this is
    ``fast`` with ``mode="exact", fused="onepass"`` pinned — registered
    under its own name so the planner, benchmarks, and serving configs
    can name the execution plan directly; assignments are bit-identical
    to ``fast_exact`` (and its stats counters match outside the
    two-phase path's capacity-overflow regime)."""

    def pool_components(self, cfg):
        # Always exact, always the in-kernel candidate walk: the edge
        # pool is unconditionally required (and validated at build).
        return ("fast",)

    def assign(self, indices, points, cfg) -> AssignResult:
        fcfg = dataclasses.replace(cfg.fast_cfg(), mode="exact",
                                   fused="onepass")
        sid, cid, bid, st = fast_mod.assign_fast(indices.fast, points,
                                                 fcfg)
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=st["n_boundary"], n_pip=st["n_pip"],
            overflow=st["overflow"], extra=st))


@functools.partial(jax.jit, static_argnames=("scfg", "cap_frac"))
def _assign_hybrid(findex: FastIndex, sindex: SimpleIndex,
                   points: jnp.ndarray, scfg: SimpleConfig,
                   cap_frac: float):
    """Hybrid strategy: interior true hits from the cell index; boundary
    points re-resolved through the hierarchical cascade."""
    n = points.shape[0]
    val = cell_values(findex, points)
    bid = jnp.where(val >= 0, val, -1)
    need = (val < 0) & (val > fast_mod.OUTSIDE)      # boundary cells
    n_boundary = jnp.sum(need.astype(jnp.int32))

    cap = capacity_for(n, cap_frac)
    idx, slot_ok = compact_indices(need, cap)
    sub_need = need[idx] & slot_ok
    # Unfilled compaction slots alias row 0; feed the cascade FAR points
    # there (and on non-boundary rows) so its stats count only real
    # boundary work — otherwise n_pip would scale with the capacity, and
    # a padded batch (assign_padded) would report different stats than
    # the unpadded call.  Result-identical: only sub_need rows' cascade
    # output is kept below.
    sub_pts = jnp.where(sub_need[:, None], points[idx],
                        jnp.float32(ops.FAR))
    _, _, sub_bid, sub_stats = simple_mod.cascade_assign(
        sindex, sub_pts, scfg)
    bid = scatter_filled(bid, idx, slot_ok,
                         jnp.where(sub_need & (sub_bid >= 0),
                                   sub_bid, bid[idx]))
    overflow = n_boundary - jnp.sum(sub_need.astype(jnp.int32))
    if findex.cand.shape[0] > 0:
        # Cascade misses + capacity overflow degrade to the centre-owner
        # candidate (the fast-approx answer) rather than staying lost.
        brow = jnp.clip(-(val + 1), 0, findex.cand.shape[0] - 1)
        bid = jnp.where(need & (bid < 0), findex.cand[brow, 0], bid)

    cid, sid = parents_of(findex, bid)
    n_pip = sum(lvl["n_pip"] for lvl in sub_stats.values())
    stats = {"n_boundary": n_boundary, "n_pip": n_pip,
             "overflow": overflow, "cascade": sub_stats}
    return sid, cid, bid, stats


@register_strategy("hybrid", needs=("simple", "fast"), needs_edge_pool=True)
class HybridStrategy(Strategy):
    """Fast cell lookup for interior true hits; boundary/overflow points
    routed through the simple cascade's hierarchical PIP instead of the
    flat candidate-list fallback (see the engine module docstring)."""

    def pool_components(self, cfg):
        # The cascade does all candidate PIP in hybrid mode — the fast
        # index's own pool is never consulted.
        return ("simple",) if cfg.fused else ()

    def assign(self, indices, points, cfg) -> AssignResult:
        sid, cid, bid, st = _assign_hybrid(
            indices.fast, indices.simple, points,
            cfg.hybrid_cascade_cfg(), cfg.cap_boundary)
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=st["n_boundary"], n_pip=st["n_pip"],
            overflow=st["overflow"], extra=st))


def _sharded_assign(sidx: ShardedFastIndex, points: jnp.ndarray, mesh,
                    cfg: FastConfig, capacity: int, cap_pip: int):
    """Dispatch-routed sharded lookup: bucket points by owning Morton
    shard, scatter into per-shard capacity buffers, look up shard-locally
    under shard_map, gather results back by buffer slot."""
    n = points.shape[0]
    s = sidx.n_shards
    codes = quantize_codes(sidx.quant, sidx.max_level, points)
    owner = jnp.clip(
        jnp.searchsorted(sidx.range_lo, codes, side="right") - 1, 0, s - 1
    ).astype(jnp.int32)
    plan = plan_routes(owner, s, capacity)
    item_for_slot, _ = slot_tables(plan, s, capacity)        # [S*cap]
    ok = item_for_slot >= 0
    # Off-extent points carry border-clipped codes (see quantize_codes);
    # deactivate their slots so they come back -1, not a border block.
    ext = fast_mod.extent_mask(sidx.quant, sidx.max_level, points)
    slot_ext = ok & ext[jnp.clip(item_for_slot, 0, n - 1)]
    buf_pts = scatter_to_buckets(plan, points, s, capacity,
                                 item_for_slot=item_for_slot
                                 ).reshape(s, capacity, 2)
    buf_ok = slot_ext.reshape(s, capacity)
    pool = sidx.edge_pool if cfg.fused else None

    def body(pts_loc, ok_loc, lo, hi, val, cand):
        pts_loc, ok_loc = pts_loc[0], ok_loc[0]
        lo, hi, val, cand = lo[0], hi[0], val[0], cand[0]
        codes_loc = quantize_codes(sidx.quant, sidx.max_level, pts_loc)
        bid, rs = local_lookup(
            sidx.block_edges, lo, hi, val, cand, codes_loc, pts_loc,
            cfg.mode, cap_pip, cfg.backend, active=ok_loc,
            edge_pool=pool)
        return (bid[None], jax.lax.psum(rs.n_need, "model"),
                jax.lax.psum(rs.n_pip, "model"),
                jax.lax.psum(rs.overflow, "model"),
                jax.lax.psum(rs.phase2_miss, "model"))

    ps = jax.sharding.PartitionSpec
    bid_buf, n_need, n_pip, pip_of, p2_miss = shard_map(
        body, mesh=mesh,
        in_specs=(ps("model"), ps("model"), ps("model"), ps("model"),
                  ps("model"), ps("model")),
        out_specs=(ps("model"), ps(), ps(), ps(), ps()),
    )(buf_pts, buf_ok, sidx.cell_lo, sidx.cell_hi, sidx.cell_val,
      sidx.cand)

    dest = jnp.where(ok, item_for_slot, n)
    bid = jnp.full((n + 1,), -1, jnp.int32).at[dest].set(
        bid_buf.reshape(-1), mode="drop")[:n]
    cid, sid = parents_of(sidx, bid)
    stats = {"n_boundary": n_need, "n_pip": n_pip, "overflow": pip_of,
             "phase2_miss": p2_miss, "n_dropped": plan.n_dropped}
    return sid, cid, bid, stats


@register_strategy("sharded", supports_sharded=True, supports_padded=False)
class ShardedStrategy(Strategy):
    """Morton-sharded cell lookup routed through the capacity-bucketed
    dispatch primitive shared with the MoE layer (DESIGN.md §6) — every
    engine's ``assign_sharded`` resolves to this plugin.

    Capacity per shard is ``cap_shard * N / n_shards`` — routing skew
    beyond that is dropped to bid -1 and counted in stats
    (extra["n_dropped"]), mirroring MoE token dropping.
    """

    def assign_sharded(self, indices, points, mesh, cfg) -> AssignResult:
        if "model" not in mesh.axis_names:
            raise ValueError("assign_sharded expects a mesh with a "
                             "'model' axis")
        n = points.shape[0]
        n_shards = int(mesh.shape["model"])
        sidx = indices.sharded_index(
            n_shards, with_pool=(cfg.fused and cfg.mode == "exact"))
        capacity = capacity_for(n, cfg.cap_shard / n_shards)
        cap_pip = capacity_for(capacity, cfg.cap_boundary,
                               ceiling=capacity)
        sid, cid, bid, st = _sharded_assign(
            sidx, points, mesh, cfg.fast_cfg(), capacity, cap_pip)
        return AssignResult(sid, cid, bid, GeoStats(
            n_need=st["n_boundary"], n_pip=st["n_pip"],
            overflow=st["overflow"] + st["n_dropped"], extra=st))
