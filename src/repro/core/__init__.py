# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Layering (DESIGN.md §3, §11):
#   geometry/synth/cells  — host-side map + index construction
#   compact/resolve       — the shared device-side resolution core
#   simple/fast           — the paper's two strategies as thin drivers
#   registry/strategies   — Strategy protocol + the registered plugins
#                           (simple | fast | hybrid | sharded)
#   artifact              — GeoIndexSet: unified indices + edge pools,
#                           versioned save/load (cold start)
#   plan                  — the auto-planner behind strategy="auto"
#   engine                — the plan-and-execute GeoEngine facade
#   distributed/enrich    — sharded lookup internals, pipeline operator
