# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Layering (DESIGN.md §3):
#   geometry/synth/cells  — host-side map + index construction
#   compact/resolve       — the shared device-side resolution core
#   simple/fast           — the paper's two strategies as thin drivers
#   engine                — the GeoEngine facade (simple|fast|hybrid,
#                           single-mesh and dispatch-routed sharded assign)
#   distributed/enrich    — sharded lookup internals, pipeline operator
