"""Polygon soup geometry for the census-block mapping engine.

Polygons are stored as *closed, padded rings*: ``verts[p, i]`` for
``i in [0, n_verts[p]]`` with ``verts[p, n_verts[p]] == verts[p, 0]``, and all
entries beyond that padded with ``verts[p, 0]``.  Edge ``i`` of polygon ``p``
is ``(verts[p, i], verts[p, i+1])``; padded edges are zero-length and
contribute no ray crossings, so every kernel can run over the full padded
extent without masking.

Device arrays are float32.  The paper stores fp64 because Matlab does; the
crossing-number test only needs consistent orientation comparisons, and the
synthetic data keeps points away from exact boundary contact (see synth.py),
so fp32 is sufficient on device.  Host-side reference checks use fp64 numpy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class PolygonSoup:
    """A level of the census hierarchy as flat padded arrays (host, numpy).

    Attributes:
      verts:   [n_poly, max_v + 1, 2] float — closed padded rings (see module doc).
      n_verts: [n_poly] int32 — true ring length (excluding the closing vertex).
      bbox:    [n_poly, 4] float — (xmin, xmax, ymin, ymax).
      parent:  [n_poly] int32 — index into the parent level (-1 at top level).
      fips:    [n_poly] int64 — FIPS-style code for the entity.
    """

    verts: Array
    n_verts: Array
    bbox: Array
    parent: Array
    fips: Array

    @property
    def n_poly(self) -> int:
        return int(self.verts.shape[0])

    @property
    def max_v(self) -> int:
        return int(self.verts.shape[1]) - 1

    def edges(self) -> Array:
        """Edge table [n_poly, max_v, 4] = (x1, y1, x2, y2)."""
        a = self.verts[:, :-1, :]
        b = self.verts[:, 1:, :]
        return np.concatenate([a, b], axis=-1)

    def validate(self) -> None:
        n, mv = self.verts.shape[0], self.verts.shape[1] - 1
        assert self.n_verts.shape == (n,)
        assert self.bbox.shape == (n, 4)
        assert self.parent.shape == (n,)
        assert self.fips.shape == (n,)
        assert np.all(self.n_verts >= 3)
        assert np.all(self.n_verts <= mv)
        idx = np.arange(n)
        # Ring closure at position n_verts.
        close = self.verts[idx, self.n_verts, :]
        np.testing.assert_allclose(close, self.verts[:, 0, :], rtol=0, atol=0)
        # bbox consistency.
        assert np.all(self.bbox[:, 0] <= self.bbox[:, 1])
        assert np.all(self.bbox[:, 2] <= self.bbox[:, 3])


def pack_rings(rings: list[np.ndarray], parent: Optional[np.ndarray] = None,
               fips: Optional[np.ndarray] = None,
               max_v: Optional[int] = None,
               dtype=np.float32) -> PolygonSoup:
    """Pack a list of [n_i, 2] open rings into a padded PolygonSoup."""
    n = len(rings)
    nv = np.array([len(r) for r in rings], dtype=np.int32)
    if max_v is None:
        max_v = int(nv.max())
    assert int(nv.max()) <= max_v, (int(nv.max()), max_v)
    verts = np.zeros((n, max_v + 1, 2), dtype=dtype)
    bbox = np.zeros((n, 4), dtype=dtype)
    for i, r in enumerate(rings):
        r = np.asarray(r, dtype=dtype)
        k = len(r)
        verts[i, :k] = r
        verts[i, k:] = r[0]  # close + pad with first vertex
        bbox[i] = (r[:, 0].min(), r[:, 0].max(), r[:, 1].min(), r[:, 1].max())
    if parent is None:
        parent = np.full((n,), -1, dtype=np.int32)
    if fips is None:
        fips = np.arange(n, dtype=np.int64)
    return PolygonSoup(verts=verts, n_verts=nv,
                       bbox=bbox.astype(dtype),
                       parent=parent.astype(np.int32),
                       fips=fips.astype(np.int64))


def polygon_areas(soup: PolygonSoup) -> Array:
    """[n_poly] float64 polygon areas (shoelace over the padded closed
    rings — padding repeats the first vertex, so padded edges contribute
    exactly zero and no masking is needed).  Units are the map's
    coordinate units squared; the analytics layer divides per-block
    occupancy counts by these for crowding density (DESIGN.md §16)."""
    v = soup.verts.astype(np.float64)
    x1, y1 = v[:, :-1, 0], v[:, :-1, 1]
    x2, y2 = v[:, 1:, 0], v[:, 1:, 1]
    return 0.5 * np.abs(np.sum(x1 * y2 - x2 * y1, axis=1))


def point_in_polygon_host(px: Array, py: Array, ring: Array) -> Array:
    """fp64 crossing-number oracle for one polygon (host side, numpy).

    ``ring`` is an open [n, 2] ring (no duplicated closing vertex).
    Returns a bool array matching ``px``/``py``.
    Uses the half-open rule ``(y1 > py) != (y2 > py)`` so vertices on the ray
    are counted exactly once.
    """
    ring = np.asarray(ring, dtype=np.float64)
    px = np.asarray(px, dtype=np.float64)[..., None]
    py = np.asarray(py, dtype=np.float64)[..., None]
    x1, y1 = ring[:, 0], ring[:, 1]
    x2, y2 = np.roll(ring[:, 0], -1), np.roll(ring[:, 1], -1)
    straddle = (y1 > py) != (y2 > py)
    # px < x1 + (py - y1) * (x2 - x1) / (y2 - y1), multiplication-only form.
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1)[None, :])
    return (np.sum(cross, axis=-1) % 2).astype(bool)


@dataclasses.dataclass(frozen=True)
class CensusMap:
    """Three-level hierarchy: states -> counties -> blocks (host container)."""

    states: PolygonSoup
    counties: PolygonSoup
    blocks: PolygonSoup
    # Map extent (xmin, xmax, ymin, ymax) used for cell-code quantization.
    extent: tuple[float, float, float, float]

    def level(self, name: str) -> PolygonSoup:
        return {"state": self.states, "county": self.counties,
                "block": self.blocks}[name]

    def validate(self) -> None:
        for s in (self.states, self.counties, self.blocks):
            s.validate()
        assert np.all(self.counties.parent >= 0)
        assert np.all(self.counties.parent < self.states.n_poly)
        assert np.all(self.blocks.parent >= 0)
        assert np.all(self.blocks.parent < self.counties.n_poly)


def children_tables(level: PolygonSoup, n_parents: int,
                    max_children: Optional[int] = None):
    """Group a level's polygons by parent into dense per-parent tables.

    Returns (child_ids [n_parents, max_children] int32 padded with -1,
             n_children [n_parents] int32).
    """
    order = np.argsort(level.parent, kind="stable")
    counts = np.bincount(level.parent, minlength=n_parents)
    if max_children is None:
        max_children = int(counts.max())
    child_ids = np.full((n_parents, max_children), -1, dtype=np.int32)
    start = 0
    for p in range(n_parents):
        c = counts[p]
        child_ids[p, :c] = order[start:start + c]
        start += c
    return child_ids, counts.astype(np.int32)
