"""Device-side "fast" approach (paper §IV): true-hit-filter cell lookup.

Lookup pipeline per point (all vectorized, jit-able):

  1. fixed-point quantize (lon, lat) -> (ix, iy) on the 2^L grid and Morton-
     interleave to a leaf code (int32 bit arithmetic — the TPU analogue of
     S2 cell ids);
  2. locate the covering cell: top-grid bucket (direct-indexed first 2g bits
     — the radix-trie-fanout analogue; g=0 disables) then a fixed-iteration
     binary search over the sorted interval starts;
  3. interior cell  -> block id, done (paper's "true hit": zero PIP tests);
     boundary cell  -> exact mode: crossing-number kernel against <=K
     candidates (compacted to a static buffer);
                       approx mode: accept the centre-owner candidate —
     error bounded by the leaf cell diagonal (paper's precision guarantee).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cells import CellCovering, morton_np
from repro.core.compact import capacity_for
from repro.core.geometry import CensusMap
from repro.core.resolve import onepass_stats, resolve_candidates
from repro.kernels import ops
from repro.kernels import cascade as _cascade

# One sentinel, two layers: the kernel package owns its copy (core
# imports kernels, never the reverse) — they must never fork.
assert _cascade.OUTSIDE == -2**30

# Sentinel cell value for points outside the map (below any candidate row
# encoding -(row+1)).
OUTSIDE = -2**30


def part1by1(x: jnp.ndarray) -> jnp.ndarray:
    x = x & 0x0000FFFF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def unpart1by1(x: jnp.ndarray) -> jnp.ndarray:
    x = x & 0x55555555
    x = (x | (x >> 1)) & 0x33333333
    x = (x | (x >> 2)) & 0x0F0F0F0F
    x = (x | (x >> 4)) & 0x00FF00FF
    x = (x | (x >> 8)) & 0x0000FFFF
    return x


def morton(ix: jnp.ndarray, iy: jnp.ndarray) -> jnp.ndarray:
    return (part1by1(iy) << 1) | part1by1(ix)


def demorton(code: jnp.ndarray):
    """Inverse of ``morton``: leaf code -> (ix, iy) grid coordinates."""
    return unpart1by1(code), unpart1by1(code >> 1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FastIndex:
    """Device-resident cell index (+ block geometry for exact fallback)."""

    cell_lo: Any        # [n_cells] i32 sorted
    cell_hi: Any        # [n_cells] i32 inclusive ends (gaps = outside map)
    cell_val: Any       # [n_cells] i32
    cand: Any           # [n_boundary, K] i32
    top_start: Any      # [4^g + 1] i32 — bucket ranges into cell_lo
    block_edges: Any    # [Nb, Eb, 4] f32 — exact-mode PIP fallback
    block_parent: Any   # [Nb] i32
    county_parent: Any  # [Nc] i32
    quant: Any          # [4] f32: (x0, y0, sx, sy) with s = 2^L / extent
    edge_pool: Any = None  # blocked-CSR EdgePool over the same blocks
    #                        (fused gather-PIP path; FastConfig.fused)
    block_bbox: Any = None  # [Nb, 4] f32 (xmin, xmax, ymin, ymax) — the
    #                         one-pass cascade kernel's in-VMEM bbox
    #                         filter stage (fused="onepass")
    # -- static --
    max_level: int = dataclasses.field(metadata=dict(static=True), default=9)
    gbits: int = dataclasses.field(metadata=dict(static=True), default=0)
    search_iters: int = dataclasses.field(metadata=dict(static=True),
                                          default=32)

    def tree_flatten(self):
        leaves = (self.cell_lo, self.cell_hi, self.cell_val, self.cand,
                  self.top_start, self.block_edges, self.block_parent,
                  self.county_parent, self.quant, self.edge_pool,
                  self.block_bbox)
        return leaves, (self.max_level, self.gbits, self.search_iters)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, max_level=aux[0], gbits=aux[1],
                   search_iters=aux[2])

    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes)
                   for a in (self.cell_lo, self.cell_hi, self.cell_val,
                             self.cand, self.top_start))

    @classmethod
    def from_covering(cls, cov: CellCovering, census: CensusMap,
                      gbits: int = 4, with_pool: bool = False):
        """gbits = quadtree levels resolved by the direct-indexed top grid
        (the paper's F1/F2/F4 trie-fanout analogue; 2*gbits key bits).

        ``with_pool`` additionally builds the blocked-CSR edge pool the
        fused gather-PIP path needs (FastConfig.fused); off by default so
        legacy callers pay neither the host build nor the device copy.
        """
        assert gbits <= cov.max_level
        nb = 1 << (2 * gbits)
        shift = 2 * (cov.max_level - gbits)
        # Bucket b covers leaf codes [b << shift, (b+1) << shift).  A covering
        # cell larger than a bucket spans several buckets; searchsorted-right
        # on lo gives, for each bucket start, the first cell *after* it, so
        # search ranges [start[b]-1, start[b+1]) — we fold the -1 into start.
        starts = np.searchsorted(cov.lo, np.arange(nb + 1, dtype=np.int64)
                                 << shift, side="left").astype(np.int32)
        # Static iteration count for the in-bucket binary search: the range
        # for bucket b is [starts[b]-1, starts[b+1]) — higher gbits => fewer
        # iterations, the paper's F1/F2/F4 fanout-vs-memory trade.
        max_span = int(np.max(starts[1:] - np.maximum(starts[:-1] - 1, 0))) \
            if len(cov.lo) else 1
        iters = max(1, int(np.ceil(np.log2(max(max_span, 2)))))
        quant = quant_for_extent(cov.extent, cov.max_level)
        block_edges_np = ops.edges_from_soup_np(census.blocks.verts)
        return cls(
            cell_lo=jnp.asarray(cov.lo),
            cell_hi=jnp.asarray(cov.hi),
            cell_val=jnp.asarray(cov.val),
            cand=jnp.asarray(cov.cand),
            top_start=jnp.asarray(starts),
            block_edges=jnp.asarray(block_edges_np),
            block_parent=jnp.asarray(census.blocks.parent),
            county_parent=jnp.asarray(census.counties.parent),
            quant=jnp.asarray(quant),
            edge_pool=(ops.build_edge_pool(block_edges_np)
                       if with_pool else None),
            # Always carried: [Nb, 4] is tiny, and the one-pass cascade
            # needs it whenever a pool is attached (possibly later, via
            # GeoIndexSet.ensure).
            block_bbox=jnp.asarray(census.blocks.bbox, jnp.float32),
            max_level=cov.max_level,
            gbits=gbits,
            search_iters=iters,
        )


def quant_for_extent(extent, max_level: int) -> np.ndarray:
    """THE quant vector: [4] f32 = (x0, y0, sx, sy) with s = 2^L / span.
    Every producer (FastIndex, ShardedFastIndex, engine extent handle,
    serving cell table) derives it here — the hot-cell cache's host/
    device bit-exactness rests on this formula never forking."""
    x0, x1, y0, y1 = extent
    n = 1 << max_level
    return np.array([x0, y0, n / (x1 - x0), n / (y1 - y0)], np.float32)


def quantize_codes(quant: jnp.ndarray, max_level: int,
                   points: jnp.ndarray) -> jnp.ndarray:
    """Fixed-point quantize + Morton-interleave [N, 2] points to leaf codes
    given the bare quant params [4] = (x0, y0, sx, sy) — usable by any
    index flavour (FastIndex, ShardedFastIndex).

    Off-extent coordinates CLIP onto the grid border, so a far-outside
    query maps to a border cell's leaf code.  Every caller that turns a
    code into a block id must therefore also apply ``extent_mask`` —
    otherwise an off-map point silently inherits a border block instead
    of -1 (the simple cascade's answer for the same point).
    """
    n = 1 << max_level
    ix = jnp.clip(((points[:, 0] - quant[0]) * quant[2])
                  .astype(jnp.int32), 0, n - 1)
    iy = jnp.clip(((points[:, 1] - quant[1]) * quant[3])
                  .astype(jnp.int32), 0, n - 1)
    return morton(ix, iy)


def extent_mask(quant: jnp.ndarray, max_level: int,
                points: jnp.ndarray) -> jnp.ndarray:
    """[N] bool — True where the point lies inside the quantization extent
    (the map bbox).  The companion of ``quantize_codes``: codes of points
    outside this mask are border-clipped and must not resolve to a block."""
    n = 1 << max_level
    fx = (points[:, 0] - quant[0]) * quant[2]
    fy = (points[:, 1] - quant[1]) * quant[3]
    return (fx >= 0) & (fx < n) & (fy >= 0) & (fy < n)


def np_quantize_codes(quant, max_level: int, points) -> np.ndarray:
    """Host (numpy) mirror of ``quantize_codes``, op-for-op in fp32
    (subtract, multiply, truncating cast — no FMA contraction on either
    side), so host and device codes agree bit-exactly.  The serving
    layer's cache keys on it without a device trip (DESIGN.md §10)."""
    n = 1 << max_level
    xy = np.asarray(points, np.float32)
    q = np.asarray(quant, np.float32)
    with np.errstate(invalid="ignore", over="ignore"):
        fx = (xy[:, 0] - q[0]) * q[2]
        fy = (xy[:, 1] - q[1]) * q[3]
        ix = np.clip(np.trunc(fx), 0, n - 1).astype(np.int32)
        iy = np.clip(np.trunc(fy), 0, n - 1).astype(np.int32)
    return morton_np(ix, iy).astype(np.int32)


def np_extent_mask(quant, max_level: int, points) -> np.ndarray:
    """Host (numpy) mirror of ``extent_mask`` — the serving router's
    ownership test, zero device traffic."""
    n = 1 << max_level
    xy = np.asarray(points, np.float32)
    q = np.asarray(quant, np.float32)
    fx = (xy[:, 0] - q[0]) * q[2]
    fy = (xy[:, 1] - q[1]) * q[3]
    return (fx >= 0) & (fx < n) & (fy >= 0) & (fy < n)


def leaf_codes(index: FastIndex, points: jnp.ndarray) -> jnp.ndarray:
    return quantize_codes(index.quant, index.max_level, points)


def locate_cells(index: FastIndex, codes: jnp.ndarray) -> jnp.ndarray:
    """Index into cell_lo of the covering cell for each leaf code (-1 =
    outside the map)."""
    n_cells = index.cell_lo.shape[0]
    if index.gbits == 0:
        # Plain vectorized binary search over the full table.
        idx = jnp.searchsorted(index.cell_lo, codes, side="right") - 1
    else:
        shift = 2 * (index.max_level - index.gbits)
        bucket = (codes >> shift).astype(jnp.int32)
        l = jnp.maximum(index.top_start[bucket] - 1, 0)
        h = index.top_start[bucket + 1]         # exclusive
        # Fixed-iteration searchsorted-right within [l, h).
        for _ in range(index.search_iters):
            active = l < h
            mid = (l + h) // 2
            go_right = index.cell_lo[jnp.clip(mid, 0, n_cells - 1)] <= codes
            nl = jnp.where(active & go_right, mid + 1, l)
            nh = jnp.where(active & ~go_right, mid, h)
            l, h = nl, nh
        idx = l - 1
    idx = jnp.clip(idx, 0, n_cells - 1)
    return idx


@dataclasses.dataclass(frozen=True)
class FastConfig:
    mode: str = "exact"          # "exact" | "approx"
    cap_boundary: float = 0.25   # compaction capacity for boundary points
    backend: str | None = None
    fused: Any = False           # exact mode candidate-PIP data path:
    #                              False     — gather + pip_gathered;
    #                              True      — fused gather-PIP kernel
    #                                          (index.edge_pool);
    #                              "onepass" — the one-pass fused cascade
    #                                          kernel (kernels/cascade.py):
    #                                          the whole quantize/lookup/
    #                                          bbox/PIP pipeline in one
    #                                          kernel, no compaction.
    #                              Results are identical in all three.


def cell_values(index: FastIndex, points: jnp.ndarray) -> jnp.ndarray:
    """Covering-cell value per point: >= 0 interior block id ("true hit"),
    -(row+1) boundary candidate row, OUTSIDE if the point is in no cell
    or off the map extent (quantization clips, so the extent test is
    explicit — see ``quantize_codes``)."""
    codes = leaf_codes(index, points)
    cidx = locate_cells(index, codes)
    in_cell = ((index.cell_lo[cidx] <= codes)
               & (codes <= index.cell_hi[cidx]))  # gap => outside the map
    in_cell = in_cell & extent_mask(index.quant, index.max_level, points)
    return jnp.where(in_cell, index.cell_val[cidx], OUTSIDE)


def parents_of(index, bid: jnp.ndarray):
    """Derive (county, state) ids from block ids via the parent tables
    (any index flavour carrying block_parent / county_parent)."""
    cid = jnp.where(bid >= 0, index.block_parent[jnp.clip(bid, 0, None)], -1)
    sid = jnp.where(cid >= 0, index.county_parent[jnp.clip(cid, 0, None)], -1)
    return cid, sid


def assign_fast_onepass(index: FastIndex, points: jnp.ndarray,
                        cfg: FastConfig):
    """Exact-mode assignment through the one-pass fused cascade kernel
    (kernels/cascade.py): quantize, cell lookup, bbox filter, and the
    candidate PIP all in one kernel — no per-stage HBM intermediates and
    no compaction buffers.  Assignments are bit-identical to the
    two-phase ``assign_fast`` path (first matching candidate in slot
    order, centre-owner fallback), and the stats counters match whenever
    the two-phase caps are not overflowing (core.resolve.onepass_stats).
    """
    if index.edge_pool is None or index.block_bbox is None:
        raise ValueError('FastConfig.fused="onepass" needs an index '
                         "built by FastIndex.from_covering with a pool "
                         "(with_pool=True / GeoIndexSet.ensure)")
    bid, flags, nrest, nskip = ops.assign_cascade(
        points, index.quant, index.cell_lo, index.cell_hi, index.cell_val,
        index.top_start, index.cand, index.block_bbox, index.edge_pool,
        max_level=index.max_level, gbits=index.gbits,
        search_iters=index.search_iters, backend=cfg.backend)
    stats = onepass_stats(flags, nrest, nskip)
    cid, sid = parents_of(index, bid)
    return sid, cid, bid, stats


@functools.partial(jax.jit, static_argnames=("cfg",))
def assign_fast(index: FastIndex, points: jnp.ndarray,
                cfg: FastConfig = FastConfig()):
    """Map [N, 2] points -> (state, county, block ids, stats)."""
    n = points.shape[0]
    # Defense in depth for direct callers: engine-built paths already
    # fail this at construction (registry capability validation,
    # DESIGN.md §11), so an engine user never reaches this raise.
    if cfg.fused and cfg.mode == "exact" and index.edge_pool is None:
        raise ValueError("FastConfig.fused needs an index built with "
                         "with_pool=True (FastIndex.from_covering)")
    if cfg.fused == "onepass" and cfg.mode == "exact":
        return assign_fast_onepass(index, points, cfg)
    val = cell_values(index, points)
    is_boundary = val < 0
    brow = jnp.clip(-(val + 1), 0, max(index.cand.shape[0] - 1, 0))
    bid = jnp.where(val >= 0, val, -1)
    need = is_boundary & (val > OUTSIDE)

    n_boundary = jnp.sum(need.astype(jnp.int32))
    n_pip = jnp.zeros((), jnp.int32)
    overflow = jnp.zeros((), jnp.int32)
    phase2_miss = jnp.zeros((), jnp.int32)

    if index.cand.shape[0] > 0:
        if cfg.mode == "approx":
            # Centre-owner candidate; error <= leaf cell diagonal.  Gather
            # only slot 0 ([N] i32) instead of the full [N, K] table.
            cand0 = index.cand[brow, 0]
            bid = jnp.where(need, cand0, bid)
        else:
            # Two-phase resolution (§Perf geo iterations 2-3): the centre-
            # owner candidate (slot 0) resolves ~90 % of boundary points,
            # so phase 1 tests ONLY slot 0 for the whole buffer; phase 2
            # batches the remaining K-1 candidates for the ~10 % of misses.
            # Unmatched boundary points fall back to the centre owner
            # (fallback="first").
            bid, rs = resolve_candidates(
                points, lambda idx, _: index.cand[brow[idx]],
                index.block_edges, need,
                cap=capacity_for(n, cfg.cap_boundary),
                backend=cfg.backend, prior=bid, fallback="first",
                two_phase=True,
                edge_pool=index.edge_pool if cfg.fused else None)
            n_pip, overflow = rs.n_pip, rs.overflow
            phase2_miss = rs.phase2_miss

    cid, sid = parents_of(index, bid)
    stats = {"n_boundary": n_boundary, "n_pip": n_pip, "overflow": overflow,
             "phase2_miss": phase2_miss}
    return sid, cid, bid, stats
