"""Synthetic hierarchical census map + location streams (host, numpy).

Real census shapefiles are not available offline, so we generate a map with
the same *structure* the paper exploits:

  * a strict 3-level hierarchy (state -> county -> block group) that exactly
    partitions a CONUS-like extent,
  * highly irregular, non-convex polygon boundaries with 10s..1000s of
    vertices,
  * bounding boxes that overlap between neighbours so that a tunable ~20 % of
    query points fall in >1 bbox (the paper's measured PIP fraction).

Construction: recursive BSP (guillotine) cuts in a rectilinear "chart" space
give an exact nested partition of rectangles.  Every rectangle edge is
subdivided on a *global* grid step (so neighbours share identical boundary
vertices), then all vertices are pushed through a smooth, multi-octave
sinusoidal warp.  The warp is a homeomorphism (displacement gradients < 1),
so the warped polygons still partition the map exactly, but edges become
curvy, polygons non-convex, and bboxes bleed across neighbours.

Ground truth is free: a query point is generated in chart space (where its
BSP cell is known by construction) and warped with the same map.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.geometry import CensusMap, pack_rings

# CONUS-like extent in chart space (degrees).
EXTENT = (-125.0, -66.0, 24.0, 49.0)


@dataclasses.dataclass(frozen=True)
class Warp:
    """Multi-octave sinusoidal displacement field (a homeomorphism)."""

    ax: np.ndarray   # [octaves] x-displacement amplitudes
    ay: np.ndarray   # [octaves]
    kx: np.ndarray   # [octaves] frequencies (rad / degree)
    ky: np.ndarray
    px: np.ndarray   # [octaves] phases
    py: np.ndarray

    def __call__(self, xy: np.ndarray) -> np.ndarray:
        x, y = xy[..., 0], xy[..., 1]
        dx = np.zeros_like(x)
        dy = np.zeros_like(y)
        for i in range(len(self.ax)):
            dx = dx + self.ax[i] * np.sin(self.ky[i] * y + self.px[i])
            dy = dy + self.ay[i] * np.sin(self.kx[i] * x + self.py[i])
        return np.stack([x + dx, y + dy], axis=-1)


def make_warp(rng: np.random.Generator, octaves: int = 3,
              grad: float = 0.2, k_finest: float = 2.4) -> Warp:
    """Octave frequencies descend 4x from ``k_finest`` with amplitude =
    grad / freq, so the displacement *gradient* stays ~``grad`` per octave and
    the total well below 1 -> invertible warp, with irregularity at every
    hierarchy scale.  ``k_finest`` is pinned to the boundary subdivision step
    (k*step = pi/4) so the chord-sagitta error between subdivision vertices
    stays << the point-sampling margin.  ``grad`` is tuned so ~20 % of uniform
    points land in >1 sibling bbox, matching the paper's measured PIP
    fraction (~0.2 evals/point)."""
    ax, ay, kx, ky, px, py = [], [], [], [], [], []
    for o in range(octaves):
        frq = k_finest / (4.0 ** o)
        amp = grad / frq
        ax.append(amp * rng.uniform(0.6, 1.0))
        ay.append(amp * rng.uniform(0.6, 1.0))
        kx.append(frq * rng.uniform(0.8, 1.2))
        ky.append(frq * rng.uniform(0.8, 1.2))
        px.append(rng.uniform(0, 2 * np.pi))
        py.append(rng.uniform(0, 2 * np.pi))
    return Warp(*(np.array(v) for v in (ax, ay, kx, ky, px, py)))


def _snap(c: float, lo: float, hi: float, step: float) -> float:
    """Snap a cut coordinate to the global grid, staying strictly inside.

    Snapping all cuts to grid ticks guarantees every rectangle corner (incl.
    T-junction contact points between neighbours) is a shared subdivision
    vertex, so the partition stays *exact* after the nonlinear warp.
    """
    t = np.round(c / step) * step
    if t <= lo + step * 0.5 or t >= hi - step * 0.5:
        # No interior tick available; keep unsnapped midpoint cut (rare, and
        # only possible for cells ~2 ticks wide where warp curvature over a
        # single step is negligible).
        return c
    return float(t)


def _bsp(rng: np.random.Generator, rect: tuple, n: int,
         step: float) -> list[tuple]:
    """Split rect into n rectangles with jittered, grid-snapped cuts."""
    rects = [rect]
    while len(rects) < n:
        # Split the rectangle with the largest area.
        areas = [(r[1] - r[0]) * (r[3] - r[2]) for r in rects]
        i = int(np.argmax(areas))
        x0, x1, y0, y1 = rects.pop(i)
        if (x1 - x0) >= (y1 - y0):
            c = _snap(x0 + (x1 - x0) * rng.uniform(0.35, 0.65), x0, x1, step)
            rects += [(x0, c, y0, y1), (c, x1, y0, y1)]
        else:
            c = _snap(y0 + (y1 - y0) * rng.uniform(0.35, 0.65), y0, y1, step)
            rects += [(x0, x1, y0, c), (x0, x1, c, y1)]
    return rects


def _rect_ring(rect: tuple, step: float) -> np.ndarray:
    """Open CCW ring for a rectangle, subdivided on the global grid step.

    Subdivision points lie at global multiples of ``step`` so neighbouring
    rectangles produce *identical* vertices along shared edges: the partition
    stays exact after warping.
    """
    x0, x1, y0, y1 = rect

    def seg(lo, hi, axis_fixed, fixed, ascending):
        # Global tick multiples strictly inside (lo, hi); ``ascending`` only
        # controls traversal order.  Epsilon is relative to the step so
        # grid-snapped endpoints are reliably excluded.
        eps = step * 1e-9
        ticks = np.arange(np.ceil((lo - eps) / step) * step, hi, step)
        ticks = ticks[(ticks > lo + eps) & (ticks < hi - eps)]
        if not ascending:
            ticks = ticks[::-1]
        pts = [(t, fixed) if axis_fixed == "y" else (fixed, t) for t in ticks]
        return pts

    ring = [(x0, y0)]
    ring += seg(x0, x1, "y", y0, True)
    ring += [(x1, y0)]
    ring += seg(y0, y1, "x", x1, True)
    ring += [(x1, y1)]
    ring += seg(x0, x1, "y", y1, False)
    ring += [(x0, y1)]
    ring += seg(y0, y1, "x", x0, False)
    return np.array(ring, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class SynthCensus:
    census: CensusMap
    warp: Warp
    # Chart-space rectangles per level, for ground-truth assignment.
    state_rects: np.ndarray    # [n_state, 4]
    county_rects: np.ndarray   # [n_county, 4]
    block_rects: np.ndarray    # [n_block, 4]
    # Upper bound on the chord-sagitta error of warped boundary segments:
    # the warped *image* of a chart boundary can bulge past the straight
    # polygon edge by at most this much.  Ground-truth points keep >= 3x this
    # distance from chart boundaries.
    sagitta: float = 0.0

    def sample_points(self, rng: np.random.Generator, n: int,
                      margin: float = 0.05):
        """Sample n points with known ground truth.

        Points are drawn uniformly inside chart-space *block* rectangles with
        a margin from the boundary (relative, floored at 3x the warp sagitta
        bound so fp32 on-device tests are unambiguous), then warped.  Returns
        (xy [n,2] f32, block_id [n] i32, county_id [n] i32, state_id [n] i32).
        """
        br = self.block_rects
        # Area-weighted block choice approximates uniform spatial sampling.
        areas = (br[:, 1] - br[:, 0]) * (br[:, 3] - br[:, 2])
        p = areas / areas.sum()
        bid = rng.choice(len(br), size=n, p=p).astype(np.int32)
        r = br[bid]
        w, h = r[:, 1] - r[:, 0], r[:, 3] - r[:, 2]
        mx = np.minimum(np.maximum(w * margin, 3 * self.sagitta), 0.45 * w)
        my = np.minimum(np.maximum(h * margin, 3 * self.sagitta), 0.45 * h)
        x = rng.uniform(r[:, 0] + mx, r[:, 1] - mx)
        y = rng.uniform(r[:, 2] + my, r[:, 3] - my)
        xy = self.warp(np.stack([x, y], axis=-1)).astype(np.float32)
        cid = self.census.blocks.parent[bid]
        sid = self.census.counties.parent[cid]
        return xy, bid, cid.astype(np.int32), sid.astype(np.int32)


def build_synth_census(seed: int = 0, n_states: int = 8,
                       counties_per_state: int = 4,
                       blocks_per_county: int = 16,
                       octaves: int = None, grad: float = 0.2,
                       extent: tuple = EXTENT,
                       grid_step: float = None) -> SynthCensus:
    """Build a synthetic census map.

    Defaults are test-sized; the paper-scale config is
    (56, ~58, ~68) -> 56 states / 3,248 counties / 220,864 blocks.
    ``grid_step`` controls boundary vertex density (default: half the typical
    block edge length, giving blocks ~8-40 vertices and states 100s-1000s).
    """
    rng = np.random.default_rng(seed)
    x0, x1, y0, y1 = extent
    rect0 = (x0, x1, y0, y1)

    n_total_blocks = n_states * counties_per_state * blocks_per_county
    if grid_step is None:
        # Typical block edge length / 2 -> blocks get >= ~8 boundary vertices.
        typ = np.sqrt((x1 - x0) * (y1 - y0) / n_total_blocks)
        grid_step = typ / 2.0
    # Finest octave: k * grid_step = pi/4 (wavelength = 8 grid steps), coarsest
    # ~ the state scale, so bbox bleed is significant at every level.
    k_finest = np.pi / (4.0 * grid_step)
    k_coarsest = 2.0 * np.pi / max(x1 - x0, y1 - y0)
    if octaves is None:
        octaves = max(2, int(np.ceil(np.log(k_finest / k_coarsest)
                                     / np.log(4.0))))
    warp = make_warp(rng, octaves=octaves, grad=grad, k_finest=k_finest)

    state_rects = _bsp(rng, rect0, n_states, grid_step)
    county_rects, county_parent = [], []
    for si, sr in enumerate(state_rects):
        for cr in _bsp(rng, sr, counties_per_state, grid_step):
            county_rects.append(cr)
            county_parent.append(si)
    block_rects, block_parent = [], []
    for ci, cr in enumerate(county_rects):
        for br in _bsp(rng, cr, blocks_per_county, grid_step):
            block_rects.append(br)
            block_parent.append(ci)

    def build_level(rects, parent, fips_base):
        rings = [warp(_rect_ring(r, grid_step)) for r in rects]
        parent = np.asarray(parent, dtype=np.int32)
        fips = fips_base + np.arange(len(rects), dtype=np.int64)
        return pack_rings(rings, parent=parent, fips=fips)

    states = build_level(state_rects, [-1] * len(state_rects), 1_000)
    counties = build_level(county_rects, county_parent, 10_000)
    blocks = build_level(block_rects, block_parent, 100_000_000)

    # Warped map extent (warp can push vertices slightly outside the chart box).
    allv = [states.bbox, counties.bbox, blocks.bbox]
    xmin = min(float(b[:, 0].min()) for b in allv)
    xmax = max(float(b[:, 1].max()) for b in allv)
    ymin = min(float(b[:, 2].min()) for b in allv)
    ymax = max(float(b[:, 3].max()) for b in allv)
    census = CensusMap(states=states, counties=counties, blocks=blocks,
                       extent=(xmin, xmax, ymin, ymax))
    # Sum of per-octave sagitta bounds: amp_o * (k_o*step/2)^2 / 2, a
    # geometric series dominated by the finest octave (k*step = pi/4).
    # x- and y-displacement bounds are equal by construction; keep the max.
    sag = float(max(sum(a * (k * grid_step / 2) ** 2 / 2
                        for a, k in zip(amps, ks))
                    for amps, ks in ((warp.ax, warp.ky), (warp.ay, warp.kx))))
    return SynthCensus(census=census, warp=warp,
                       state_rects=np.array(state_rects),
                       county_rects=np.array(county_rects),
                       block_rects=np.array(block_rects),
                       sagitta=sag)
