"""Distributed geo-index lookup (beyond-paper; DESIGN.md §2 last row).

The paper's approximate index hits ~90 GiB on a single node (Table I).  On
TPU we remove that wall by sharding the cell table by contiguous Morton
ranges across the "model" axis while points stay batch-sharded across
("pod","data") — the same activation/weight split as the MoE layer:

  * every model-rank holds its Morton slice of (cell_lo, cell_hi, val,
    cand) — 1/16th of the index per chip on the production mesh;
  * points are replicated over "model" (they are only batch-sharded), so
    each rank resolves the points whose leaf code falls in its range — no
    payload all_to_all at all, only an i32 ``pmax`` per point to combine;
  * the PIP fallback for boundary points runs on the owning rank with a
    fixed-capacity compaction, so exact-mode compute is also sharded.

``shard_covering`` splits a host-side CellCovering into equal-cell padded
slices; ``assign_fast_distributed`` is the shard_map lookup.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.core.cells import CellCovering
from repro.core.fast import (FastConfig, extent_mask, quant_for_extent,
                             quantize_codes)
from repro.core.geometry import CensusMap
from repro.core.compact import capacity_for
from repro.core.resolve import ResolveStats, resolve_candidates
from repro.kernels import ops
from repro.compat import shard_map


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedFastIndex:
    """Morton-range-sharded cell index. Arrays are stacked [n_shards, ...]
    and sharded on axis 0 over "model"."""

    cell_lo: Any       # [S, Lmax] i32 (padded with INT32_MAX)
    cell_hi: Any       # [S, Lmax] i32
    cell_val: Any      # [S, Lmax] i32
    cand: Any          # [S, Cmax, K] i32
    range_lo: Any      # [S] i32 — first leaf code owned by each shard
    block_edges: Any   # [Nb, Eb, 4] f32 (replicated; small vs the index)
    block_parent: Any  # [Nb] i32
    county_parent: Any # [Nc] i32
    quant: Any         # [4] f32
    edge_pool: Any = None  # blocked-CSR EdgePool (replicated; fused path)
    max_level: int = dataclasses.field(metadata=dict(static=True), default=9)
    n_shards: int = dataclasses.field(metadata=dict(static=True), default=16)

    def tree_flatten(self):
        leaves = (self.cell_lo, self.cell_hi, self.cell_val, self.cand,
                  self.range_lo, self.block_edges, self.block_parent,
                  self.county_parent, self.quant, self.edge_pool)
        return leaves, (self.max_level, self.n_shards)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, max_level=aux[0], n_shards=aux[1])

    def index_bytes_per_shard(self) -> int:
        per = (np.asarray(self.cell_lo).nbytes
               + np.asarray(self.cell_hi).nbytes
               + np.asarray(self.cell_val).nbytes
               + np.asarray(self.cand).nbytes)
        return per // self.n_shards


INT_MAX = np.int32(2**31 - 1)


def shard_covering(cov: CellCovering, census: CensusMap,
                   n_shards: int, with_pool: bool = False
                   ) -> ShardedFastIndex:
    """Split the covering into ``n_shards`` contiguous Morton slices with
    (approximately) equal cell counts, padded to a common length.

    ``with_pool`` additionally builds the (replicated) blocked-CSR edge
    pool the fused gather-PIP path needs (FastConfig.fused)."""
    n = len(cov.lo)
    bounds = [int(round(i * n / n_shards)) for i in range(n_shards + 1)]
    lmax = max(bounds[i + 1] - bounds[i] for i in range(n_shards))
    cmax = 0
    rows = []
    for i in range(n_shards):
        a, b = bounds[i], bounds[i + 1]
        val = cov.val[a:b]
        brow = -(val[val < 0] + 1)
        cmax = max(cmax, len(brow))
        rows.append((a, b))

    cell_lo = np.full((n_shards, lmax), INT_MAX, np.int32)
    cell_hi = np.full((n_shards, lmax), -1, np.int32)
    cell_val = np.full((n_shards, lmax), -1, np.int32)
    cand = np.full((n_shards, max(cmax, 1), cov.cand.shape[1]), -1, np.int32)
    range_lo = np.zeros((n_shards,), np.int32)
    for i, (a, b) in enumerate(rows):
        m = b - a
        cell_lo[i, :m] = cov.lo[a:b]
        cell_hi[i, :m] = cov.hi[a:b]
        val = cov.val[a:b].copy()
        # Re-base boundary candidate rows into this shard's local table.
        is_b = val < 0
        src_rows = -(val[is_b] + 1)
        local = np.arange(is_b.sum(), dtype=np.int32)
        cand[i, :len(local)] = cov.cand[src_rows]
        val[is_b] = -(local + 1)
        cell_val[i, :m] = val
        range_lo[i] = cov.lo[a]
    range_lo[0] = 0

    quant = quant_for_extent(cov.extent, cov.max_level)
    block_edges_np = ops.edges_from_soup_np(census.blocks.verts)
    return ShardedFastIndex(
        cell_lo=jnp.asarray(cell_lo), cell_hi=jnp.asarray(cell_hi),
        cell_val=jnp.asarray(cell_val), cand=jnp.asarray(cand),
        range_lo=jnp.asarray(range_lo),
        block_edges=jnp.asarray(block_edges_np),
        block_parent=jnp.asarray(census.blocks.parent),
        county_parent=jnp.asarray(census.counties.parent),
        quant=jnp.asarray(quant),
        edge_pool=(ops.build_edge_pool(block_edges_np)
                   if with_pool else None),
        max_level=cov.max_level, n_shards=n_shards)


def local_lookup(block_edges, lo, hi, val, cand, codes, points,
                 mode: str, cap: int, backend, active=None,
                 edge_pool=None):
    """Lookup of ``codes`` against ONE shard's table (padded rows inert).

    ``active`` optionally masks rows (e.g. empty dispatch-buffer slots).
    Boundary points go through the shared resolution core (sequential
    schedule, centre-owner fallback); ``edge_pool`` routes their PIP
    through the fused gather-PIP kernel.  Returns (bid, ResolveStats).
    """
    pos = jnp.searchsorted(lo, codes, side="right") - 1
    pos = jnp.clip(pos, 0, lo.shape[0] - 1)
    found = (lo[pos] <= codes) & (codes <= hi[pos])
    if active is not None:
        found = found & active
    v = jnp.where(found, val[pos], -INT_MAX)
    bid = jnp.where(v >= 0, v, -1)
    is_b = found & (v < 0) & (v > -INT_MAX)
    brow = jnp.clip(-(v + 1), 0, cand.shape[0] - 1)
    if mode == "approx":
        bid = jnp.where(is_b, cand[brow, 0], bid)
        rs = ResolveStats(n_need=jnp.sum(is_b.astype(jnp.int32)),
                          n_pip=jnp.zeros((), jnp.int32),
                          overflow=jnp.zeros((), jnp.int32),
                          phase2_miss=jnp.zeros((), jnp.int32))
    else:
        bid, rs = resolve_candidates(
            points, lambda i, _: cand[brow[i]], block_edges, is_b,
            cap=cap, backend=backend, prior=bid, fallback="first",
            edge_pool=edge_pool)
    return bid, rs


def assign_fast_distributed(idx: ShardedFastIndex, points: jnp.ndarray,
                            mesh, cfg: FastConfig = FastConfig()):
    """Sharded-index lookup under shard_map.  points [N, 2] batch-sharded
    over ("pod","data"); index sharded over "model".  Returns
    (sid, cid, bid, stats) exactly like assign_fast."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    n = points.shape[0]
    n_loc = n // dp_size
    cap = capacity_for(n_loc, cfg.cap_boundary)
    # Defense in depth for direct callers — engine-routed sharded assign
    # builds the pool on demand (GeoIndexSet.sharded_index) and never
    # reaches this raise.
    if cfg.fused and cfg.mode == "exact" and idx.edge_pool is None:
        raise ValueError("FastConfig.fused needs an index built with "
                         "with_pool=True (shard_covering)")
    pool = idx.edge_pool if cfg.fused else None

    def body(points_loc, lo, hi, val, cand, range_lo):
        lo, hi, val, cand = lo[0], hi[0], val[0], cand[0]
        codes = quantize_codes(idx.quant, idx.max_level, points_loc)
        # Off-extent points quantize onto the border (see quantize_codes);
        # mask them so they resolve to -1 instead of a border-cell block.
        ext = extent_mask(idx.quant, idx.max_level, points_loc)
        bid, rs = local_lookup(idx.block_edges, lo, hi, val, cand,
                               codes, points_loc, cfg.mode, cap,
                               cfg.backend, active=ext, edge_pool=pool)
        # Each point is owned by exactly one shard -> pmax combines.
        bid = jax.lax.pmax(bid, "model")
        axes = ("model",) + dp
        n_need = jax.lax.psum(rs.n_need, axes)
        n_pip = jax.lax.psum(rs.n_pip, axes)
        overflow = jax.lax.psum(rs.overflow, axes)
        return bid, n_need, n_pip, overflow

    bspec = dp if dp else None
    bid, n_need, n_pip, overflow = shard_map(
        body, mesh=mesh,
        in_specs=(PS(bspec, None), PS("model", None), PS("model", None),
                  PS("model", None), PS("model", None, None), PS("model")),
        out_specs=(PS(bspec), PS(), PS(), PS()),
    )(points, idx.cell_lo, idx.cell_hi, idx.cell_val, idx.cand,
      idx.range_lo)
    cid = jnp.where(bid >= 0, idx.block_parent[jnp.clip(bid, 0, None)], -1)
    sid = jnp.where(cid >= 0, idx.county_parent[jnp.clip(cid, 0, None)], -1)
    return sid, cid, bid, {"n_boundary": n_need, "n_pip": n_pip,
                           "overflow": overflow}
