"""The paper's "simple" approach (§III), TPU-adapted.

Three-stage cascade: state -> county -> block.  At each level a point is
tested against the bounding boxes of the *children of its current parent*
(the hierarchy is what keeps candidate sets tiny).  Points inside exactly one
bbox are resolved for free (paper: ~80 %); the rest go through the
crossing-number kernel against at most ``k_cand`` candidate polygons.

TPU adaptation vs the Matlab/GraphBLAS original (see DESIGN.md §2):
  * sparse bbox outer products  -> dense Pallas tiles (`kernels/bbox.py`);
  * per-state `find()` loops    -> fixed-capacity compaction: unresolved
    points are argsort-compacted into a static-shape buffer, resolved with
    the gathered-PIP kernel, and scattered back.  Capacity overflow is
    *counted and reported* (stats.overflow) rather than silently dropped —
    callers either size capacities generously or re-run stragglers on host.
  * everything is a single jit-able function of device arrays -> it fuses
    into data pipelines and shards over ("pod","data") by construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compact import compact_indices
from repro.core.geometry import CensusMap, children_tables
from repro.kernels import ops, ref


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimpleIndex:
    """Device-resident flattened census hierarchy.

    bbox tables carry one trailing sentinel row (empty box) so parent id -1
    gathers a never-matching candidate; children tables carry a sentinel row
    of -1s for the same reason.
    """

    state_bbox: Any      # [Ns+1, 4] f32 (sentinel last)
    county_bbox: Any     # [Nc+1, 4]
    block_bbox: Any      # [Nb+1, 4]
    state_edges: Any     # [Ns, Es, 4] f32
    county_edges: Any    # [Nc, Ec, 4]
    block_edges: Any     # [Nb, Eb, 4]
    county_children: Any # [Ns+1, Cc] i32, -1 padded
    block_children: Any  # [Nc+1, Cb] i32
    block_parent: Any    # [Nb] i32 (county of each block)
    county_parent: Any   # [Nc] i32

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def from_census(cls, census: CensusMap, pad_children: int = 128):
        def bbox_with_sentinel(soup):
            bb = np.concatenate(
                [soup.bbox, np.array([[1.0, 0.0, 1.0, 0.0]], np.float32)], 0)
            return jnp.asarray(bb)

        def edges(soup):
            return jnp.asarray(ops.edges_from_soup_np(soup.verts))

        def children(soup, n_parents):
            ids, _ = children_tables(soup, n_parents)
            sentinel = np.full((1, ids.shape[1]), -1, np.int32)
            return jnp.asarray(np.concatenate([ids, sentinel], 0))

        return cls(
            state_bbox=bbox_with_sentinel(census.states),
            county_bbox=bbox_with_sentinel(census.counties),
            block_bbox=bbox_with_sentinel(census.blocks),
            state_edges=edges(census.states),
            county_edges=edges(census.counties),
            block_edges=edges(census.blocks),
            county_children=children(census.counties, census.states.n_poly),
            block_children=children(census.blocks, census.counties.n_poly),
            block_parent=jnp.asarray(census.blocks.parent),
            county_parent=jnp.asarray(census.counties.parent),
        )


@dataclasses.dataclass(frozen=True)
class SimpleConfig:
    """Static cascade knobs (part of the jit cache key)."""

    k_cand: int = 4          # max PIP candidates per point per level
    cap_state: float = 0.25  # compaction capacity as a fraction of N
    cap_county: float = 0.5
    cap_block: float = 0.5
    backend: str | None = None  # kernel backend override


def _first_k_candidates(mask: jnp.ndarray, k: int) -> jnp.ndarray:
    """Slots of the first k set bits per row of a [R, C] mask (else -1)."""
    c = mask.shape[1]
    iota = jnp.arange(c, dtype=jnp.int32)[None, :]
    score = jnp.where(mask != 0, c - iota, 0)       # larger = earlier slot
    vals, _ = jax.lax.top_k(score, k)
    return jnp.where(vals > 0, c - vals, -1)        # [R, k] slot indices


def _compact_indices(unresolved: jnp.ndarray, cap: int):
    """Indices of unresolved points, compacted to a static-size buffer
    (O(N) cumsum compaction; see core/compact.py).  Returns (idx, valid)."""
    return compact_indices(unresolved, cap)


def _resolve_level(points, idx, cand_ids, edges_table, unresolved, backend):
    """PIP-resolve compacted points against their candidate polygon ids.

    Args:
      points:      [R, 2] compacted points.
      idx:         [R] original indices (for stats only; unused here).
      cand_ids:    [R, K] candidate polygon ids (-1 = none).
      edges_table: [P, E, 4] level edge table.
      unresolved:  [R] bool — rows actually needing resolution.
    Returns:
      assign [R] i32 (-1 if nothing matched), n_pip_tests [] i32.
    """
    k = cand_ids.shape[1]
    assign = jnp.full(points.shape[0], -1, jnp.int32)
    n_tests = jnp.zeros((), jnp.int32)
    for kk in range(k):
        pid = cand_ids[:, kk]
        active = unresolved & (pid >= 0) & (assign < 0)
        edges = edges_table[jnp.clip(pid, 0, edges_table.shape[0] - 1)]
        inside = ops.pip_gathered(points, edges, backend=backend)
        assign = jnp.where(active & inside, pid, assign)
        n_tests = n_tests + jnp.sum(active.astype(jnp.int32))
    return assign, n_tests


def _level_pass(points, parent, children_table, bbox_table, edges_table,
                cap: int, k_cand: int, backend):
    """One hierarchy level: bbox count/select then PIP fallback.

    Args:
      points: [N, 2]; parent: [N] i32 id into the *parent* level (-1 = lost).
    Returns:
      (assign [N] i32 child ids, stats dict)
    """
    n = points.shape[0]
    n_parents = children_table.shape[0] - 1
    parent_ix = jnp.where(parent >= 0, parent, n_parents)      # sentinel row
    cand = children_table[parent_ix]                            # [N, C]
    cand_ix = jnp.where(cand >= 0, cand, bbox_table.shape[0] - 1)
    boxes = bbox_table[cand_ix]                                 # [N, C, 4]
    cnt, sel = ops.bbox_count_select(points, boxes, backend=backend)
    assign = jnp.where(sel >= 0,
                       jnp.take_along_axis(cand, sel[:, None].clip(0),
                                           axis=1)[:, 0],
                       -1)
    unresolved = cnt > 1
    # --- fixed-capacity compaction + PIP fallback ---
    idx, slot_ok = _compact_indices(unresolved, cap)
    sub_pts = points[idx]
    sub_unres = unresolved[idx] & slot_ok
    sub_mask = ref.bbox_mask_gathered(sub_pts, boxes[idx])      # [R, C] i8
    cand_slots = _first_k_candidates(sub_mask, k_cand)          # [R, K]
    sub_cand = jnp.take_along_axis(cand[idx], cand_slots.clip(0), axis=1)
    sub_cand = jnp.where(cand_slots >= 0, sub_cand, -1)
    resolved, n_pip = _resolve_level(sub_pts, idx, sub_cand, edges_table,
                                     sub_unres, backend)
    # Points whose PIP found nothing keep the bbox select (boundary grazing).
    new_val = jnp.where(sub_unres,
                        jnp.where(resolved >= 0, resolved, assign[idx]),
                        assign[idx])
    assign = assign.at[idx].set(new_val)
    overflow = jnp.sum(unresolved.astype(jnp.int32)) - \
        jnp.sum(sub_unres.astype(jnp.int32))
    stats = {"n_multi": jnp.sum(unresolved.astype(jnp.int32)),
             "n_pip": n_pip, "overflow": overflow}
    return assign, stats


def _assign_impl(index: SimpleIndex, points: jnp.ndarray, cfg: SimpleConfig):
    n = points.shape[0]
    backend = cfg.backend

    # --- Stage 1: states (flat bbox mask over all states) ---
    ns = index.state_bbox.shape[0] - 1
    mask = ops.bbox_mask(points, index.state_bbox[:ns], backend=backend)
    cnt = jnp.sum(mask.astype(jnp.int32), axis=1)
    iota = jnp.arange(ns, dtype=jnp.int32)[None, :]
    sid = jnp.max(jnp.where(mask != 0, iota, -1), axis=1)
    unresolved = cnt > 1
    cap1 = min(_round_up(max(int(n * cfg.cap_state), 256), 256), n)
    idx, slot_ok = _compact_indices(unresolved, cap1)
    sub_unres = unresolved[idx] & slot_ok
    cand_slots = _first_k_candidates(mask[idx], cfg.k_cand)
    resolved, n_pip1 = _resolve_level(points[idx], idx, cand_slots,
                                      index.state_edges, sub_unres,
                                      backend)
    new_sid = jnp.where(sub_unres,
                        jnp.where(resolved >= 0, resolved, sid[idx]),
                        sid[idx])
    sid = sid.at[idx].set(new_sid)
    s_stats = {"n_multi": jnp.sum(unresolved.astype(jnp.int32)),
               "n_pip": n_pip1,
               "overflow": jnp.sum(unresolved.astype(jnp.int32))
               - jnp.sum(sub_unres.astype(jnp.int32))}

    # --- Stage 2: counties of the point's state ---
    cap2 = min(_round_up(max(int(n * cfg.cap_county), 256), 256), n)
    cid, c_stats = _level_pass(points, sid, index.county_children,
                               index.county_bbox, index.county_edges,
                               cap2, cfg.k_cand, backend)

    # --- Stage 3: blocks of the point's county ---
    cap3 = min(_round_up(max(int(n * cfg.cap_block), 256), 256), n)
    bid, b_stats = _level_pass(points, cid, index.block_children,
                               index.block_bbox, index.block_edges,
                               cap3, cfg.k_cand, backend)

    stats = {"state": s_stats, "county": c_stats, "block": b_stats}
    return sid, cid, bid, stats


@functools.partial(jax.jit, static_argnames=("cfg",))
def assign_simple(index: SimpleIndex, points: jnp.ndarray,
                  cfg: SimpleConfig = SimpleConfig()):
    """Map [N, 2] (lon, lat) points to (state, county, block) ids + stats."""
    return _assign_impl(index, points, cfg)
