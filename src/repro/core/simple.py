"""The paper's "simple" approach (§III), TPU-adapted.

Three-stage cascade: state -> county -> block.  At each level a point is
tested against the bounding boxes of the *children of its current parent*
(the hierarchy is what keeps candidate sets tiny).  Points inside exactly one
bbox are resolved for free (paper: ~80 %); the rest go through the
crossing-number kernel against at most ``k_cand`` candidate polygons.

TPU adaptation vs the Matlab/GraphBLAS original (see DESIGN.md §2):
  * sparse bbox outer products  -> dense Pallas tiles (`kernels/bbox.py`);
  * per-state `find()` loops    -> fixed-capacity compaction: unresolved
    points are compacted into a static-shape buffer (O(N) cumsum;
    core/compact.py), resolved with the gathered-PIP kernel, and scattered
    back — all via the shared resolution core in core/resolve.py.  Capacity
    overflow is *counted and reported* (stats.overflow) rather than
    silently dropped — callers either size capacities generously or re-run
    stragglers on host.
  * everything is a single jit-able function of device arrays -> it fuses
    into data pipelines and shards over ("pod","data") by construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compact import capacity_for
from repro.core.geometry import CensusMap, children_tables
from repro.core.resolve import first_k_candidates, resolve_candidates
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimpleIndex:
    """Device-resident flattened census hierarchy.

    bbox tables carry one trailing sentinel row (empty box) so parent id -1
    gathers a never-matching candidate; children tables carry a sentinel row
    of -1s for the same reason.
    """

    state_bbox: Any      # [Ns+1, 4] f32 (sentinel last)
    county_bbox: Any     # [Nc+1, 4]
    block_bbox: Any      # [Nb+1, 4]
    state_edges: Any     # [Ns, Es, 4] f32
    county_edges: Any    # [Nc, Ec, 4]
    block_edges: Any     # [Nb, Eb, 4]
    county_children: Any # [Ns+1, Cc] i32, -1 padded
    block_children: Any  # [Nc+1, Cb] i32
    block_parent: Any    # [Nb] i32 (county of each block)
    county_parent: Any   # [Nc] i32
    state_pool: Any = None   # blocked-CSR EdgePools mirroring the three
    county_pool: Any = None  # *_edges tables (fused gather-PIP path;
    block_pool: Any = None   # SimpleConfig.fused)

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def from_census(cls, census: CensusMap, pad_children: int = 128,
                    with_pools: bool = False):
        """``with_pools`` additionally builds the blocked-CSR edge pools
        the fused gather-PIP path needs (SimpleConfig.fused); off by
        default so legacy callers pay neither the host build nor the
        device copies."""
        def bbox_with_sentinel(soup):
            bb = np.concatenate(
                [soup.bbox, np.array([[1.0, 0.0, 1.0, 0.0]], np.float32)], 0)
            return jnp.asarray(bb)

        def edges(soup):
            return ops.edges_from_soup_np(soup.verts)

        def children(soup, n_parents):
            ids, _ = children_tables(soup, n_parents)
            sentinel = np.full((1, ids.shape[1]), -1, np.int32)
            return jnp.asarray(np.concatenate([ids, sentinel], 0))

        se = edges(census.states)
        ce = edges(census.counties)
        be = edges(census.blocks)
        return cls(
            state_bbox=bbox_with_sentinel(census.states),
            county_bbox=bbox_with_sentinel(census.counties),
            block_bbox=bbox_with_sentinel(census.blocks),
            state_edges=jnp.asarray(se),
            county_edges=jnp.asarray(ce),
            block_edges=jnp.asarray(be),
            county_children=children(census.counties, census.states.n_poly),
            block_children=children(census.blocks, census.counties.n_poly),
            block_parent=jnp.asarray(census.blocks.parent),
            county_parent=jnp.asarray(census.counties.parent),
            state_pool=ops.build_edge_pool(se) if with_pools else None,
            county_pool=ops.build_edge_pool(ce) if with_pools else None,
            block_pool=ops.build_edge_pool(be) if with_pools else None,
        )


@dataclasses.dataclass(frozen=True)
class SimpleConfig:
    """Static cascade knobs (part of the jit cache key)."""

    k_cand: int = 4          # max PIP candidates per point per level
    cap_state: float = 0.25  # compaction capacity as a fraction of N
    cap_county: float = 0.5
    cap_block: float = 0.5
    backend: str | None = None  # kernel backend override
    fused: bool = False      # fused gather-PIP kernel (the *_pool tables)
    #                          instead of gather + pip_gathered per level


def _level_stats(rs) -> dict:
    """Legacy per-level stats dict from a ResolveStats."""
    return {"n_multi": rs.n_need, "n_pip": rs.n_pip,
            "overflow": rs.overflow, "phase2_miss": rs.phase2_miss}


def _level_pass(points, parent, children_table, bbox_table, edges_table,
                cap: int, k_cand: int, backend, edge_pool=None):
    """One hierarchy level: bbox count/select, then the shared resolution
    core for points in more than one child bbox.

    Args:
      points: [N, 2]; parent: [N] i32 id into the *parent* level (-1 = lost).
    Returns:
      (assign [N] i32 child ids, stats dict)
    """
    n_parents = children_table.shape[0] - 1
    parent_ix = jnp.where(parent >= 0, parent, n_parents)      # sentinel row
    cand = children_table[parent_ix]                            # [N, C]
    cand_ix = jnp.where(cand >= 0, cand, bbox_table.shape[0] - 1)
    boxes = bbox_table[cand_ix]                                 # [N, C, 4]
    cnt, sel = ops.bbox_count_select(points, boxes, backend=backend)
    assign = jnp.where(sel >= 0,
                       jnp.take_along_axis(cand, sel[:, None].clip(0),
                                           axis=1)[:, 0],
                       -1)
    unresolved = cnt > 1

    def cand_fn(idx, sub_pts):
        # Candidate gathering deferred to the compacted buffer: recompute
        # the per-box mask only for the rows that actually need PIP.
        sub_mask = ops.bbox_mask_gathered(sub_pts, boxes[idx],
                                          backend=backend)      # [R, C] i8
        slots = first_k_candidates(sub_mask, k_cand)            # [R, K]
        sub_cand = jnp.take_along_axis(cand[idx], slots.clip(0), axis=1)
        return jnp.where(slots >= 0, sub_cand, -1)

    # Points whose PIP finds nothing keep the bbox select (fallback="prior"
    # — boundary grazing).
    assign, rs = resolve_candidates(points, cand_fn, edges_table,
                                    unresolved, cap=cap, backend=backend,
                                    prior=assign, fallback="prior",
                                    edge_pool=edge_pool)
    return assign, _level_stats(rs)


def cascade_assign(index: SimpleIndex, points: jnp.ndarray,
                   cfg: SimpleConfig):
    """The three-level cascade as a plain traceable function (no jit) so
    other strategies — notably the engine's hybrid mode — can embed it."""
    n = points.shape[0]
    backend = cfg.backend
    # Defense in depth for direct callers — engine-built paths fail this
    # at construction instead (registry validation, DESIGN.md §11).
    if cfg.fused and index.state_pool is None:
        raise ValueError("SimpleConfig.fused needs an index built with "
                         "with_pools=True (SimpleIndex.from_census)")
    pools = ((index.state_pool, index.county_pool, index.block_pool)
             if cfg.fused else (None, None, None))

    # --- Stage 1: states (flat bbox mask over all states) ---
    ns = index.state_bbox.shape[0] - 1
    mask = ops.bbox_mask(points, index.state_bbox[:ns], backend=backend)
    cnt = jnp.sum(mask.astype(jnp.int32), axis=1)
    iota = jnp.arange(ns, dtype=jnp.int32)[None, :]
    sid = jnp.max(jnp.where(mask != 0, iota, -1), axis=1)
    unresolved = cnt > 1
    # State candidates ARE bbox slots, so candidate selection is just
    # first_k over the flat mask rows.
    sid, rs1 = resolve_candidates(
        points, lambda idx, _: first_k_candidates(mask[idx], cfg.k_cand),
        index.state_edges, unresolved,
        cap=capacity_for(n, cfg.cap_state), backend=backend,
        prior=sid, fallback="prior", edge_pool=pools[0])

    # --- Stage 2: counties of the point's state ---
    cid, c_stats = _level_pass(points, sid, index.county_children,
                               index.county_bbox, index.county_edges,
                               capacity_for(n, cfg.cap_county),
                               cfg.k_cand, backend, edge_pool=pools[1])

    # --- Stage 3: blocks of the point's county ---
    bid, b_stats = _level_pass(points, cid, index.block_children,
                               index.block_bbox, index.block_edges,
                               capacity_for(n, cfg.cap_block),
                               cfg.k_cand, backend, edge_pool=pools[2])

    stats = {"state": _level_stats(rs1), "county": c_stats,
             "block": b_stats}
    return sid, cid, bid, stats


@functools.partial(jax.jit, static_argnames=("cfg",))
def assign_simple(index: SimpleIndex, points: jnp.ndarray,
                  cfg: SimpleConfig = SimpleConfig()):
    """Map [N, 2] (lon, lat) points to (state, county, block) ids + stats."""
    return cascade_assign(index, points, cfg)
