"""Version-adaptive jax compatibility layer (DESIGN.md §12).

One import surface for every mesh / shard_map consumer in the repo.  The
installed jax may be 0.4.x (no ``jax.sharding.get_abstract_mesh``, no
``jax.set_mesh``, no ``jax.shard_map``) or 0.5+ (all three public); the
model stack and the geo engine's sharded strategies must run on both
without touching version-specific symbols themselves.

Semantics:

  * ``use_mesh(mesh)`` — context manager activating ``mesh``.  On new jax
    it is exactly ``jax.set_mesh``.  On 0.4.x it records the mesh in a
    context-local **ambient mesh** (a ``ContextVar``, so it nests and is
    async/thread-safe) *and* enters the ``Mesh`` context manager, so both
    ``shard_act``-style consumers and legacy bare-``PartitionSpec`` code
    see it.
  * ``get_abstract_mesh()`` — the active mesh or ``None``.  New jax:
    ``jax.sharding.get_abstract_mesh()`` (empty mesh normalized to
    ``None``).  Old jax: the ambient mesh, falling back to the
    resource-env physical mesh so raw ``with Mesh(...):`` scopes (code
    that never went through ``use_mesh``) still resolve.
  * ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
    — ``jax.shard_map`` on new jax; on 0.4.x the experimental
    ``shard_map`` with ``check_vma`` translated to its old name
    ``check_rep``.  ``mesh=None`` resolves the ambient mesh.
  * ``with_sharding_constraint(x, spec, mesh=None)`` — activation
    constraint that works on both: a concrete ``Mesh`` is wrapped into a
    ``NamedSharding`` (0.4.x has no abstract-mesh constraint resolution),
    an abstract mesh (new jax) uses the bare ``PartitionSpec``.

Import this module — never ``jax.sharding.get_abstract_mesh`` /
``jax.set_mesh`` / ``jax.shard_map`` directly — from any code that must
run on the pinned 0.4.x toolchain (ROADMAP: supported-jax matrix).

CAVEAT (0.4.x only): the ambient mesh is read at *trace* time and is NOT
part of jit's cache key (new jax threads the abstract mesh through the
tracing context precisely for this).  A jitted callable traced under one
mesh scope and re-invoked under another (or under none) with the same
avals silently reuses the first trace's constraints.  Rule: trace inside
the ``use_mesh`` scope the executable will run under, and do not share
one jitted callable across different mesh scopes — every in-repo caller
(tests, launchers, benchmarks) follows this.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# ------------------------------------------------------------- feature probes
HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_PUBLIC_SHARD_MAP = hasattr(jax, "shard_map")

try:                                        # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                         # pragma: no cover - older jax
    AxisType = None

if HAS_PUBLIC_SHARD_MAP:                    # pragma: no cover - newer jax
    _shard_map_impl = jax.shard_map
    _VMA_KWARG = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _VMA_KWARG = "check_rep"


# ------------------------------------------------------------- ambient mesh
_ambient_mesh: ContextVar[Optional[Mesh]] = ContextVar(
    "repro_ambient_mesh", default=None)


def _resource_env_mesh() -> Optional[Mesh]:
    """The physical mesh of the active ``with Mesh(...):`` scope, if any.

    Private-API access is deliberately confined to this one function: it
    is the 0.4.x fallback for callers that entered a raw ``Mesh`` context
    manager instead of ``use_mesh``.
    """
    try:
        from jax._src import mesh as _mesh_lib  # noqa: PLC0415
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:                       # pragma: no cover - API drift
        return None
    if env_mesh is None or env_mesh.empty:
        return None
    return env_mesh


def get_abstract_mesh():
    """The active mesh, or None when no mesh scope is in effect.

    The ambient ContextVar — recorded by :func:`use_mesh` on EVERY jax
    generation, so the probes can never disagree — is consulted first;
    then ``jax.sharding.get_abstract_mesh()`` where it exists (scopes
    opened by a raw ``jax.set_mesh`` that bypassed ``use_mesh``; the
    empty mesh normalizes to None); last the 0.4.x resource-env mesh
    (raw ``with Mesh(...):`` scopes).
    """
    m = _ambient_mesh.get()
    if m is not None:
        return m
    if HAS_ABSTRACT_MESH:                   # pragma: no cover - newer jax
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    return _resource_env_mesh()


@contextlib.contextmanager
def _ambient_scope(mesh: Mesh):
    token = _ambient_mesh.set(mesh)
    try:
        if HAS_SET_MESH:                    # pragma: no cover - newer jax
            with jax.set_mesh(mesh):
                yield mesh
        else:
            # Enter the Mesh context manager so the resource env is set:
            # legacy code inside the scope may still use bare
            # PartitionSpecs (pjit in-axis-resources style) that resolve
            # against it.
            with mesh:
                yield mesh
    finally:
        _ambient_mesh.reset(token)


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for everything underneath it.

    The concrete mesh is always recorded in the ambient ContextVar
    (queried by ``models.layers.shard_act``, ``shard_map(mesh=None)``
    and :func:`concrete_mesh`); underneath that, jax >= 0.5 enters
    ``jax.set_mesh`` and 0.4.x enters the ``Mesh`` resource-env scope.
    """
    return _ambient_scope(mesh)


def concrete_mesh() -> Optional[Mesh]:
    """The active *concrete* ``Mesh`` (device-backed), or None.

    ``NamedSharding`` construction (checkpoint restore, param shardings)
    needs real devices, which the new-jax abstract mesh does not carry —
    hence ``use_mesh`` recording the concrete mesh on every version.
    """
    return _ambient_mesh.get() or _resource_env_mesh()


# ----------------------------------------------------------------- shard_map
def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` surface on every supported jax version.

    ``check_vma`` is the new-jax name for replication checking; on 0.4.x
    it is forwarded as ``check_rep``.  ``mesh=None`` resolves the ambient
    mesh (new jax resolves it natively).
    """
    if mesh is None:
        mesh = get_abstract_mesh()
        if mesh is None and not HAS_PUBLIC_SHARD_MAP:
            # New jax can still resolve mesh=None natively (set_mesh
            # scopes that bypassed use_mesh); old jax cannot.
            raise ValueError(
                "shard_map: no mesh argument and no ambient mesh active "
                "(wrap the call in repro.compat.use_mesh(...))")
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_VMA_KWARG: check_vma})


# ------------------------------------------------------------------ builders
def make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with explicit Auto axis types where supported."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,          # pragma: no cover - newer jax
                         axis_types=(AxisType.Auto,) * len(axes))


# --------------------------------------------------------------- constraints
def with_sharding_constraint(x, spec: PartitionSpec, mesh=None):
    """Activation-sharding constraint valid on both jax generations.

    ``mesh=None`` resolves the ambient mesh; no active mesh makes this a
    no-op (CPU smoke tests).  A concrete ``Mesh`` becomes a
    ``NamedSharding`` (0.4.x cannot resolve a bare PartitionSpec outside
    a resource-env scope); an abstract mesh (new jax) takes the bare
    ``PartitionSpec``, which resolves against it inside jit.
    """
    if mesh is None:
        mesh = get_abstract_mesh()
    if mesh is None:
        return x
    if isinstance(mesh, Mesh):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)  # pragma: no cover
