"""train_step / serve_step builders.

``make_train_step`` returns a pure function (params, opt, batch) ->
(params, opt, metrics) with optional gradient accumulation (scan over
microbatches), z-loss, MoE load-balance loss, and vocab-sharded logits.
``make_serve_step`` returns (params, tokens, cache) -> (next_tokens, cache).
Both are what launch/dryrun.py lowers for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import RunConfig
from repro.models.layers import act_spec
from repro.models.model import Model
from repro.optim import adamw
from repro.sharding.rules import param_shardings


def _shard(x, mesh, *parts):
    """Sharding constraint with the same policy as ``shard_act`` (one
    implementation: ``models.layers.act_spec``): part entries absent from
    the mesh are dropped (e.g. "pod" on the single-pod mesh), never
    silently ignored as a whole, and non-divisible dims replicate.
    ``mesh=None`` falls back to the ambient mesh (a no-op when none is
    active); the constraint goes through repro.compat so the step
    builders run on the pinned 0.4.x jax (DESIGN.md §12)."""
    if mesh is None:
        mesh = compat.get_abstract_mesh()
    if mesh is None:
        return x
    return compat.with_sharding_constraint(
        x, act_spec(x.shape, parts, mesh), mesh=mesh)


def cross_entropy(logits, labels, z_loss_coef: float, mesh=None):
    """Token-mean CE over vocab-sharded f32 logits.

    The gold logit is extracted with a masked reduction (iota == label)
    rather than take_along_axis: the comparison fuses into the reduce and
    partitions cleanly over the sharded vocab axis, whereas a gather on a
    sharded axis makes GSPMD replicate the [B, S, V] logits.
    """
    logits = _shard(logits, mesh, ("pod", "data"), None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = (vocab_iota == labels[..., None]).astype(logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    ce = jnp.mean(lse - gold)
    zl = z_loss_coef * jnp.mean(jnp.square(lse)) if z_loss_coef else 0.0
    return ce + zl, ce


def make_loss_fn(model: Model, run: RunConfig, mesh=None):
    cfg = model.cfg
    p_sh = param_shardings(model.specs, mesh) if mesh is not None else None

    def cast_params(params):
        """Compute-cast matrices to bf16 *while still FSDP-sharded* (pinned
        by the sharding constraint) so GSPMD's per-layer weight all-gathers
        move bf16, not f32 — halving the FSDP gather volume.  The cast's
        transpose converts bf16 grads back to f32 at the shard boundary.
        1-D params (norm scales, biases) stay f32."""
        if p_sh is None:
            return params

        def one(p, sh):
            if p.dtype == jnp.float32 and p.ndim >= 2:
                return jax.lax.with_sharding_constraint(
                    p.astype(jnp.bfloat16), sh)
            return p
        return jax.tree.map(one, params, p_sh)

    def loss_fn(params, batch):
        logits, aux = model.forward(cast_params(params), run, batch,
                                    mesh=mesh)
        loss, ce = cross_entropy(logits, batch["labels"], run.z_loss, mesh)
        metrics = {"ce": ce}
        if "lb_loss" in aux:
            loss = loss + cfg.router_aux_coef * aux["lb_loss"]
            metrics["lb_loss"] = aux["lb_loss"]
            metrics["dropped"] = aux["dropped"].astype(jnp.float32)
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, run: RunConfig, mesh=None):
    loss_fn = make_loss_fn(model, run, mesh)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    p_sh = param_shardings(model.specs, mesh) if mesh is not None else None

    def constrain_grads(grads):
        """Pin gradient shardings to the (FSDP+TP) param shardings — without
        this, scan-accumulated grads of FSDP-gathered weights stay unsharded
        over "data" and blow per-device memory."""
        if p_sh is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, p_sh)

    def train_step(params, opt: adamw.OptState, batch):
        if run.microbatch and run.microbatch > 1:
            nmb = run.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape((nmb, b // nmb) + x.shape[1:])
            mb_batch = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gacc, macc = carry
                (_, metrics), grads = grad_fn(params, mb)
                grads = constrain_grads(grads)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / nmb,
                    gacc, grads)
                macc = jax.tree.map(lambda a, m: a + m / nmb, macc, metrics)
                return (gacc, macc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"ce": 0.0, "loss": 0.0}
            if model.cfg.n_experts:
                m0.update(lb_loss=0.0, dropped=0.0)
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), mb_batch)
        else:
            (_, metrics), grads = grad_fn(params, batch)
        grads = constrain_grads(grads)

        lr = adamw.schedule(run, opt.step)
        params, opt, gnorm = adamw.update(grads, opt, params, run, lr)
        if p_sh is not None:
            # Pin the updated params and fp32 moments back to the declared
            # (FSDP+TP) layout.  Newer-jax GSPMD usually propagates this on
            # its own; on the pinned 0.4.x toolchain propagation may choose
            # a different output sharding, silently re-laying-out params
            # every step and breaking the declared-sharding invariant.
            params = jax.tree.map(jax.lax.with_sharding_constraint,
                                  params, p_sh)
            opt = opt._replace(
                m=jax.tree.map(jax.lax.with_sharding_constraint, opt.m, p_sh),
                v=jax.tree.map(jax.lax.with_sharding_constraint, opt.v, p_sh))
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt, metrics

    return train_step


def make_prefill_step(model: Model, run: RunConfig, mesh=None):
    """Forward-only step over a full sequence (the inference-prefill cell)."""

    def prefill_step(params, batch):
        logits, _ = model.forward(params, run, batch, mesh=mesh)
        # Next-token logits for the last position only.
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(model: Model, run: RunConfig, mesh=None):
    """One greedy decode step against a KV/state cache."""

    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, run, tokens, cache,
                                          mesh=mesh)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step
