"""Fault-tolerant training driver.

Designed for fleets where steps fail (preemption, flaky hosts, data blips):

  * checkpoint/restart — async checkpoints every ``ckpt_every`` steps; any
    step exception restores the latest checkpoint and resumes.  The data
    pipeline is stateless (batch = f(seed, step)) so the resume is bitwise.
    When training under a mesh (``repro.compat.use_mesh`` scopes), pass
    ``shardings`` — or rely on the restore path re-placing each leaf onto
    the live params' own committed shardings — so a restart keeps the
    FSDP/TP layout instead of concentrating state on one device.
  * bounded retries  — ``max_restarts`` guards against crash loops.
  * straggler watch  — per-step wall times are tracked; a step slower than
    ``straggler_factor`` x the running median is counted and surfaced via
    ``on_straggler`` (on a real fleet this triggers hot-spares / re-slicing;
    the hook keeps the policy pluggable).
  * failure injection — ``fail_at`` raises inside given steps (once each),
    which is how the restart path is tested.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 5
    straggler_factor: float = 3.0
    log_every: int = 10


class InjectedFailure(RuntimeError):
    pass


def train_loop(train_step, params, opt, source, dcfg: DriverConfig,
               shardings=None, fail_at: Optional[set] = None,
               on_straggler: Optional[Callable[[int, float], None]] = None,
               log: Callable[[str], None] = print):
    """Run to dcfg.total_steps with checkpoint/restart. Returns
    (params, opt, history dict)."""
    mgr = CheckpointManager(dcfg.ckpt_dir, keep=dcfg.keep)
    fail_at = set(fail_at or ())
    fired: set = set()
    restarts = 0
    step_times: list[float] = []
    hist = {"loss": [], "restarts": 0, "stragglers": 0, "steps_run": 0}

    start = mgr.latest_step()
    step = 0
    if start is not None:
        state = mgr.restore(start, {"params": params, "opt": opt},
                            shardings)
        params, opt = state["params"], state["opt"]
        step = start
        log(f"[driver] resumed from checkpoint step {start}")
    else:
        # Initial checkpoint: a failure before the first periodic save must
        # restart from the true initial state, not silently re-train on
        # already-stepped params.
        mgr.save(0, {"params": params, "opt": opt})
        mgr.wait()

    while step < dcfg.total_steps:
        try:
            batch = source.batch_at(step)
            t0 = time.perf_counter()
            if step in fail_at and step not in fired:
                fired.add(step)
                raise InjectedFailure(f"injected failure at step {step}")
            params, opt, metrics = train_step(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            hist["steps_run"] += 1

            # Straggler detection on the running median.
            if len(step_times) >= 5:
                med = float(np.median(step_times[-50:]))
                if dt > dcfg.straggler_factor * med:
                    hist["stragglers"] += 1
                    if on_straggler:
                        on_straggler(step, dt / med)
                    log(f"[driver] straggler: step {step} took {dt:.2f}s "
                        f"({dt/med:.1f}x median)")
            step_times.append(dt)

            loss = float(metrics["loss"])
            hist["loss"].append(loss)
            if step % dcfg.log_every == 0:
                log(f"[driver] step {step}: loss={loss:.4f} "
                    f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            step += 1
            if step % dcfg.ckpt_every == 0 or step == dcfg.total_steps:
                mgr.save(step, {"params": params, "opt": opt})
        except Exception as e:  # noqa: BLE001 — the whole point
            restarts += 1
            hist["restarts"] = restarts
            log(f"[driver] step {step} failed ({e!r}); "
                f"restart {restarts}/{dcfg.max_restarts}")
            if restarts > dcfg.max_restarts:
                raise
            latest = mgr.latest_step()
            if latest is not None:
                state = mgr.restore(latest, {"params": params, "opt": opt},
                                    shardings)
                params, opt = state["params"], state["opt"]
                step = latest
                log(f"[driver] restored step {latest}")
            else:
                step = 0
    mgr.wait()
    return params, opt, hist
