"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Parameters declare logical axes in their ParamSpec; these rules resolve them
against whatever mesh is in use.  A rule is silently dropped (replicated)
when the dimension is not divisible by the assigned mesh extent — e.g. GQA
kv-head counts smaller than the model axis.

Weight strategy (DESIGN.md §5):
  tensor-parallel axes (vocab, heads, mlp, experts, q_lora) -> "model"
  FSDP axis (embed / the non-TP matmul dim)                 -> "data"
Activations are sharded only on batch (("pod","data")) via constraints.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro import compat
from repro.models.module import P

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "q_lora": ("model",),
    "embed": ("data",),          # FSDP / ZeRO-3 weight sharding
    "moe_mlp": (),
    "kv_lora": (),
    "layers": (),
    "groups": (),
}

BATCH_AXES = ("pod", "data")


def mesh_extent(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(math.prod(mesh.shape[a] for a in axes))


def spec_pspec(p: P, mesh: Mesh, rules=None) -> PartitionSpec:
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    parts = []
    for dim, ax in zip(p.shape, p.axes):
        assign = tuple(rules.get(ax, ())) if ax else ()
        assign = tuple(a for a in assign
                       if a in mesh.axis_names and a not in used)
        if assign and mesh_extent(mesh, assign) > 1 \
                and dim % mesh_extent(mesh, assign) == 0:
            parts.append(assign if len(assign) > 1 else assign[0])
            used.update(assign)
        else:
            parts.append(None)
    return PartitionSpec(*parts)


def param_shardings(specs, mesh: Optional[Mesh] = None, rules=None):
    """NamedSharding tree for a spec tree.

    ``mesh=None`` resolves the ambient *concrete* mesh (the scope opened
    by ``repro.compat.use_mesh`` — NamedSharding needs real devices, so
    an abstract mesh alone is not enough); no active mesh is an error
    rather than a silent replication.
    """
    if mesh is None:
        mesh = compat.concrete_mesh()
        if mesh is None:
            raise ValueError(
                "param_shardings: no mesh argument and no ambient mesh "
                "active (wrap the call in repro.compat.use_mesh(...))")
    return jax.tree.map(
        lambda p: NamedSharding(mesh, spec_pspec(p, mesh, rules)), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh, batch: int, ndim: int) -> PartitionSpec:
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    if not axes or batch % mesh_extent(mesh, axes) != 0:
        # Try the data axis alone before giving up.
        axes = tuple(a for a in ("data",) if a in mesh.axis_names)
        if not axes or batch % mesh_extent(mesh, axes) != 0:
            return PartitionSpec(*([None] * ndim))
    return PartitionSpec(axes if len(axes) > 1 else axes[0],
                         *([None] * (ndim - 1)))


def input_shardings(mesh: Mesh, batch_specs) -> dict:
    """Shardings for a train/prefill input tree: batch on ("pod","data")."""
    def one(s):
        return NamedSharding(mesh, batch_pspec(mesh, s.shape[0], len(s.shape)))
    return jax.tree.map(one, batch_specs)


# KV-cache leaves that carry kv-heads on axis -2.
_KV_KEYS = ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v",
            "dense_k", "dense_v", "img_k", "img_v")


def cache_shardings(mesh: Mesh, cache_specs, batch: int):
    """Shardings for a decode cache tree.

    Batch: the first axis whose size equals ``batch`` goes on
    ("pod","data").  KV caches additionally shard kv-heads (axis -2) on
    "model"; SSM/xLSTM state tensors shard their head axis on "model" when
    divisible.  This keeps the 500k-context caches within per-chip HBM.
    """
    model = mesh.shape.get("model", 1)
    dp = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    dp_size = mesh_extent(mesh, dp) if dp else 1

    def one(path, s):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        parts: list = [None] * len(s.shape)
        for i, d in enumerate(s.shape):
            if d == batch and dp and batch % dp_size == 0:
                parts[i] = dp if len(dp) > 1 else dp[0]
                break
        if key in _KV_KEYS and len(s.shape) >= 4 \
                and s.shape[-2] % model == 0 and model > 1:
            parts[-2] = "model"
        elif key in ("S", "C", "conv") and len(s.shape) >= 4 and model > 1:
            # ssm state [.., B, H, N, P] / conv [.., B, K-1, C] — shard the
            # widest trailing axis divisible by model.
            for i in range(len(s.shape) - 1, 1, -1):
                if parts[i] is None and s.shape[i] % model == 0 \
                        and s.shape[i] >= model:
                    parts[i] = "model"
                    break
        return NamedSharding(mesh, PartitionSpec(*parts))

    return jax.tree_util.tree_map_with_path(one, cache_specs)
