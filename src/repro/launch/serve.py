"""Serving launcher: prefill a batch of prompts, then decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.configs.base import RunConfig
from repro.models.model import build_model
from repro.models.module import init_params
from repro.runtime.steps import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    run = RunConfig(remat="none", attn_chunk_q=min(128, args.prompt_len),
                    attn_chunk_kv=min(128, args.prompt_len))
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    serve_step = jax.jit(make_serve_step(model, run))

    t0 = time.perf_counter()
    if model.prefill is not None:
        logits, cache = jax.jit(
            lambda p, t: model.prefill(p, run, t, max_len))(params, prompts)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    else:
        cache = model.init_cache(args.batch, max_len)
        tok = prompts[:, :1]
        for t in range(args.prompt_len):
            tok, cache = serve_step(params, prompts[:, t:t + 1], cache)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        tok, cache = serve_step(params, tok, cache)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f}ms; {args.gen - 1} decode steps in "
          f"{t_decode*1e3:.0f}ms "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("[serve] sample generation (first row):", gen[0][:12], "...")


if __name__ == "__main__":
    main()
