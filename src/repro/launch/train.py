"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck

Runs the fault-tolerant driver (checkpoint/restart, straggler watch) on the
current backend.  On a real TPU fleet the same entry point runs under
multi-host jax.distributed; XLA latency-hiding flags for compute/comm
overlap are applied here (launcher-level, per DESIGN.md §5).
"""
import os

# Compute/communication overlap: enable XLA's latency-hiding scheduler on
# TPU (no-op on CPU).  Must be set before jax import.
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true")

import argparse
import contextlib

import jax

from repro.compat import use_mesh
from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.configs.base import RunConfig
from repro.data.pipeline import make_source
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.models.module import init_params, param_count
from repro.optim import adamw
from repro.runtime.driver import DriverConfig, train_loop
from repro.runtime.steps import make_train_step
from repro.sharding.rules import param_shardings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=("cosine", "wsd", "const"))
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="none",
                    choices=("none", "dots", "full"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--geo-enrich", action="store_true",
                    help="join synthetic locations onto census blocks in "
                         "the pipeline (the paper's technique)")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    # MiniCPM trains with WSD (its signature feature).
    sched = "wsd" if args.arch == "minicpm-2b" else args.schedule
    run = RunConfig(remat=args.remat, learning_rate=args.lr,
                    schedule=sched, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 1),
                    microbatch=args.microbatch,
                    attn_chunk_q=min(128, args.seq),
                    attn_chunk_kv=min(128, args.seq),
                    ssm_chunk=min(64, args.seq), seed=args.seed)

    model = build_model(cfg)
    params = init_params(model.specs, jax.random.key(args.seed))
    # Data-parallel mesh over every local device, activated through the
    # compat layer so the same entry point runs on 0.4.x and 0.5+ jax
    # (DESIGN.md §12).  Single-device hosts (the CPU container) keep the
    # exact unsharded path.
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    print(f"[train] {cfg.name}: {param_count(model.specs):,} params, "
          f"{n_dev} devices" + (f", mesh {dict(mesh.shape)}" if mesh else ""))

    geo = None
    if args.geo_enrich:
        from repro.core.cells import build_cell_covering
        from repro.core.fast import FastConfig, FastIndex
        from repro.core.synth import build_synth_census
        sc = build_synth_census(seed=1)
        cov = build_cell_covering(sc.census, max_level=8)
        geo = (FastIndex.from_covering(cov, sc.census, gbits=4),
               FastConfig(mode="approx"))
        print(f"[train] geo enrichment on: {len(cov.lo)} cells")

    class Shape:
        global_batch = args.batch
        seq_len = args.seq
    src = make_source(cfg, Shape, seed=args.seed, geo=geo)

    step_fn = jax.jit(make_train_step(model, run, mesh))
    dcfg = DriverConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir)
    with use_mesh(mesh) if mesh is not None else contextlib.nullcontext():
        if mesh is not None:
            # FSDP-place params before the first step; the optimizer state
            # inherits the layout (and the checkpoint restore path re-places
            # onto it after a crash — see checkpoint/manager.restore).
            params = jax.device_put(params, param_shardings(model.specs,
                                                            mesh))
        opt = adamw.init(params)
        params, opt, hist = train_loop(step_fn, params, opt, src, dcfg)
    print(f"[train] done: loss {hist['loss'][0]:.4f} -> "
          f"{hist['loss'][-1]:.4f}, {hist['steps_run']} steps, "
          f"{hist['restarts']} restarts, {hist['stragglers']} stragglers")


if __name__ == "__main__":
    main()
