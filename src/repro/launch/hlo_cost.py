"""HLO cost walker with loop multipliers.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body exactly ONCE
(verified empirically), which silently drops ~n_layers x the FLOPs of any
scan-over-layers model.  This walker parses the *partitioned, optimized*
HLO text, recursing through while bodies with their ``known_trip_count``
multipliers:

  flops       — 2 * prod(result_dims) * prod(contracting_dims) per dot
  bytes       — 2 x result bytes per op with result >= 1 MiB (each
                materialized buffer is written once and read ~once; slicing
                ops count only the slice).  Operand fan-out is deliberately
                not multiple-counted, and sub-MiB intermediates are treated
                as VMEM/register-resident on the TPU target.
  collectives — result bytes per collective op type

All values are per-device (the partitioned module is per-partition).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_ARRAY_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8"
                       r"|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    """Total bytes of all arrays in a (possibly tuple) type string."""
    return sum(_shape_elems(m.group(2)) * _DTYPE_BYTES[m.group(1)]
               for m in _ARRAY_RE.finditer(type_str))


def _type_dims(type_str: str):
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class OpLine:
    name: str
    type_str: str
    opcode: str
    rest: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},\s/]+?))\s+"
    r"([\w\-]+)\((.*)$")


def parse_hlo(text: str):
    """-> (computations: {name: [OpLine]}, shapes: {op_name: type_str})."""
    comps: dict[str, list[OpLine]] = {}
    shapes: dict[str, str] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", s)
        if m and not s.startswith("//") and "=" not in s.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            if s.startswith("ENTRY") or " ENTRY " in line:
                comps["__entry__"] = comps[cur]
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(s)
        if mi:
            op = OpLine(name=mi.group(1), type_str=mi.group(2).strip(),
                        opcode=mi.group(3), rest=mi.group(4))
            comps[cur].append(op)
            shapes[op.name] = op.type_str
        else:
            # parameter declarations inside computation headers etc.
            mp = re.match(r"^%?([\w.\-]+)\s*=\s*(.+?)\s+parameter\(", s)
            if mp:
                shapes[mp.group(1)] = mp.group(2)
    return comps, shapes


def _dot_flops(op: OpLine, shapes: dict) -> float:
    out = _type_dims(op.type_str)
    if out is None:
        return 0.0
    # lhs operand name is the first %ref in the args.
    margs = re.findall(r"%([\w.\-]+)", op.rest)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not margs or not mc:
        return 0.0
    lhs_dims = _type_dims(shapes.get(margs[0], ""))
    if lhs_dims is None:
        return 0.0
    k = 1
    for ix in mc.group(1).split(","):
        if ix:
            k *= lhs_dims[int(ix)]
    n_out = 1
    for d in out:
        n_out *= d
    return 2.0 * n_out * k


_TRIP_RE = re.compile(r'known_trip_count.*?"n":"(\d+)"')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")


MIN_TRAFFIC_BYTES = 1 << 20     # 1 MiB: VMEM-resident below this


def cost_of(text: str, min_traffic_bytes: int = MIN_TRAFFIC_BYTES):
    """Walk the entry computation; returns dict with flops, bytes,
    collective byte totals/counts (all loop-multiplied, per device)."""
    comps, shapes = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        # Fall back: the computation with the most ops.
        entry = max(comps.values(), key=len)
    memo: dict[str, dict] = {}

    def walk(name: str) -> dict:
        if name in memo:
            return memo[name]
        tot = {"flops": 0.0, "bytes": 0.0,
               "coll": defaultdict(float), "coll_n": defaultdict(float)}
        memo[name] = tot  # cycle guard
        for op in comps.get(name, ()):
            res_bytes = _type_bytes(op.type_str)
            # Slicing ops move only the slice, not the backing buffer —
            # counting the full accumulator per scan step would overcount
            # stacked-carry traffic ~n_layers x.
            if op.opcode in ("dynamic-slice", "gather", "slice"):
                if res_bytes >= min_traffic_bytes:
                    tot["bytes"] += 2 * res_bytes      # read + write slice
                continue
            if op.opcode in ("dynamic-update-slice", "scatter"):
                margs = re.findall(r"%([\w.\-]+)", op.rest)
                upd = _type_bytes(shapes.get(margs[1], "")) \
                    if len(margs) > 1 else 0
                if upd >= min_traffic_bytes:
                    tot["bytes"] += 2 * upd            # read + write region
                continue
            if res_bytes >= min_traffic_bytes:
                arg_bytes = res_bytes          # write + one read
            else:
                res_bytes = 0 if op.opcode not in COLLECTIVES else res_bytes
                arg_bytes = 0
            if op.opcode == "while":
                trips = 1
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    trips = int(mt.group(1))
                mb = _BODY_RE.search(op.rest)
                if mb:
                    sub = walk(mb.group(1))
                    tot["flops"] += trips * sub["flops"]
                    tot["bytes"] += trips * sub["bytes"]
                    for k, v in sub["coll"].items():
                        tot["coll"][k] += trips * v
                    for k, v in sub["coll_n"].items():
                        tot["coll_n"][k] += trips * v
                continue
            if op.opcode in ("call", "conditional", "custom-call",
                             "fusion", "map", "reduce", "sort", "scatter"):
                mc = _CALLS_RE.search(op.rest)
                if mc and op.opcode in ("call", "conditional"):
                    sub = walk(mc.group(1))
                    for k in ("flops", "bytes"):
                        tot[k] += sub[k]
                    for k, v in sub["coll"].items():
                        tot["coll"][k] += v
                    for k, v in sub["coll_n"].items():
                        tot["coll_n"][k] += v
                    continue
                if op.opcode == "fusion":
                    # Count dots inside the fused computation (CPU fuses
                    # small dots), plus the fusion's real buffer traffic.
                    mfc = _CALLS_RE.search(op.rest)
                    dus_update = None
                    if mfc:
                        fops = comps.get(mfc.group(1), ())
                        for fop in fops:
                            if fop.opcode == "dot":
                                tot["flops"] += _dot_flops(fop, shapes)
                        # A fusion whose root is dynamic-update-slice writes
                        # one slice in-place; counting the whole buffer per
                        # scan step overstates ys-stacking traffic by the
                        # trip count (e.g. 4096x for a time-step scan).
                        if fops and fops[-1].opcode == "dynamic-update-slice":
                            margs = re.findall(r"%([\w.\-]+)",
                                               fops[-1].rest)
                            if len(margs) > 1:
                                dus_update = _type_bytes(
                                    shapes.get(margs[1], ""))
                    if dus_update is not None:
                        tot["bytes"] += 2 * dus_update
                    else:
                        tot["bytes"] += res_bytes + arg_bytes
                    continue
                tot["bytes"] += res_bytes + arg_bytes
                continue
            if op.opcode == "dot":
                tot["flops"] += _dot_flops(op, shapes)
                tot["bytes"] += res_bytes + arg_bytes
                continue
            for c in COLLECTIVES:
                if op.opcode == c:
                    tot["coll"][c] += res_bytes
                    tot["coll_n"][c] += 1
                    tot["bytes"] += res_bytes + arg_bytes
                    break
            else:
                if op.opcode in ("parameter", "constant", "tuple",
                                 "get-tuple-element", "bitcast"):
                    continue
                tot["bytes"] += res_bytes + arg_bytes
        return tot

    out = walk("__entry__") if "__entry__" in comps else walk(
        [k for k, v in comps.items() if v is entry][0])
    return {"flops": out["flops"], "bytes": out["bytes"],
            "collective_bytes": dict(out["coll"]),
            "collective_counts": {k: int(v)
                                  for k, v in out["coll_n"].items()}}
