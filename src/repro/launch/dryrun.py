import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below assumes 512 placeholder devices.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins — no weight is ever allocated.

For each cell we record:
  * memory_analysis()  — bytes per device (proves the cell fits),
  * cost_analysis()    — HLO FLOPs / bytes accessed (roofline numerator),
  * collective bytes   — parsed from the compiled HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute),
into a JSON report consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import RunConfig, ShapeConfig, shapes_for
from repro.launch.hlo_cost import cost_of
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models.model import build_model, input_specs
from repro.models.module import param_bytes, param_count
from repro.optim import adamw
from repro.runtime.steps import make_prefill_step, make_serve_step, \
    make_train_step
from repro.sharding.rules import cache_shardings, input_shardings, \
    param_shardings

def abstract_with_sharding(specs_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs_tree, shardings_tree)


def run_cell(arch: str, shape: ShapeConfig, mesh, run: RunConfig,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    t0 = time.monotonic()

    with use_mesh(mesh):
        p_abs = model.abstract_params()
        p_sh = param_shardings(model.specs, mesh)
        params = abstract_with_sharding(p_abs, p_sh)
        in_specs = input_specs(cfg, shape, model=model)

        if shape.kind == "train":
            opt_abs = jax.eval_shape(adamw.init, p_abs)
            opt_sh = adamw.OptState(
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                m=p_sh, v=p_sh)
            opt = abstract_with_sharding(opt_abs, opt_sh)
            batch = abstract_with_sharding(
                in_specs, input_shardings(mesh, in_specs))
            step = make_train_step(model, run, mesh)
            lowered = jax.jit(step, out_shardings=(p_sh, opt_sh, None)) \
                .lower(params, opt, batch)
        elif shape.kind == "prefill":
            batch = abstract_with_sharding(
                in_specs, input_shardings(mesh, in_specs))
            step = make_prefill_step(model, run, mesh)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            tokens = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=jax.tree.leaves(input_shardings(
                    mesh, {"t": in_specs["tokens"]}))[0])
            cache = abstract_with_sharding(
                in_specs["cache"],
                cache_shardings(mesh, in_specs["cache"],
                                shape.global_batch))
            step = make_serve_step(model, run, mesh)
            lowered = jax.jit(step).lower(params, tokens, cache)

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # XLA's cost_analysis counts while bodies ONCE; the walker multiplies
    # them by their known trip counts (launch/hlo_cost.py).
    walked = cost_of(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, (int(v) for v in
                                           mesh.devices.shape))),
        "n_devices": int(mesh.devices.size),
        "params": param_count(model.specs),
        "param_bytes": param_bytes(model.specs),
        "flops_per_device": walked["flops"],
        "bytes_accessed_per_device": walked["bytes"],
        "collective_bytes_per_device": walked["collective_bytes"],
        "collective_counts": walked["collective_counts"],
        "xla_cost_analysis": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_size": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size": int(getattr(
                mem, "generated_code_size_in_bytes", 0)),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "ok": True,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape.name} x {rec['mesh']}: "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_accessed_per_device']:.3e} "
              f"coll={sum(rec['collective_bytes_per_device'].values()):.3e}B "
              f"mem(temp)={rec['memory']['temp_size']/2**30:.2f}GiB "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s", flush=True)
        print("  memory_analysis:", {k: f"{v/2**30:.2f}GiB"
                                     for k, v in rec["memory"].items()},
              flush=True)
    return rec


def default_run(shape: ShapeConfig) -> RunConfig:
    return RunConfig(remat="full", attn_chunk_q=1024, attn_chunk_kv=1024,
                     ssm_chunk=256)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES))
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = []
    if not args.multi_pod_only:
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if not args.single_pod_only:
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    archs = list(ARCH_NAMES) if args.all or not args.arch else [args.arch]
    results = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = get_config(arch)
            shapes = shapes_for(cfg)
            if args.shape:
                shapes = [s for s in shapes if s.name == args.shape]
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, mesh, default_run(shape))
                except Exception as e:  # noqa: BLE001 — report, don't die
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape.name,
                           "mesh_name": mesh_name, "ok": False,
                           "error": repr(e)}
                rec["mesh_name"] = mesh_name
                results.append(rec)

    n_ok = sum(r["ok"] for r in results)
    print(f"\n[dryrun] {n_ok}/{len(results)} cells compiled OK")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    if n_ok != len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
