"""Production mesh builders + jax version-compat shims.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required for the
dry-run's XLA_FLAGS ordering (see launch/dryrun.py).

The compat shims (``make_mesh``, ``shard_map``, ``use_mesh``) paper over
the jax.sharding API churn between 0.4.x and 0.5+: AxisType / jax.set_mesh
/ jax.shard_map only exist on newer versions, and the geo engine's sharded
assign must run on both.
"""
from __future__ import annotations

import jax

try:                                        # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                         # pragma: no cover - older jax
    AxisType = None

try:                                        # jax >= 0.5
    shard_map = jax.shard_map
except AttributeError:                      # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def use_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh on new jax, the
    Mesh object's own context manager — which sets the resource env — on
    old)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods for the multi-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (fake CPU devices)."""
    return make_mesh(shape, axes)
