"""Production mesh builders (jax version shims live in repro.compat).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required for the
dry-run's XLA_FLAGS ordering (see launch/dryrun.py).

The compat shims (``make_mesh``, ``shard_map``, ``use_mesh``) moved to
``repro.compat`` (DESIGN.md §12) so model code can import them without
pulling in launcher modules; they are re-exported here for existing
callers — both names are the same objects.
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh, shard_map, use_mesh  # noqa: F401

__all__ = ["AxisType", "make_mesh", "shard_map", "use_mesh",
           "make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods for the multi-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (fake CPU devices)."""
    return make_mesh(shape, axes)
