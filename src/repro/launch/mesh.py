"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required for the
dry-run's XLA_FLAGS ordering (see launch/dryrun.py).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods for the multi-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (fake CPU devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
