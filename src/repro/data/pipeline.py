"""Deterministic, stateless data pipeline.

Every batch is a pure function of (seed, step) — ``batch_at(step)`` — so a
restarted job resumes *bitwise* identically with zero pipeline state in the
checkpoint, and elastic re-sharding only re-slices the same global batch.
This statelessness is the fault-tolerance contract the runtime relies on.

Two sources:
  * SyntheticLM  — reproducible token streams (zipf-ish unigram mixture with
    a per-sequence "topic" so the loss is learnable, not pure noise).
  * GeoEnriched  — wraps another source and joins each record's (lon, lat)
    onto census blocks with the paper's fast index, appending the block id
    as a feature token — the paper's technique as a first-class pipeline
    stage (core/enrich.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """batch_at(step) -> {"tokens", "labels"} (+modality stubs)."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    n_topics: int = 64

    def _key(self, step: int):
        return jax.random.fold_in(jax.random.key(self.seed), step)

    def batch_at(self, step: int) -> dict:
        k = self._key(step)
        kt, kz, kn = jax.random.split(k, 3)
        v = self.cfg.vocab
        # Per-sequence topic biases a small token subset -> learnable stats.
        topic = jax.random.randint(kz, (self.batch, 1), 0, self.n_topics)
        base = jax.random.randint(kt, (self.batch, self.seq + 1), 0, v)
        bias = (topic * 97 + jnp.arange(self.seq + 1) % 13) % v
        use_bias = jax.random.bernoulli(kn, 0.5,
                                        (self.batch, self.seq + 1))
        toks = jnp.where(use_bias, bias, base).astype(jnp.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            out["img"] = jax.random.normal(
                kz, (self.batch, self.cfg.n_img_tokens, self.cfg.d_vision),
                jnp.bfloat16)
        if self.cfg.family == "encdec":
            out["frames"] = jax.random.normal(
                kz, (self.batch, self.seq, self.cfg.d_model), jnp.bfloat16)
        return out


@dataclasses.dataclass
class GeoEnriched:
    """Wraps a source; each sequence carries a (lon, lat) and its census
    block id (via the paper's fast index) is prepended as a feature token
    ``vocab_geo_base + (block_id % n_geo_tokens)``."""

    source: SyntheticLM
    fast_index: object               # core.fast.FastIndex
    fast_cfg: object                 # core.fast.FastConfig
    points_seed: int = 7
    n_geo_tokens: int = 1024

    def batch_at(self, step: int) -> dict:
        from repro.core.fast import assign_fast
        out = dict(self.source.batch_at(step))
        b = out["tokens"].shape[0]
        k = jax.random.fold_in(jax.random.key(self.points_seed), step)
        x0, x1, y0, y1 = [float(v) for v in np.asarray(
            self.fast_index.quant)[:2]] + [0.0, 0.0]
        # Sample device-side points uniformly in the map extent.
        q = self.fast_index.quant
        n = 1 << self.fast_index.max_level
        u = jax.random.uniform(k, (b, 2))
        xy = jnp.stack([q[0] + u[:, 0] * (n / q[2]),
                        q[1] + u[:, 1] * (n / q[3])], axis=-1)
        _, _, bid, _ = assign_fast(self.fast_index, xy, self.fast_cfg)
        geo_tok = (jnp.maximum(bid, 0) % self.n_geo_tokens).astype(jnp.int32)
        tokens = out["tokens"].at[:, 0].set(
            geo_tok % self.source.cfg.vocab)
        out["tokens"] = tokens
        out["geo_block"] = bid
        return out


def make_source(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                geo: Optional[tuple] = None):
    src = SyntheticLM(cfg=cfg, batch=shape.global_batch, seq=shape.seq_len,
                      seed=seed)
    if geo is not None:
        index, fcfg = geo
        return GeoEnriched(source=src, fast_index=index, fast_cfg=fcfg)
    return src
