"""Deterministic, stateless data pipeline.

Every batch is a pure function of (seed, step) — ``batch_at(step)`` — so a
restarted job resumes *bitwise* identically with zero pipeline state in the
checkpoint, and elastic re-sharding only re-slices the same global batch.
This statelessness is the fault-tolerance contract the runtime relies on.

Two sources:
  * SyntheticLM  — reproducible token streams (zipf-ish unigram mixture with
    a per-sequence "topic" so the loss is learnable, not pure noise).
  * GeoEnriched  — wraps another source and joins each record's (lon, lat)
    onto census blocks through a GeoEngine (core/engine.py), appending the
    block id as a feature token — the paper's technique as a first-class
    pipeline stage (core/enrich.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """batch_at(step) -> {"tokens", "labels"} (+modality stubs)."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    n_topics: int = 64

    def _key(self, step: int):
        return jax.random.fold_in(jax.random.key(self.seed), step)

    def batch_at(self, step: int) -> dict:
        k = self._key(step)
        kt, kz, kn = jax.random.split(k, 3)
        v = self.cfg.vocab
        # Per-sequence topic biases a small token subset -> learnable stats.
        topic = jax.random.randint(kz, (self.batch, 1), 0, self.n_topics)
        base = jax.random.randint(kt, (self.batch, self.seq + 1), 0, v)
        bias = (topic * 97 + jnp.arange(self.seq + 1) % 13) % v
        use_bias = jax.random.bernoulli(kn, 0.5,
                                        (self.batch, self.seq + 1))
        toks = jnp.where(use_bias, bias, base).astype(jnp.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            out["img"] = jax.random.normal(
                kz, (self.batch, self.cfg.n_img_tokens, self.cfg.d_vision),
                jnp.bfloat16)
        if self.cfg.family == "encdec":
            out["frames"] = jax.random.normal(
                kz, (self.batch, self.seq, self.cfg.d_model), jnp.bfloat16)
        return out


@dataclasses.dataclass
class GeoEnriched:
    """Wraps a source; each sequence carries a (lon, lat) and its census
    block id is prepended as a feature token
    ``vocab_geo_base + (block_id % n_geo_tokens)``.

    The mapping runs through a ``core.engine.GeoEngine`` with a cell index
    (strategy "fast" or "hybrid" — point sampling draws from the covering
    cells, so a simple-only engine is rejected); the legacy
    ``fast_index``/``fast_cfg`` pair is still accepted and wrapped into a
    fast-strategy engine on first use.
    """

    source: SyntheticLM
    engine: object = None            # core.engine.GeoEngine
    fast_index: object = None        # legacy: core.fast.FastIndex
    fast_cfg: object = None          # legacy: core.fast.FastConfig
    points_seed: int = 7
    n_geo_tokens: int = 1024

    def _engine(self):
        if self.engine is None:
            from repro.core.engine import EngineConfig, GeoEngine
            fcfg = self.fast_cfg
            cfg = EngineConfig() if fcfg is None else EngineConfig(
                mode=fcfg.mode, cap_boundary=fcfg.cap_boundary,
                backend=fcfg.backend)
            self.engine = GeoEngine("fast", cfg, fast_index=self.fast_index)
        if self.engine.fast_index is None:
            raise ValueError("GeoEnriched needs an engine with a cell "
                             "index (strategy 'fast' or 'hybrid'); got "
                             f"strategy {self.engine.strategy!r}")
        return self.engine

    def _sample_points(self, key, batch: int) -> jnp.ndarray:
        """Device-side (lon, lat) samples guaranteed to land on the map:
        pick a covering cell uniformly, then a point inside its first leaf
        cell (a covering cell always contains its own leaf cells, so no
        sample falls into an off-map gap the way extent-uniform sampling
        did)."""
        index = self._engine().fast_index
        kc, ku = jax.random.split(key)
        r = jax.random.randint(kc, (batch,), 0, index.cell_lo.shape[0])
        from repro.core.fast import demorton
        ix, iy = demorton(index.cell_lo[r])
        # Keep the intra-cell jitter off the leaf borders so fp32
        # re-quantization in leaf_codes can't push a sample into a
        # neighbouring (possibly off-map) cell.
        u = 0.05 + 0.9 * jax.random.uniform(ku, (batch, 2))
        q = index.quant
        return jnp.stack([q[0] + (ix + u[:, 0]) / q[2],
                          q[1] + (iy + u[:, 1]) / q[3]], axis=-1)

    def batch_at(self, step: int) -> dict:
        out = dict(self.source.batch_at(step))
        b = out["tokens"].shape[0]
        k = jax.random.fold_in(jax.random.key(self.points_seed), step)
        xy = self._sample_points(k, b)
        bid = self._engine().assign(xy).block
        geo_tok = (jnp.maximum(bid, 0) % self.n_geo_tokens).astype(jnp.int32)
        tokens = out["tokens"].at[:, 0].set(
            geo_tok % self.source.cfg.vocab)
        out["tokens"] = tokens
        out["geo_block"] = bid
        return out


def make_source(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                geo=None):
    """``geo`` is a GeoEngine, or the legacy (FastIndex, FastConfig) pair."""
    src = SyntheticLM(cfg=cfg, batch=shape.global_batch, seq=shape.seq_len,
                      seed=seed)
    if geo is None:
        return src
    if isinstance(geo, tuple):
        index, fcfg = geo
        return GeoEnriched(source=src, fast_index=index, fast_cfg=fcfg)
    return GeoEnriched(source=src, engine=geo)
