"""Shared capacity-bucketed dispatch used by both the MoE layer and the
sharded geo-index lookup (DESIGN.md: the paper's sharded cell index *is* an
expert-dispatch problem — same primitive, different payload).

Given per-item integer bucket ids, produce a static-shape routing plan:
items are stably sorted by bucket, positioned within their bucket, and
dropped beyond ``capacity`` (dropping is counted, never silent).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class RoutePlan(NamedTuple):
    order: jnp.ndarray      # [N] permutation: items sorted by bucket
    bucket: jnp.ndarray     # [N] bucket id per sorted item
    slot: jnp.ndarray       # [N] position within bucket (sorted order)
    keep: jnp.ndarray       # [N] bool — survives capacity (sorted order)
    flat_ix: jnp.ndarray    # [N] index into [n_buckets*capacity] buffer
                            #     (overflow -> n_buckets*capacity sentinel)
    n_dropped: jnp.ndarray  # [] i32


def plan_routes(bucket_ids: jnp.ndarray, n_buckets: int,
                capacity: int) -> RoutePlan:
    """bucket_ids: [N] i32 in [0, n_buckets]; id == n_buckets means "not
    mine / inactive" and is never kept."""
    n = bucket_ids.shape[0]
    order = jnp.argsort(bucket_ids, stable=True)
    sb = bucket_ids[order]
    pos = (jnp.arange(n, dtype=jnp.int32)
           - jnp.searchsorted(sb, sb, side="left").astype(jnp.int32))
    active = sb < n_buckets
    keep = active & (pos < capacity)
    flat = jnp.where(keep, sb * capacity + pos, n_buckets * capacity)
    n_dropped = jnp.sum((active & ~keep).astype(jnp.int32))
    return RoutePlan(order=order, bucket=sb, slot=pos, keep=keep,
                     flat_ix=flat.astype(jnp.int32), n_dropped=n_dropped)


def slot_tables(plan: RoutePlan, n_buckets: int, capacity: int,
                item_of: jnp.ndarray | None = None,
                weights: jnp.ndarray | None = None):
    """Inverse routing tables, indexed by *buffer slot* (not route entry).

    Scattering only int32 indices (never [N, D] payloads) keeps the dispatch
    memory bounded by the capacity buffer — scattering payload rows makes
    XLA materialize [N, D] plus same-sized u32 index arrays, which for
    top-6 MoE at 4k seq is tens of GiB.

    Returns (item_for_slot [n_buckets*capacity] i32 with -1 = empty,
             weight_for_slot [n_buckets*capacity] f32).
    """
    n_slots = n_buckets * capacity
    src_items = plan.order if item_of is None else item_of[plan.order]
    src_items = jnp.where(plan.keep, src_items, -1)
    ifs = jnp.full((n_slots + 1,), -1, jnp.int32)
    ifs = ifs.at[plan.flat_ix].set(src_items.astype(jnp.int32), mode="drop")
    if weights is None:
        wfs = (ifs[:-1] >= 0).astype(jnp.float32)
    else:
        w = jnp.where(plan.keep, weights[plan.order].astype(jnp.float32),
                      0.0)
        wfs = jnp.zeros((n_slots + 1,), jnp.float32)
        wfs = wfs.at[plan.flat_ix].set(w, mode="drop")
        wfs = wfs[:-1]
    return ifs[:-1], wfs


def scatter_to_buckets(plan: RoutePlan, payload: jnp.ndarray,
                       n_buckets: int, capacity: int,
                       item_of: jnp.ndarray | None = None,
                       item_for_slot: jnp.ndarray | None = None
                       ) -> jnp.ndarray:
    """payload: [n_items, D] in original item order.  Fills the capacity
    buffer by *gathering* payload rows per slot (see slot_tables).

    Returns [n_buckets * capacity, D]; empty/dropped rows are zero.
    """
    if item_for_slot is None:
        item_for_slot, _ = slot_tables(plan, n_buckets, capacity, item_of)
    rows = payload[jnp.clip(item_for_slot, 0, payload.shape[0] - 1)]
    return rows * (item_for_slot >= 0)[:, None].astype(payload.dtype)


def gather_from_buckets(slot_tabs, buf: jnp.ndarray,
                        n_items: int) -> jnp.ndarray:
    """Combine buffer rows back per original item (duplicates summed,
    e.g. top-k routing).  buf: [n_buckets*capacity, D];
    slot_tabs: (item_for_slot, weight_for_slot) from slot_tables()."""
    ifs, wfs = slot_tabs
    rows = buf * wfs[:, None].astype(buf.dtype)
    out = jnp.zeros((n_items, buf.shape[-1]), buf.dtype)
    return out.at[jnp.clip(ifs, 0, n_items - 1)].add(
        rows * (ifs >= 0)[:, None].astype(buf.dtype), mode="drop")
