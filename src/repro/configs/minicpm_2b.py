"""MiniCPM-2B: llama-like dense decoder, tied embeddings; trained with the
WSD schedule (see optim/schedules.py) [arXiv:2404.06395]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, act="swiglu", tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="minicpm-2b-reduced", family="dense",
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=6,
    d_ff=180, vocab=512, act="swiglu", tie_embeddings=True,
)
