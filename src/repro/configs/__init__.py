"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (deepseek_v2_236b, llama_3_2_vision_90b,
                           minicpm_2b, mixtral_8x7b, nemotron_4_15b,
                           qwen1_5_0_5b, seamless_m4t_medium, xlstm_1_3b,
                           yi_9b, zamba2_1_2b)
from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                PREFILL_32K, TRAIN_4K, ModelConfig,
                                RunConfig, ShapeConfig, shapes_for)

# The registry is also the convenience surface for the shape/run presets:
# callers import everything config-shaped from ``repro.configs``.
__all__ = ["ALL_SHAPES", "ARCH_NAMES", "DECODE_32K", "LONG_500K",
           "ModelConfig", "PREFILL_32K", "RunConfig", "ShapeConfig",
           "TRAIN_4K", "get_config", "get_reduced_config", "shapes_for"]

_MODULES = {
    "yi-9b": yi_9b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "nemotron-4-15b": nemotron_4_15b,
    "minicpm-2b": minicpm_2b,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "zamba2-1.2b": zamba2_1_2b,
    "xlstm-1.3b": xlstm_1_3b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "mixtral-8x7b": mixtral_8x7b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    return _MODULES[name].REDUCED
