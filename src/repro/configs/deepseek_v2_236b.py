"""DeepSeek-V2-236B: MLA (kv_lora=512) + MoE with 2 shared + 160 routed
experts, top-6; first layer dense [arXiv:2405.04434]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400, act="swiglu",
    mla=True, q_lora=1536, kv_lora=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, head_dim=192,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    first_dense_layers=1,
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b-reduced", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=512, act="swiglu",
    mla=True, q_lora=48, kv_lora=32,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, head_dim=24,
    n_experts=8, n_shared_experts=2, top_k=2, d_ff_expert=48,
    first_dense_layers=1,
)
