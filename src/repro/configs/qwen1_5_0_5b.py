"""Qwen1.5-0.5B: dense decoder, MHA with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab=151936, qkv_bias=True, act="swiglu",
)

REDUCED = ModelConfig(
    name="qwen1.5-0.5b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=176, vocab=512, qkv_bias=True, act="swiglu",
)
