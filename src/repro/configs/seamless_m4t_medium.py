"""SeamlessM4T-medium backbone: encoder-decoder transformer.  The speech
frontend is a STUB — input_specs() provides precomputed frame embeddings
[B, S, d_model].  RoPE replaces the original relative positions (TPU
adaptation, DESIGN.md) [arXiv:2308.11596]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, act="gelu",
)

REDUCED = ModelConfig(
    name="seamless-m4t-medium-reduced", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, act="gelu",
)
