"""Yi-9B: llama-arch dense decoder with GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, rope_theta=5_000_000.0, act="swiglu",
)

REDUCED = ModelConfig(
    name="yi-9b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=176, vocab=512, rope_theta=5_000_000.0, act="swiglu",
)
