"""Llama-3.2-Vision-90B backbone: dense decoder with gated cross-attention
image layers every 5th layer.  The vision tower is a STUB — input_specs()
provides precomputed patch embeddings [B, n_img_tokens, d_vision]
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, rope_theta=500_000.0, act="swiglu",
    cross_attn_every=5, n_img_tokens=1600, d_vision=1280,
)

REDUCED = ModelConfig(
    name="llama-3.2-vision-90b-reduced", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=176, vocab=512, rope_theta=500_000.0, act="swiglu",
    cross_attn_every=2, n_img_tokens=16, d_vision=48,
)
