"""Mixtral-8x7B: 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].  SWA rolling cache -> sub-quadratic decode, runs
long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, act="swiglu", rope_theta=1_000_000.0,
    sliding_window=4096,
    n_experts=8, top_k=2, d_ff_expert=14336,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=512, act="swiglu",
    sliding_window=32,
    n_experts=4, top_k=2, d_ff_expert=160,
    subquadratic=True,
)
