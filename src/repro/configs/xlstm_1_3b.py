"""xLSTM-1.3B: mLSTM blocks with an sLSTM block every 8th layer
[arXiv:2405.04517].  Constant-size recurrent state -> runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced", family="xlstm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, slstm_every=2,
    subquadratic=True,
)
