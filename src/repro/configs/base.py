"""Config dataclasses for models, input shapes and runs."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm_hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None

    # mlp
    act: str = "swiglu"         # swiglu | relu2 | gelu

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v2)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0   # zamba2: shared attn block cadence
    shared_lora_rank: int = 0

    # xLSTM
    slstm_every: int = 0         # 0 = all mLSTM

    # VLM (llama-3.2-vision)
    cross_attn_every: int = 0
    n_img_tokens: int = 0
    d_vision: int = 0

    # enc-dec (seamless-m4t)
    enc_layers: int = 0

    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Sub-quadratic context support (decides long_500k applicability).
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_decoder_only(self) -> bool:
        return self.family not in ("encdec",)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: an input shape + which step it exercises."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> tuple[ShapeConfig, ...]:
    """Shape cells applicable to an architecture (see DESIGN.md §4)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs for a step (static; part of jit key)."""

    microbatch: int = 0          # 0 = no gradient accumulation
    remat: str = "full"          # none | dots | full
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    ssm_chunk: int = 256
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    schedule: str = "cosine"     # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10000
    z_loss: float = 1e-4
    seed: int = 0
