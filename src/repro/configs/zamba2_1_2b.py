"""Zamba2-1.2B: Mamba2 backbone with a shared attention+MLP block invoked
every 6 layers (per-invocation LoRA on q) [arXiv:2411.15242].
Sub-quadratic -> runs the long_500k cell."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="ssm_hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, act="swiglu",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, shared_lora_rank=128,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="ssm_hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, act="swiglu",
    ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    shared_attn_every=2, shared_lora_rank=8,
    subquadratic=True,
)
