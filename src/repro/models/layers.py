"""Shared layers: norms, embeddings, RoPE, activation-sharding helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro import compat
from repro.models.module import P

ACT_DTYPE = jnp.bfloat16

BATCH = ("pod", "data")


def act_spec(shape, parts, mesh) -> PartitionSpec:
    """The PartitionSpec ``shard_act`` would apply to ``shape`` on ``mesh``.

    Axis names absent from the mesh are dropped; entries whose dimension
    is not divisible by the assigned mesh extent are replicated (e.g. 4
    kv heads on a 16-way model axis).  ``mesh`` only needs ``axis_names``
    and a name->size ``shape`` mapping (Mesh, AbstractMesh, or a test
    stub).
    """
    names = set(mesh.axis_names)

    def extent(axes):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    spec = []
    for dim, p in zip(shape, parts):
        if p is None:
            spec.append(None)
            continue
        axes = tuple(a for a in ((p,) if isinstance(p, str) else p)
                     if a in names)
        if axes and dim % extent(axes) == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return PartitionSpec(*spec)


def shard_act(x, *parts):
    """Activation sharding constraint against the ambient mesh.

    A no-op when no mesh is active (CPU smoke tests) — GSPMD propagation
    alone loses batch sharding through the scanned/blocked attention
    reshapes, so the model calls this explicitly at block boundaries.
    The mesh comes from ``repro.compat.get_abstract_mesh()`` — never from
    newer-jax symbols directly — so the same model code runs on the
    pinned 0.4.x toolchain inside ``compat.use_mesh(...)`` scopes
    (DESIGN.md §12).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    return compat.with_sharding_constraint(
        x, act_spec(x.shape, parts, mesh), mesh=mesh)


def rmsnorm_spec(d):
    return {"scale": P((d,), (None,), init="ones")}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


def layernorm_spec(d):
    return {"scale": P((d,), (None,), init="ones"),
            "bias": P((d,), (None,), init="zeros")}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dt)


def embed_spec(vocab, d):
    return {"table": P((vocab, d), ("vocab", "embed"), init="normal")}


def embed(params, tokens):
    return shard_act(params["table"].astype(ACT_DTYPE)[tokens],
                     BATCH, None, None)


def unembed_spec(vocab, d):
    return {"w": P((d, vocab), ("embed", "vocab"), init="fanin", fan_in=d)}


def unembed(params, x):
    # Logits in f32 for a stable softmax/cross-entropy.
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                      params["w"].astype(jnp.float32))


def rope_tables(positions: jnp.ndarray, dim: int, theta: float):
    """positions [S] (or [B, S]) -> (sin, cos) [..., S, dim/2] f32."""
    assert dim % 2 == 0
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray):
    """x [..., S, H, D]; sin/cos [S, D/2] or [B, S, D/2] (broadcast over H)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if sin.ndim == 2:   # [S, D/2] -> broadcast over batch and heads
        s = sin[None, :, None, :]
        c = cos[None, :, None, :]
    else:               # [B, S, D/2]
        s = sin[:, :, None, :]
        c = cos[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


def dense_spec(d_in, d_out, axes, bias=False, init="fanin"):
    s = {"w": P((d_in, d_out), axes, init=init, fan_in=d_in)}
    if bias:
        s["b"] = P((d_out,), (axes[1],), init="zeros")
    return s


def dense(params, x):
    y = jnp.einsum("...d,df->...f", x, params["w"].astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y
