"""Minimal param-spec module system (no flax available offline).

Every model is a pure function over a nested dict of arrays.  Shapes,
logical sharding axes and initializers are declared once as ``P`` specs;
from the same spec tree we derive:

  * materialized params        (init_params)     — training / smoke tests
  * ShapeDtypeStruct stand-ins (abstract_params) — the multi-pod dry-run
    never allocates a single real weight
  * NamedSharding trees        (sharding/rules.py)

Logical axis names are free-form strings resolved by sharding rules; None
means "never sharded".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Spec of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | fanin
    fan_in: Optional[int] = None
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack(specs, n: int, axis_name: str = "layers"):
    """Prepend a scanned-stack dimension to every spec in a tree."""
    def one(p: P) -> P:
        return P(shape=(n,) + p.shape, axes=(axis_name,) + p.axes,
                 init=p.init, fan_in=p.fan_in, scale=p.scale, dtype=p.dtype)
    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, P))


def _init_one(p: P, key) -> jnp.ndarray:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "fanin":
        fan = p.fan_in or (p.shape[-2] if len(p.shape) >= 2 else p.shape[-1])
        std = 1.0 / math.sqrt(fan)
    else:
        std = p.scale
    return (jax.random.truncated_normal(key, -2.0, 2.0, p.shape, jnp.float32)
            * std).astype(p.dtype)


def init_params(specs, key):
    """Materialize a spec tree into arrays (host/devices as placed)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(p, k) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct stand-ins (for .lower() without allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), specs,
        is_leaf=lambda x: isinstance(x, P))


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return int(sum(np.prod(p.shape) for p in leaves))


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return int(sum(np.prod(p.shape) * jnp.dtype(p.dtype).itemsize
                   for p in leaves))
