"""Mixture-of-Experts with explicit expert parallelism via shard_map.

Design (DESIGN.md §5): activations are batch-sharded and *replicated* over
the "model" axis, experts are sharded over "model".  Each model-rank
therefore already holds every local token: it filters the (token, choice)
pairs routed to *its* experts, capacity-buckets them (distributed/dispatch),
runs its expert FFNs, scatter-adds partial outputs, and a single
``psum("model")`` combines — one collective per MoE layer, the same volume
as a tensor-parallel all-reduce.  No all_to_all of token payloads is needed
because the tokens were never sharded over "model" to begin with.

Expert weights are additionally sharded over "data" (FSDP); the body
all-gathers them per layer, and the transpose (reduce-scatter of expert
grads) lands exactly on the ZeRO-sharded optimizer state.

Without a mesh (unit tests / CPU smoke), ``moe_ffn`` runs the same math on
a single rank — it is the reference implementation of itself.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.compat import shard_map
from repro.distributed.dispatch import gather_from_buckets, plan_routes, \
    scatter_to_buckets, slot_tables
from repro.models.ffn import ffn, ffn_spec
from repro.models.layers import dense_spec
from repro.models.module import P


def moe_spec(cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    spec = {
        "router": dense_spec(d, e, ("embed", None)),
        "w_gate": P((e, d, f), ("expert", "embed", "moe_mlp"),
                    init="fanin", fan_in=d),
        "w_up": P((e, d, f), ("expert", "embed", "moe_mlp"),
                  init="fanin", fan_in=d),
        "w_down": P((e, f, d), ("expert", "moe_mlp", "embed"),
                    init="fanin", fan_in=f),
    }
    if cfg.n_shared_experts:
        spec["shared"] = ffn_spec(d, cfg.d_ff_expert * cfg.n_shared_experts,
                                  "swiglu")
    return spec


def _router(params, cfg, x2d):
    """x2d [T, D] -> (probs [T, k], ids [T, k], aux_fields)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    if cfg.family == "moe" and cfg.top_k:
        pass
    probs_all = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs_all, cfg.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # Switch-style load-balance terms (to be averaged over the data axes).
    me = jnp.mean(probs_all, axis=0)                          # [E]
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0) / (x2d.shape[0] * cfg.top_k)
    return top_p, top_i, (me, ce)


def _expert_ffn(w_gate, w_up, w_down, buf):
    """buf [E, C, D] -> [E, C, D] through per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))


def _moe_local(params, cfg, x2d, e_lo, e_loc: int, capacity: int):
    """Route local tokens to the ``e_loc`` experts starting at ``e_lo``
    (``e_lo`` may be a traced axis_index); return the partial output (zero
    rows for tokens whose experts live elsewhere), aux terms and the
    dropped-token count."""
    t, d = x2d.shape
    top_p, top_i, (me, ce) = _router(params, cfg, x2d)
    flat_e = top_i.reshape(-1)
    local = (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
    bucket = jnp.where(local, flat_e - e_lo, e_loc).astype(jnp.int32)
    item_of = (jnp.arange(t * cfg.top_k, dtype=jnp.int32) // cfg.top_k)
    plan = plan_routes(bucket, e_loc, capacity)
    tabs = slot_tables(plan, e_loc, capacity, item_of=item_of,
                       weights=top_p.reshape(-1))
    buf = scatter_to_buckets(plan, x2d, e_loc, capacity,
                             item_for_slot=tabs[0])
    buf = buf.reshape(e_loc, capacity, d)
    h = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], buf)
    out = gather_from_buckets(tabs, h.reshape(e_loc * capacity, d), t)
    return out, me, ce, plan.n_dropped


def moe_ffn(params, cfg, x, mesh=None):
    """x [B, S, D] -> ([B, S, D], aux dict).

    With a mesh, runs under shard_map with experts on the "model" axis and
    expert weights FSDP-gathered over "data".
    """
    b, s, d = x.shape
    e = cfg.n_experts

    if mesh is None or "model" not in mesh.axis_names:
        x2d = x.reshape(b * s, d)
        capacity = max(1, int(math.ceil(
            b * s * cfg.top_k / e * cfg.capacity_factor)))
        out, me, ce, dropped = _moe_local(params, cfg, x2d, 0, e, capacity)
        aux = {"lb_loss": e * jnp.sum(me * ce), "dropped": dropped}
        y = out.reshape(b, s, d)
    else:
        n_model = mesh.shape["model"]
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_extent = math.prod(mesh.shape[a] for a in dp_axes)
        if b % max(dp_extent, 1) != 0:
            dp_axes = ()            # tiny batches (long_500k) replicate
        b_loc = b // math.prod([mesh.shape[a] for a in dp_axes] or [1])
        if e % n_model == 0:
            tp, e_loc = 1, e // n_model
            wg_v, wu_v, wd_v = (params["w_gate"], params["w_up"],
                                params["w_down"])
        elif n_model % e == 0:
            # Virtual experts: split each expert's FFN hidden dim into
            # tp slices so E*tp == n_model.  SwiGLU factorizes exactly over
            # the hidden dim, and the down-projection halves are partial
            # sums combined by the existing psum("model").
            tp, e_loc = n_model // e, 1
            f = cfg.d_ff_expert
            assert f % tp == 0, (f, tp)
            wg_v = params["w_gate"].reshape(e, d, tp, f // tp) \
                .transpose(0, 2, 1, 3).reshape(e * tp, d, f // tp)
            wu_v = params["w_up"].reshape(e, d, tp, f // tp) \
                .transpose(0, 2, 1, 3).reshape(e * tp, d, f // tp)
            wd_v = params["w_down"].reshape(e, tp, f // tp, d) \
                .reshape(e * tp, f // tp, d)
        else:
            raise ValueError(f"n_experts={e} vs model axis {n_model}: "
                             "need one to divide the other")
        capacity = max(1, int(math.ceil(
            b_loc * s * cfg.top_k / e * cfg.capacity_factor)))

        def body(x_loc, router_w, wg, wu, wd):
            # FSDP-gather expert weights over "data" in bf16 (cast before
            # the gather halves the dominant weight-gather collective; the
            # transpose reduce-scatters bf16 grads into f32 accumulation at
            # the cast boundary).
            wg = jax.lax.all_gather(wg.astype(jnp.bfloat16), "data",
                                    axis=1, tiled=True)
            wu = jax.lax.all_gather(wu.astype(jnp.bfloat16), "data",
                                    axis=1, tiled=True)
            wd = jax.lax.all_gather(wd.astype(jnp.bfloat16), "data",
                                    axis=2, tiled=True)
            bl = x_loc.shape[0]
            x2d = x_loc.reshape(bl * s, d)
            rank = jax.lax.axis_index("model")
            # With virtual experts the rank owns one slice of real expert
            # rank // tp; routing filters on the *real* expert id.
            e_lo = (rank // tp) * e_loc
            lp = {"router": {"w": router_w}, "w_gate": wg, "w_up": wu,
                  "w_down": wd}
            out, me, ce, dropped = _moe_local(lp, cfg, x2d, e_lo, e_loc,
                                              capacity)
            if tp > 1:
                dropped = dropped // tp  # each drop counted tp times
            # Combine in bf16: halves the per-layer [T_loc, D] all-reduce.
            out = jax.lax.psum(out.astype(jnp.bfloat16), "model")
            # me/ce are computed from model-replicated inputs (invariant over
            # "model" in VMA terms); average over the data axes only.
            if dp_axes:
                me = jax.lax.pmean(me, dp_axes)
                ce = jax.lax.pmean(ce, dp_axes)
            dropped = jax.lax.psum(dropped, "model")
            if dp_axes:
                dropped = jax.lax.psum(dropped, dp_axes)
            return out.reshape(bl, s, d), me, ce, dropped

        bspec = dp_axes if dp_axes else None
        y, me, ce, dropped = shard_map(
            body, mesh=mesh,
            in_specs=(PS(bspec, None, None),
                      PS(None, None),
                      PS("model", "data", None),
                      PS("model", "data", None),
                      PS("model", None, "data")),
            out_specs=(PS(bspec, None, None), PS(), PS(), PS()),
            # With a replicated batch (long_500k), the FSDP all_gather over
            # "data" defeats VMA's replication inference; the outputs are
            # data-invariant by construction.
            check_vma=bool(dp_axes),
        )(x, params["router"]["w"], wg_v, wu_v, wd_v)
        aux = {"lb_loss": e * jnp.sum(me * ce), "dropped": dropped}

    if cfg.n_shared_experts:
        y = y + ffn(params["shared"], x, "swiglu")
    return y, aux
