"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, recurrent
state update for decode (constant-memory long context; this is why zamba2
runs the 500k cell).

Per head h the SSD recurrence with scalar decay a_t = exp(-exp(A_log_h) *
softplus(dt_t + dt_bias_h)) is

    S_t = a_t * S_{t-1} + B_t (dt_t x_t)^T          S in R^{N x P}
    y_t = C_t . S_t + D_h x_t

Chunked form (chunk length Lc, scanned): intra-chunk is a decay-masked
quadratic ("attention-like") product, inter-chunk is a rank-N state carried
across chunks.  All decay factors are exp of non-positive numbers -> no
stabilizer needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import BATCH, dense, dense_spec, rmsnorm, \
    rmsnorm_spec, shard_act
from repro.models.module import P


def mamba2_spec(cfg, d_in=None):
    d = d_in or cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    conv_dim = di + 2 * n           # x, B, C go through the causal conv
    return {
        "in_proj": dense_spec(d, 2 * di + 2 * n + h, ("embed", "mlp")),
        "conv_w": P((conv_dim, cfg.ssm_conv), (None, None), init="fanin",
                    fan_in=cfg.ssm_conv),
        "conv_b": P((conv_dim,), (None,), init="zeros"),
        "a_log": P((h,), (None,), init="zeros"),
        "d_skip": P((h,), (None,), init="ones"),
        "dt_bias": P((h,), (None,), init="zeros"),
        "norm": rmsnorm_spec(di),
        "out_proj": dense_spec(di, d, ("mlp", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x [B, S, C]; w [C, K]; state [B, K-1, C] or
    None (zeros).  Returns (y [B, S, C], new_state)."""
    k = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, j:j + x.shape[1], :] * w[None, None, :, j].astype(x.dtype)
            for j in range(k))
    new_state = xp[:, -(k - 1):, :]
    return y + b.astype(x.dtype), new_state


def _split_in_proj(params, cfg, x, d_in):
    di = cfg.ssm_expand * d_in
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    zxbcdt = dense(params["in_proj"], x)
    z, xs, bb, cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xs, bb, cc, dt, di, n, h


def mamba2(params, cfg, x, chunk: int = 128, d_in=None):
    """Train/prefill. x [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    z, xs, bb, cc, dt, di, n, h = _split_in_proj(params, cfg, x, d_in or d)
    p = cfg.ssm_head_dim

    conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_out, _ = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xs, bb, cc = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    log_a = (-jnp.exp(params["a_log"].astype(jnp.float32)) * dt)   # <= 0
    xh = xs.reshape(b, s, h, p)
    xt = xh * dt[..., None].astype(xh.dtype)                       # dt * x

    lc = min(chunk, s)
    nc = s // lc
    assert nc * lc == s, (s, lc)

    xtc = jnp.moveaxis(xt.reshape(b, nc, lc, h, p), 1, 0)
    xc = jnp.moveaxis(xh.reshape(b, nc, lc, h, p), 1, 0)
    bc = jnp.moveaxis(bb.reshape(b, nc, lc, n), 1, 0)
    ccc = jnp.moveaxis(cc.reshape(b, nc, lc, n), 1, 0)
    lac = jnp.moveaxis(log_a.reshape(b, nc, lc, h), 1, 0)
    # Batch + head sharding through chunk reshapes (heads carry TP).
    xtc = shard_act(xtc, None, BATCH, None, "model", None)
    xc = shard_act(xc, None, BATCH, None, "model", None)
    bc = shard_act(bc, None, BATCH, None, None)
    ccc = shard_act(ccc, None, BATCH, None, None)
    lac = shard_act(lac, None, BATCH, None, "model")

    def body(S, xs_):
        xtc, xc, bc, cc, lac = xs_
        csum = jnp.cumsum(lac, axis=1)                             # [B,Lc,H]
        cb = jnp.einsum("btn,bsn->bts", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))
        dec = jnp.exp(csum[:, :, None, :] - csum[:, None, :, :])   # [B,t,s,H]
        tri = jnp.tril(jnp.ones((lc, lc), jnp.float32))
        scores = cb[..., None] * dec * tri[None, :, :, None]
        y_intra = jnp.einsum("btsh,bshp->bthp", scores,
                             xtc.astype(jnp.float32))
        y_inter = jnp.einsum("btn,bhnp->bthp", cc.astype(jnp.float32), S) \
            * jnp.exp(csum)[..., None]
        to_end = jnp.exp(csum[:, -1:, :] - csum)                   # [B,Lc,H]
        s_c = jnp.einsum("bsn,bshp,bsh->bhnp", bc.astype(jnp.float32),
                         xtc.astype(jnp.float32), to_end)
        S = jnp.exp(csum[:, -1])[:, :, None, None] * S + s_c
        return S, y_intra + y_inter

    s0 = shard_act(jnp.zeros((b, h, n, p), jnp.float32),
                   BATCH, "model", None, None)
    _, ys = jax.lax.scan(body, s0, (xtc, xc, bc, ccc, lac))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(params["out_proj"], y)


def mamba2_init_state(cfg, batch, d_in, dtype=jnp.float32):
    di = cfg.ssm_expand * d_in
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    return {
        "S": jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
    }


def mamba2_step(params, cfg, x, state, d_in=None):
    """Decode one token. x [B, 1, D]; state {"S", "conv"}."""
    b, _, d = x.shape
    z, xs, bb, cc, dt, di, n, h = _split_in_proj(params, cfg, x, d_in or d)
    p = cfg.ssm_head_dim
    conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"],
                                        state["conv"].astype(conv_in.dtype))
    conv_out = jax.nn.silu(conv_out)
    xs, bb, cc = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]
    a = jnp.exp(-jnp.exp(params["a_log"].astype(jnp.float32)) * dt)  # [B,H]
    xh = xs.reshape(b, h, p).astype(jnp.float32)
    xt = xh * dt[..., None]
    S = a[:, :, None, None] * state["S"] + jnp.einsum(
        "bn,bhp->bhnp", bb[:, 0].astype(jnp.float32), xt)
    y = jnp.einsum("bn,bhnp->bhp", cc[:, 0].astype(jnp.float32), S)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(params["out_proj"], y), {"S": S, "conv": conv_state}
