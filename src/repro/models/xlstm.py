"""xLSTM blocks: mLSTM (matrix-memory, chunked-parallel) and sLSTM
(scalar-memory, inherently sequential -> lax.scan; the xLSTM paper itself
notes sLSTM is not parallelizable).

mLSTM per head: exponential input gate i_t, forget gate f_t (sigmoid in log
space), matrix memory C in R^{dk x dv}, normalizer n in R^{dk}, running
stabilizer m:

    C_t = f_t C_{t-1} + i_t k_t v_t^T        (stabilized by m_t)
    h_t = (q_t C_t) / max(|q_t n_t|, exp(-m_t))

Train/prefill uses the chunkwise form (intra-chunk decay-masked quadratic +
carried (C, n, m)), decode the recurrent step — constant-size state, which
is why xlstm runs the 500k-context cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import BATCH, dense, dense_spec, rmsnorm, \
    rmsnorm_spec, shard_act
from repro.models.module import P


def mlstm_spec(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    return {
        "wq": dense_spec(d, d, ("embed", "heads")),
        "wk": dense_spec(d, d, ("embed", "heads")),
        "wv": dense_spec(d, d, ("embed", "heads")),
        "wi": dense_spec(d, h, ("embed", None), bias=True),
        "wf": dense_spec(d, h, ("embed", None), bias=True),
        "wo_gate": dense_spec(d, d, ("embed", "heads")),
        "norm": rmsnorm_spec(d),
        "wo": dense_spec(d, d, ("heads", "embed")),
    }


def _mlstm_qkvif(params, cfg, x):
    b, s, d = x.shape
    h = cfg.n_heads
    dk = d // h
    q = dense(params["wq"], x).reshape(b, s, h, dk)
    k = dense(params["wk"], x).reshape(b, s, h, dk) / math.sqrt(dk)
    v = dense(params["wv"], x).reshape(b, s, h, dk)
    log_i = dense(params["wi"], x).astype(jnp.float32)            # [B,S,H]
    log_f = jax.nn.log_sigmoid(dense(params["wf"], x).astype(jnp.float32))
    return q, k, v, log_i, log_f, dk


def mlstm(params, cfg, x, chunk: int = 128):
    """Train/prefill mLSTM. x [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    h = cfg.n_heads
    q, k, v, log_i, log_f, dk = _mlstm_qkvif(params, cfg, x)

    lc = min(chunk, s)
    nc = s // lc
    assert nc * lc == s, (s, lc)

    def tochunks(a):
        a = jnp.moveaxis(a.reshape((b, nc, lc) + a.shape[2:]), 1, 0)
        return shard_act(a, *((None, BATCH) + (None,) * (a.ndim - 2)))

    qc, kc, vc = tochunks(q), tochunks(k), tochunks(v)
    lic, lfc = tochunks(log_i), tochunks(log_f)

    def body(carry, xs_):
        C, n, m = carry          # [B,H,dk,dv], [B,H,dk], [B,H]
        qq, kk, vv, li, lf = xs_
        csum = jnp.cumsum(lf, axis=1)                             # [B,Lc,H]
        # Stabilizers per query position.
        m_inter = csum + m[:, None, :]                            # [B,Lc,H]
        dtil = (csum[:, :, None, :] - csum[:, None, :, :]
                + li[:, None, :, :])                              # [B,t,s,H]
        tri = jnp.tril(jnp.ones((lc, lc), bool))
        dtil = jnp.where(tri[None, :, :, None], dtil, -jnp.inf)
        m_intra = jnp.max(dtil, axis=2)                           # [B,Lc,H]
        m_new = jnp.maximum(m_inter, m_intra)
        dmat = jnp.exp(dtil - m_new[:, :, None, :])               # [B,t,s,H]
        qk = jnp.einsum("bthd,bshd->btsh", qq.astype(jnp.float32),
                        kk.astype(jnp.float32))
        w = qk * dmat
        scale_i = jnp.exp(m_inter - m_new)                        # [B,Lc,H]
        h_num = jnp.einsum("btsh,bshv->bthv", w, vv.astype(jnp.float32)) \
            + scale_i[..., None] * jnp.einsum(
                "bthd,bhdv->bthv", qq.astype(jnp.float32), C)
        # Normalizer: q_t . n_t = sum_s dmat_ts (q_t . k_s) + inter term
        #           = sum_s w_ts + scale_i * (q_t . n_prev).
        qn = jnp.einsum("bthd,bhd->bth", qq.astype(jnp.float32), n)
        qn_total = jnp.sum(w, axis=2) + scale_i * qn
        denom = jnp.maximum(jnp.abs(qn_total), jnp.exp(-m_new))
        hh = h_num / denom[..., None]
        # Carry update.
        total = csum[:, -1]                                       # [B,H]
        m_c = jnp.maximum(m + total,
                          jnp.max(total[:, None, :] - csum + li, axis=1))
        sc_old = jnp.exp(m + total - m_c)
        sc_new = jnp.exp(total[:, None, :] - csum + li
                         - m_c[:, None, :])                       # [B,Lc,H]
        C = sc_old[:, :, None, None] * C + jnp.einsum(
            "bshd,bshv,bsh->bhdv", kk.astype(jnp.float32),
            vv.astype(jnp.float32), sc_new)
        n = sc_old[:, :, None] * n + jnp.einsum(
            "bshd,bsh->bhd", kk.astype(jnp.float32), sc_new)
        return (C, n, m_c), hh

    dk_ = d // h
    c0 = shard_act(jnp.zeros((b, h, dk_, dk_), jnp.float32),
                   BATCH, None, None, None)
    n0 = shard_act(jnp.zeros((b, h, dk_), jnp.float32), BATCH, None, None)
    m0 = shard_act(jnp.full((b, h), -jnp.inf, jnp.float32), BATCH, None)
    _, hs = jax.lax.scan(body, (c0, n0, m0), (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(dense(params["wo_gate"], x))
    y = rmsnorm(params["norm"], y * o, cfg.norm_eps)
    return dense(params["wo"], y)


def mlstm_init_state(cfg, batch):
    h = cfg.n_heads
    dk = cfg.d_model // h
    return {"C": jnp.zeros((batch, h, dk, dk), jnp.float32),
            "n": jnp.zeros((batch, h, dk), jnp.float32),
            "m": jnp.full((batch, h), -jnp.inf, jnp.float32)}


def mlstm_step(params, cfg, x, state):
    """Decode one token. x [B,1,D]."""
    b, _, d = x.shape
    q, k, v, log_i, log_f, dk = _mlstm_qkvif(params, cfg, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]          # [B,H,dk]
    li, lf = log_i[:, 0], log_f[:, 0]            # [B,H]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_new)
    is_ = jnp.exp(li - m_new)
    C = fs[:, :, None, None] * C + is_[:, :, None, None] * jnp.einsum(
        "bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    n = fs[:, :, None] * n + is_[:, :, None] * k.astype(jnp.float32)
    h_num = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), C)
    qn = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    y = (h_num / denom[..., None]).reshape(b, 1, d).astype(x.dtype)
    o = jax.nn.sigmoid(dense(params["wo_gate"], x))
    y = rmsnorm(params["norm"], y * o, cfg.norm_eps)
    return dense(params["wo"], y), {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------- sLSTM
def slstm_spec(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w{g}"] = dense_spec(d, d, ("embed", "heads"), bias=True)
        gates[f"r{g}"] = P((h, dh, dh), (None, None, None), init="fanin",
                           fan_in=dh)
    gates["norm"] = rmsnorm_spec(d)
    gates["wo"] = dense_spec(d, d, ("heads", "embed"))
    return gates


def slstm(params, cfg, x):
    """x [B,S,D] -> [B,S,D] via sequential scan (sLSTM is not
    parallelizable over time — xLSTM paper §2)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    pre = {g: dense(params[f"w{g}"], x).reshape(b, s, h, dh).astype(
        jnp.float32) for g in ("z", "i", "f", "o")}
    rec = {g: params[f"r{g}"].astype(jnp.float32) for g in
           ("z", "i", "f", "o")}

    def step(carry, xs_):
        c, n, hprev, m = carry
        pz, pi, pf, po = xs_

        def r(g, p):
            return p + jnp.einsum("bhd,hde->bhe", hprev, rec[g])
        z = jnp.tanh(r("z", pz))
        li = r("i", pi)
        lf = jax.nn.log_sigmoid(r("f", pf))
        o = jax.nn.sigmoid(r("o", po))
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        hnew = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, hnew, m_new), hnew

    z0 = shard_act(jnp.zeros((b, h, dh), jnp.float32), BATCH, None, None)
    m0 = shard_act(jnp.full((b, h, dh), -jnp.inf, jnp.float32),
                   BATCH, None, None)
    xs_ = tuple(shard_act(jnp.moveaxis(pre[g], 1, 0),
                          None, BATCH, None, None)
                for g in ("z", "i", "f", "o"))
    _, hs = jax.lax.scan(step, (z0, z0, z0, m0), xs_)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return dense(params["wo"], y)


def slstm_init_state(cfg, batch):
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, h, dh), -jnp.inf, jnp.float32)}


def slstm_step(params, cfg, x, state):
    b, _, d = x.shape
    h = cfg.n_heads
    dh = d // h
    pre = {g: dense(params[f"w{g}"], x).reshape(b, h, dh).astype(jnp.float32)
           for g in ("z", "i", "f", "o")}
    rec = {g: params[f"r{g}"].astype(jnp.float32) for g in
           ("z", "i", "f", "o")}
    c, n, hprev, m = state["c"], state["n"], state["h"], state["m"]

    def r(g):
        return pre[g] + jnp.einsum("bhd,hde->bhe", hprev, rec[g])
    z = jnp.tanh(r("z"))
    li = r("i")
    lf = jax.nn.log_sigmoid(r("f"))
    o = jax.nn.sigmoid(r("o"))
    m_new = jnp.maximum(lf + m, li)
    i_ = jnp.exp(li - m_new)
    f_ = jnp.exp(lf + m - m_new)
    c = f_ * c + i_ * z
    n = f_ * n + i_
    hnew = o * c / jnp.maximum(jnp.abs(n), 1.0)
    y = hnew.reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return dense(params["wo"], y), {"c": c, "n": n, "h": hnew, "m": m_new}
