"""Model assembly: config -> (specs, forward, init_cache, decode_step).

All stacks scan over homogeneous groups (see transformer.py); caches are
stacked along the scan dimension so decode steps scan too.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import ssm, xlstm
from repro.models import transformer as tf
from repro.models.layers import ACT_DTYPE, BATCH, dense, embed, embed_spec, \
    rmsnorm, rmsnorm_spec, shard_act, unembed, unembed_spec
from repro.models.module import abstract_params, stack

CACHE_DTYPE = tf.CACHE_DTYPE


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    specs: Any
    forward: Callable      # (params, run, batch, mesh=None) -> (logits, aux)
    init_cache: Callable   # (batch, max_len) -> cache pytree (zeros)
    decode_step: Callable  # (params, run, tokens[B,1], cache, mesh=None)
                           #   -> (logits [B,1,V], cache)
    prefill: Optional[Callable] = None  # (params, run, tokens, max_len) ->
                                        #   (last logits, cache)

    def cache_specs(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def abstract_params(self):
        return abstract_params(self.specs)


def _head_specs(cfg):
    s = {"embed": embed_spec(cfg.vocab, cfg.d_model),
         "final_norm": rmsnorm_spec(cfg.d_model)}
    if not cfg.tie_embeddings:
        s["unembed"] = unembed_spec(cfg.vocab, cfg.d_model)
    return s


def _logits(params, cfg, x):
    x = shard_act(x, BATCH, None, None)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        out = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                         params["embed"]["table"].astype(jnp.float32))
    else:
        out = unembed(params["unembed"], x)
    return shard_act(out, BATCH, None, "model")


def _positions(s):
    return jnp.arange(s, dtype=jnp.int32)


# ------------------------------------------------------------------ dense
def build_dense(cfg: ModelConfig) -> Model:
    specs = dict(_head_specs(cfg))
    specs["blocks"] = stack(tf.dense_block_spec(cfg), cfg.n_layers)

    def forward(params, run, batch, mesh=None):
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens)
        pos = _positions(tokens.shape[1])
        blk = _wrap_remat(
            lambda p, x: tf.dense_block(p, cfg, run, x, pos), run)

        def body(x, p):
            return blk(p, x), None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return _logits(params, cfg, x), {}

    def init_cache(batch, max_len):
        t = min(max_len, cfg.sliding_window) if cfg.sliding_window \
            else max_len
        shape = (cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, CACHE_DTYPE),
                "v": jnp.zeros(shape, CACHE_DTYPE),
                "pos": jnp.zeros((), jnp.int32)}

    def decode_step(params, run, tokens, cache, mesh=None):
        x = embed(params["embed"], tokens)
        pos = cache["pos"]

        def body(x, xs_):
            p, kc, vc = xs_
            x, kc, vc = tf.dense_block_decode(p, cfg, x, kc, vc, pos)
            return x, (kc, vc)
        x, (k, v) = jax.lax.scan(body, x,
                                 (params["blocks"], cache["k"], cache["v"]))
        return _logits(params, cfg, x), {"k": k, "v": v, "pos": pos + 1}

    def prefill(params, run, tokens, max_len):
        """Run the prompt once, returning (last-position logits, cache)
        ready for decode_step — the serving entry point."""
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        pos = _positions(s)
        t = min(max_len, cfg.sliding_window) if cfg.sliding_window \
            else max_len

        def body(x, p):
            from repro.models.attention import gqa_project_qkv, \
                blockwise_attn, repeat_kv
            from repro.models.layers import rope_tables, dense
            h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
            sin, cos = rope_tables(pos, cfg.hd, cfg.rope_theta)
            q, k, v = gqa_project_qkv(p["attn"], cfg, h, rope=(sin, cos))
            o = blockwise_attn(q, repeat_kv(k, cfg.n_heads),
                               repeat_kv(v, cfg.n_heads), causal=True,
                               window=cfg.sliding_window,
                               chunk_q=run.attn_chunk_q,
                               chunk_kv=run.attn_chunk_kv)
            x = x + dense(p["attn"]["wo"], o.reshape(b, s, -1))
            from repro.models.ffn import ffn as ffn_
            x = x + ffn_(p["ffn"], rmsnorm(p["ffn_norm"], x, cfg.norm_eps),
                         cfg.act)
            if cfg.sliding_window and s > t:
                k, v = k[:, -t:], v[:, -t:]
            pad = t - min(s, t)
            kc = jnp.pad(k.astype(CACHE_DTYPE),
                         ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v.astype(CACHE_DTYPE),
                         ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x, (kc, vc)
        x, (k, v) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": k, "v": v,
                 "pos": jnp.asarray(s, jnp.int32)}
        return _logits(params, cfg, x[:, -1:, :]), cache

    return Model(cfg, specs, forward, init_cache, decode_step,
                 prefill=prefill)


# -------------------------------------------------------------------- moe
def build_moe(cfg: ModelConfig) -> Model:
    specs = dict(_head_specs(cfg))
    fd = cfg.first_dense_layers
    if fd:
        specs["dense_blocks"] = stack(tf.dense_block_spec(cfg), fd)
    specs["blocks"] = stack(tf.moe_block_spec(cfg), cfg.n_layers - fd)

    def forward(params, run, batch, mesh=None):
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens)
        pos = _positions(tokens.shape[1])
        if fd:
            dblk = _wrap_remat(
                lambda p, x: tf.dense_block(p, cfg, run, x, pos), run)
            x, _ = jax.lax.scan(lambda x, p: (dblk(p, x), None), x,
                                params["dense_blocks"])
        mblk = _wrap_remat(
            lambda p, x: tf.moe_block(p, cfg, run, x, pos, mesh), run,
            has_aux=True)

        def body(x, p):
            x, aux = mblk(p, x)
            return x, (aux["lb_loss"], aux["dropped"])
        x, (lb, dropped) = jax.lax.scan(body, x, params["blocks"])
        aux = {"lb_loss": jnp.mean(lb), "dropped": jnp.sum(dropped)}
        return _logits(params, cfg, x), aux

    def init_cache(batch, max_len):
        n = cfg.n_layers - fd
        c: dict = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.mla:
            c["ckv"] = jnp.zeros((n, batch, max_len, cfg.kv_lora),
                                 CACHE_DTYPE)
            c["kr"] = jnp.zeros((n, batch, max_len, cfg.qk_rope_dim),
                                CACHE_DTYPE)
        else:
            t = min(max_len, cfg.sliding_window) if cfg.sliding_window \
                else max_len
            shape = (n, batch, t, cfg.n_kv_heads, cfg.hd)
            c["k"] = jnp.zeros(shape, CACHE_DTYPE)
            c["v"] = jnp.zeros(shape, CACHE_DTYPE)
        if fd:
            shape = (fd, batch, max_len, cfg.n_kv_heads, cfg.hd)
            c["dense_k"] = jnp.zeros(shape, CACHE_DTYPE)
            c["dense_v"] = jnp.zeros(shape, CACHE_DTYPE)
        return c

    def decode_step(params, run, tokens, cache, mesh=None):
        x = embed(params["embed"], tokens)
        pos = cache["pos"]
        new = {"pos": pos + 1}
        if fd:
            def dbody(x, xs_):
                p, kc, vc = xs_
                x, kc, vc = tf.dense_block_decode(p, cfg, x, kc, vc, pos)
                return x, (kc, vc)
            x, (dk, dv) = jax.lax.scan(
                dbody, x, (params["dense_blocks"], cache["dense_k"],
                           cache["dense_v"]))
            new["dense_k"], new["dense_v"] = dk, dv

        if cfg.mla:
            def mbody(x, xs_):
                p, ckv, kr = xs_
                x, nc = tf.moe_block_decode(p, cfg, x,
                                            {"ckv": ckv, "kr": kr}, pos,
                                            mesh)
                return x, (nc["ckv"], nc["kr"])
            x, (ckv, kr) = jax.lax.scan(
                mbody, x, (params["blocks"], cache["ckv"], cache["kr"]))
            new["ckv"], new["kr"] = ckv, kr
        else:
            def mbody(x, xs_):
                p, kc, vc = xs_
                x, nc = tf.moe_block_decode(p, cfg, x, {"k": kc, "v": vc},
                                            pos, mesh)
                return x, (nc["k"], nc["v"])
            x, (k, v) = jax.lax.scan(
                mbody, x, (params["blocks"], cache["k"], cache["v"]))
            new["k"], new["v"] = k, v
        return _logits(params, cfg, x), new

    return Model(cfg, specs, forward, init_cache, decode_step)


# -------------------------------------------------------------------- vlm
def build_vlm(cfg: ModelConfig) -> Model:
    k = cfg.cross_attn_every
    assert cfg.n_layers % k == 0
    g = cfg.n_layers // k
    group_spec = {"selfs": stack(tf.dense_block_spec(cfg), k - 1),
                  "cross": tf.cross_block_spec(cfg)}
    specs = dict(_head_specs(cfg))
    specs["groups"] = stack(group_spec, g, axis_name="groups")

    def forward(params, run, batch, mesh=None):
        tokens = batch["tokens"]
        img = batch["img"].astype(ACT_DTYPE)
        x = embed(params["embed"], tokens)
        pos = _positions(tokens.shape[1])
        sblk = _wrap_remat(
            lambda p, x: tf.dense_block(p, cfg, run, x, pos), run)

        def group(x, p):
            x, _ = jax.lax.scan(lambda x, pp: (sblk(pp, x), None), x,
                                p["selfs"])
            kv = tf.cross_img_kv(p["cross"], cfg, img)
            x = tf.cross_block(p["cross"], cfg, run, x, kv)
            return x, None
        x, _ = jax.lax.scan(group, x, params["groups"])
        return _logits(params, cfg, x), {}

    def init_cache(batch, max_len):
        shape = (g, k - 1, batch, max_len, cfg.n_kv_heads, cfg.hd)
        ishape = (g, batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, CACHE_DTYPE),
                "v": jnp.zeros(shape, CACHE_DTYPE),
                "img_k": jnp.zeros(ishape, CACHE_DTYPE),
                "img_v": jnp.zeros(ishape, CACHE_DTYPE),
                "pos": jnp.zeros((), jnp.int32)}

    def decode_step(params, run, tokens, cache, mesh=None):
        x = embed(params["embed"], tokens)
        pos = cache["pos"]

        def group(x, xs_):
            p, kc, vc, ik, iv = xs_

            def sbody(x, ys_):
                pp, kk, vv = ys_
                x, kk, vv = tf.dense_block_decode(pp, cfg, x, kk, vv, pos)
                return x, (kk, vv)
            x, (kc, vc) = jax.lax.scan(sbody, x, (p["selfs"], kc, vc))
            x = tf.cross_block_decode(p["cross"], cfg, x, ik, iv)
            return x, (kc, vc)
        x, (k, v) = jax.lax.scan(group, x,
                                 (params["groups"], cache["k"], cache["v"],
                                  cache["img_k"], cache["img_v"]))
        return _logits(params, cfg, x), {"k": k, "v": v,
                                         "img_k": cache["img_k"],
                                         "img_v": cache["img_v"],
                                         "pos": pos + 1}

    return Model(cfg, specs, forward, init_cache, decode_step)


# ----------------------------------------------------------------- encdec
def build_encdec(cfg: ModelConfig) -> Model:
    dec_spec = {
        "self_norm": rmsnorm_spec(cfg.d_model),
        "self": tf.gqa_spec(cfg),
        "cross_norm": rmsnorm_spec(cfg.d_model),
        "cross": tf.gqa_spec(cfg),
        "ffn_norm": rmsnorm_spec(cfg.d_model),
        "ffn": tf.ffn_spec(cfg.d_model, cfg.d_ff, cfg.act),
    }
    specs = dict(_head_specs(cfg))
    specs["enc_blocks"] = stack(tf.dense_block_spec(cfg), cfg.enc_layers)
    specs["enc_norm"] = rmsnorm_spec(cfg.d_model)
    specs["dec_blocks"] = stack(dec_spec, cfg.n_layers)

    def encode(params, run, frames):
        pos = _positions(frames.shape[1])
        blk = _wrap_remat(
            lambda p, x: tf.dense_block_bidir(p, cfg, run, x, pos), run)
        x, _ = jax.lax.scan(lambda x, p: (blk(p, x), None), frames,
                            params["enc_blocks"])
        return rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def dec_block(p, x, enc_out, pos, run):
        x = x + tf.gqa_self_attn(p["self"], cfg,
                                 rmsnorm(p["self_norm"], x, cfg.norm_eps),
                                 positions=pos, chunk_q=run.attn_chunk_q,
                                 chunk_kv=run.attn_chunk_kv)
        h = rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        q, kk, vv = tf.gqa_project_qkv(p["cross"], cfg, h, kv_x=enc_out)
        o = tf.blockwise_attn(q, kk, vv, causal=False,
                              chunk_q=run.attn_chunk_q,
                              chunk_kv=run.attn_chunk_kv)
        b, s = x.shape[:2]
        x = x + dense(p["cross"]["wo"], o.reshape(b, s, -1))
        x = x + tf.ffn(p["ffn"], rmsnorm(p["ffn_norm"], x, cfg.norm_eps),
                       cfg.act)
        return x

    def forward(params, run, batch, mesh=None):
        frames = batch["frames"].astype(ACT_DTYPE)
        tokens = batch["tokens"]
        enc_out = encode(params, run, frames)
        x = embed(params["embed"], tokens)
        pos = _positions(tokens.shape[1])
        blk = _wrap_remat(
            lambda p, x: dec_block(p, x, enc_out, pos, run), run)
        x, _ = jax.lax.scan(lambda x, p: (blk(p, x), None), x,
                            params["dec_blocks"])
        return _logits(params, cfg, x), {}

    def init_cache(batch, max_len):
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        cshape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, CACHE_DTYPE),
                "v": jnp.zeros(shape, CACHE_DTYPE),
                "cross_k": jnp.zeros(cshape, CACHE_DTYPE),
                "cross_v": jnp.zeros(cshape, CACHE_DTYPE),
                "pos": jnp.zeros((), jnp.int32)}

    def decode_step(params, run, tokens, cache, mesh=None):
        x = embed(params["embed"], tokens)
        pos = cache["pos"]

        def body(x, xs_):
            p, kc, vc, ck, cv = xs_
            a, kc, vc = tf.gqa_decode_self_attn(
                p["self"], cfg, rmsnorm(p["self_norm"], x, cfg.norm_eps),
                kc, vc, pos)
            x = x + a
            h = rmsnorm(p["cross_norm"], x, cfg.norm_eps)
            b = x.shape[0]
            q = dense(p["cross"]["wq"], h).reshape(b, 1, cfg.n_heads, cfg.hd)
            o = tf.decode_attn(q, ck, cv, ck.shape[1])
            x = x + dense(p["cross"]["wo"], o.reshape(b, 1, -1))
            x = x + tf.ffn(p["ffn"],
                           rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg.act)
            return x, (kc, vc)
        x, (k, v) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        return _logits(params, cfg, x), {"k": k, "v": v,
                                         "cross_k": cache["cross_k"],
                                         "cross_v": cache["cross_v"],
                                         "pos": pos + 1}

    return Model(cfg, specs, forward, init_cache, decode_step)


# --------------------------------------------------------------- ssm hybrid
def build_ssm_hybrid(cfg: ModelConfig) -> Model:
    k = cfg.shared_attn_every
    g, tail = divmod(cfg.n_layers, k)
    group_spec = {"mambas": stack(ssm.mamba2_spec(cfg), k),
                  "lora": tf.shared_lora_spec(cfg)}
    specs = dict(_head_specs(cfg))
    specs["shared"] = tf.shared_attn_spec(cfg)
    specs["groups"] = stack(group_spec, g, axis_name="groups")
    if tail:
        specs["tail"] = stack(ssm.mamba2_spec(cfg), tail)

    def forward(params, run, batch, mesh=None):
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens)
        pos = _positions(tokens.shape[1])
        mblk = _wrap_remat(
            lambda p, x: x + ssm.mamba2(p, cfg, x, chunk=run.ssm_chunk), run)

        def group(x, p):
            x, _ = jax.lax.scan(lambda x, pp: (mblk(pp, x), None), x,
                                p["mambas"])
            x = tf._shared_attn(params["shared"], p["lora"], cfg, run, x,
                                pos)
            return x, None
        x, _ = jax.lax.scan(group, x, params["groups"])
        if tail:
            x, _ = jax.lax.scan(lambda x, pp: (mblk(pp, x), None), x,
                                params["tail"])
        return _logits(params, cfg, x), {}

    def init_cache(batch, max_len):
        one = ssm.mamba2_init_state(cfg, batch, cfg.d_model)
        groups = jax.tree.map(
            lambda a: jnp.zeros((g, k) + a.shape, a.dtype), one)
        cache = {"ssm": groups,
                 "attn_k": jnp.zeros((g, batch, max_len, cfg.n_kv_heads,
                                      cfg.hd), CACHE_DTYPE),
                 "attn_v": jnp.zeros((g, batch, max_len, cfg.n_kv_heads,
                                      cfg.hd), CACHE_DTYPE),
                 "pos": jnp.zeros((), jnp.int32)}
        if tail:
            cache["tail_ssm"] = jax.tree.map(
                lambda a: jnp.zeros((tail,) + a.shape, a.dtype), one)
        return cache

    def decode_step(params, run, tokens, cache, mesh=None):
        x = embed(params["embed"], tokens)
        pos = cache["pos"]

        def group(x, xs_):
            p, st, kc, vc = xs_

            def mbody(x, ys_):
                pp, s = ys_
                y, s = ssm.mamba2_step(pp, cfg, x, s)
                return x + y, s
            x, st = jax.lax.scan(mbody, x, (p["mambas"], st))
            x, kc, vc = tf._shared_attn_decode(params["shared"], p["lora"],
                                               cfg, x, kc, vc, pos)
            return x, (st, kc, vc)
        x, (st, k, v) = jax.lax.scan(
            group, x, (params["groups"], cache["ssm"], cache["attn_k"],
                       cache["attn_v"]))
        new = {"ssm": st, "attn_k": k, "attn_v": v, "pos": pos + 1}
        if tail:
            def mbody(x, ys_):
                pp, s = ys_
                y, s = ssm.mamba2_step(pp, cfg, x, s)
                return x + y, s
            x, ts = jax.lax.scan(mbody, x,
                                 (params["tail"], cache["tail_ssm"]))
            new["tail_ssm"] = ts
        return _logits(params, cfg, x), new

    return Model(cfg, specs, forward, init_cache, decode_step)


# ------------------------------------------------------------------ xlstm
def build_xlstm(cfg: ModelConfig) -> Model:
    k = cfg.slstm_every
    specs = dict(_head_specs(cfg))
    if k:
        assert cfg.n_layers % k == 0
        g = cfg.n_layers // k
        group_spec = {"mlstms": stack(xlstm.mlstm_spec(cfg), k - 1),
                      "slstm": xlstm.slstm_spec(cfg)}
        specs["groups"] = stack(group_spec, g, axis_name="groups")
    else:
        g = 0
        specs["blocks"] = stack(xlstm.mlstm_spec(cfg), cfg.n_layers)

    def forward(params, run, batch, mesh=None):
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens)
        mblk = _wrap_remat(
            lambda p, x: x + xlstm.mlstm(p, cfg, x, chunk=run.ssm_chunk),
            run)
        if k:
            def group(x, p):
                x, _ = jax.lax.scan(lambda x, pp: (mblk(pp, x), None), x,
                                    p["mlstms"])
                x = x + xlstm.slstm(p["slstm"], cfg, x)
                return x, None
            x, _ = jax.lax.scan(group, x, params["groups"])
        else:
            x, _ = jax.lax.scan(lambda x, p: (mblk(p, x), None), x,
                                params["blocks"])
        return _logits(params, cfg, x), {}

    def init_cache(batch, max_len):
        m_one = xlstm.mlstm_init_state(cfg, batch)
        if k:
            s_one = xlstm.slstm_init_state(cfg, batch)
            return {"m": jax.tree.map(
                        lambda a: jnp.zeros((g, k - 1) + a.shape, a.dtype),
                        m_one),
                    "s": jax.tree.map(
                        lambda a: jnp.zeros((g,) + a.shape, a.dtype), s_one),
                    "pos": jnp.zeros((), jnp.int32)}
        return {"m": jax.tree.map(
                    lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype),
                    m_one),
                "pos": jnp.zeros((), jnp.int32)}

    def decode_step(params, run, tokens, cache, mesh=None):
        x = embed(params["embed"], tokens)

        def mbody(x, ys_):
            pp, s = ys_
            y, s = xlstm.mlstm_step(pp, cfg, x, s)
            return x + y, s
        if k:
            def group(x, xs_):
                p, ms, ss_ = xs_
                x, ms = jax.lax.scan(mbody, x, (p["mlstms"], ms))
                y, ss_ = xlstm.slstm_step(p["slstm"], cfg, x, ss_)
                return x + y, (ms, ss_)
            x, (m, s) = jax.lax.scan(group, x,
                                     (params["groups"], cache["m"],
                                      cache["s"]))
            new = {"m": m, "s": s, "pos": cache["pos"] + 1}
        else:
            x, m = jax.lax.scan(mbody, x, (params["blocks"], cache["m"]))
            new = {"m": m, "pos": cache["pos"] + 1}
        return _logits(params, cfg, x), new

    return Model(cfg, specs, forward, init_cache, decode_step)


# -------------------------------------------------------------- dispatcher
BUILDERS = {
    "dense": build_dense,
    "moe": build_moe,
    "vlm": build_vlm,
    "encdec": build_encdec,
    "ssm_hybrid": build_ssm_hybrid,
    "xlstm": build_xlstm,
}


def build_model(cfg: ModelConfig) -> Model:
    return BUILDERS[cfg.family](cfg)


def _wrap_remat(fn, run: RunConfig, has_aux: bool = False):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                model: Optional[Model] = None):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    For train/prefill: token batch (+ modality stubs).  For decode: one-token
    batch + a full cache at seq_len (the dry-run lowers serve_step).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        d: dict = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            d["img"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
        if cfg.family == "encdec":
            d["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
        return d
    # decode
    model = model or build_model(cfg)
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": model.cache_specs(b, s)}
