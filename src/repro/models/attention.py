"""Attention: GQA/MQA/MHA with RoPE, sliding windows, cross-attention, MLA.

Training/prefill uses a blockwise memory-efficient formulation (online
softmax over KV chunks inside a ``lax.scan``) so the [S, S] score matrix is
never materialized — this is what makes 32k prefill fit HBM and keeps the
roofline memory term sane.  Decode uses single-token attention against a KV
cache: full, rolling-window (SWA), or compressed-latent (MLA, with the
matrix-absorbed query path).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import BATCH, apply_rope, dense, \
    dense_spec, rmsnorm, rmsnorm_spec, rope_tables, shard_act

NEG_INF = -1.0e30


# --------------------------------------------------------------------------
# Blockwise attention (train / prefill)
# --------------------------------------------------------------------------
def blockwise_attn(q, k, v, *, causal: bool, window: Optional[int] = None,
                   chunk_q: int = 1024, chunk_kv: int = 1024,
                   q_offset: int = 0):
    """Online-softmax attention over KV chunks.

    q: [B, S, H, D]; k, v: [B, T, KH, D] with H % KH == 0.
    Returns [B, S, H, D] in q.dtype.  Scores/stats are f32.
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]            # may differ from d (MLA)
    g = h // kh
    cq = min(chunk_q, s)
    ck = min(chunk_kv, t)
    nq = -(-s // cq)
    nk = -(-t // ck)
    # Pad sequence dims to chunk multiples (masked out below).
    if nq * cq != s:
        q = jnp.pad(q, ((0, 0), (0, nq * cq - s), (0, 0), (0, 0)))
    if nk * ck != t:
        k = jnp.pad(k, ((0, 0), (0, nk * ck - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * ck - t), (0, 0), (0, 0)))

    scale = 1.0 / math.sqrt(d)
    qb = q.reshape(b, nq, cq, kh, g, d)
    kb = jnp.moveaxis(k.reshape(b, nk, ck, kh, d), 1, 0)     # [nk, b, ck,...]
    vb = jnp.moveaxis(v.reshape(b, nk, ck, kh, dv), 1, 0)
    # Keep batch + head sharding through the chunk reshapes (GSPMD loses it).
    qb = shard_act(qb, BATCH, None, None, "model", None, None)
    kb = shard_act(kb, None, BATCH, None, "model", None)
    vb = shard_act(vb, None, BATCH, None, "model", None)

    qpos = q_offset + jnp.arange(nq * cq).reshape(nq, cq)    # [nq, cq]

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, jblk = xs
        sc = jnp.einsum("bnckgd,bjkd->bnckgj", qb, kc,
                        preferred_element_type=jnp.float32) * scale
        kpos = jblk * ck + jnp.arange(ck)                    # [ck]
        valid = kpos[None, None, :] < t
        ok = valid
        if causal:
            ok = ok & (kpos[None, None, :] <= qpos[:, :, None])
        if window is not None:
            ok = ok & (kpos[None, None, :] > qpos[:, :, None] - window)
        # ok: [nq, cq, ck] -> broadcast to [b, nq, cq, kh, g, ck]
        sc = jnp.where(ok[None, :, :, None, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        # Probabilities materialize in bf16 only (flash-attention practice):
        # the row-sum l accumulates in f32 via the reduce, never as an f32
        # [.., cq, ck] buffer — halves the dominant HBM-traffic term.
        p = jnp.exp(sc - m_new[..., None]).astype(vc.dtype)
        r = jnp.exp(m - m_new)
        l_new = l * r + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc_new = acc * r[..., None] + jnp.einsum(
            "bnckgj,bjkd->bnckgd", p, vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = shard_act(jnp.full((b, nq, cq, kh, g), NEG_INF, jnp.float32),
                   BATCH, None, None, "model", None)
    l0 = shard_act(jnp.zeros((b, nq, cq, kh, g), jnp.float32),
                   BATCH, None, None, "model", None)
    a0 = shard_act(jnp.zeros((b, nq, cq, kh, g, dv), jnp.float32),
                   BATCH, None, None, "model", None, None)
    # Checkpoint the kv-chunk body: without it the backward pass saves the
    # f32 [.., cq, ck] score tile for EVERY chunk step (gigabytes per layer);
    # with it only the (m, l, acc) carries are stacked.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kb, vb, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, nq * cq, h, dv)[:, :s]
    return out.astype(q.dtype)


def decode_attn(q, k_cache, v_cache, valid_len, *,
                window: Optional[int] = None, cache_pos=None):
    """Single-token attention against a cache.

    q: [B, 1, H, D]; caches [B, T, KH, D]; valid_len [] or [B] — number of
    valid cache entries.  For rolling SWA caches pass ``cache_pos`` [B, T]
    giving each slot's absolute position (-1 = empty).
    """
    b, _, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kh, g, d)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    if cache_pos is not None:
        ok = cache_pos[:, None, None, :] >= 0
    else:
        slot = jnp.arange(t)
        vl = jnp.asarray(valid_len)
        vl = vl[:, None, None, None] if vl.ndim else vl
        ok = slot[None, None, None, :] < vl
    sc = jnp.where(ok, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block (self / cross)
# --------------------------------------------------------------------------
def gqa_spec(cfg, d_in=None, kv_d_in=None):
    d = d_in or cfg.d_model
    kv_d = kv_d_in or d
    hd = cfg.hd
    return {
        "wq": dense_spec(d, cfg.n_heads * hd, ("embed", "heads"),
                         bias=cfg.qkv_bias),
        "wk": dense_spec(kv_d, cfg.n_kv_heads * hd, ("embed", "heads"),
                         bias=cfg.qkv_bias),
        "wv": dense_spec(kv_d, cfg.n_kv_heads * hd, ("embed", "heads"),
                         bias=cfg.qkv_bias),
        "wo": dense_spec(cfg.n_heads * hd, cfg.d_model, ("heads", "embed")),
    }


def gqa_project_qkv(params, cfg, x, kv_x=None, rope=None):
    """x [B,S,D] -> q [B,S,H,hd], k/v [B,T,KH,hd] (rope applied if given)."""
    b, s, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    t = kv_x.shape[1]
    hd = cfg.hd
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(params["wk"], kv_x).reshape(b, t, cfg.n_kv_heads, hd)
    v = dense(params["wv"], kv_x).reshape(b, t, cfg.n_kv_heads, hd)
    q = shard_act(q, BATCH, None, "model", None)
    k = shard_act(k, BATCH, None, "model", None)
    v = shard_act(v, BATCH, None, "model", None)
    if rope is not None:
        sin, cos = rope
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    return q, k, v


def repeat_kv(k, n_heads):
    """Expand KV heads to n_heads so the head axis TP-shards even when
    n_kv_heads < the model-axis extent (train/prefill only — the decode
    cache keeps grouped KV heads).  FLOPs are unchanged; the repeated KV is
    re-sharded over the full head axis."""
    b, t, kh, d = k.shape
    g = n_heads // kh
    if g == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, kh, g, d))
    return shard_act(k.reshape(b, t, kh * g, d), BATCH, None, "model", None)


def gqa_self_attn(params, cfg, x, *, positions, chunk_q, chunk_kv,
                  causal=True):
    sin, cos = rope_tables(positions, cfg.hd, cfg.rope_theta)
    q, k, v = gqa_project_qkv(params, cfg, x, rope=(sin, cos))
    k = repeat_kv(k, cfg.n_heads)
    v = repeat_kv(v, cfg.n_heads)
    o = blockwise_attn(q, k, v, causal=causal, window=cfg.sliding_window,
                       chunk_q=chunk_q, chunk_kv=chunk_kv)
    b, s = x.shape[:2]
    return dense(params["wo"], o.reshape(b, s, -1))


def gqa_decode_self_attn(params, cfg, x, k_cache, v_cache, pos):
    """x [B,1,D]; per-layer caches [B,T,KH,hd]; pos [] absolute position.
    Returns (out [B,1,D], k_cache, v_cache updated).  For SWA the cache is a
    rolling buffer of length == window."""
    b = x.shape[0]
    hd = cfg.hd
    sin, cos = rope_tables(pos[None], hd, cfg.rope_theta)
    q = dense(params["wq"], x).reshape(b, 1, cfg.n_heads, hd)
    k = dense(params["wk"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    v = dense(params["wv"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    t = k_cache.shape[1]
    slot = (pos % t) if cfg.sliding_window else jnp.minimum(pos, t - 1)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, axis=1)
    if cfg.sliding_window:
        # Rolling buffer: slot i holds absolute position pos - ((slot-i) % t),
        # valid iff non-negative.
        idx = jnp.arange(t)
        age = (slot - idx) % t
        cache_pos = jnp.where(age <= jnp.minimum(pos, t - 1), pos - age, -1)
        cache_pos = jnp.broadcast_to(cache_pos[None, :], (b, t))
        o = decode_attn(q, k_cache, v_cache, None, cache_pos=cache_pos)
    else:
        o = decode_attn(q, k_cache, v_cache, pos + 1)
    out = dense(params["wo"], o.reshape(b, 1, -1))
    return out, k_cache, v_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# --------------------------------------------------------------------------
def mla_spec(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    qk_d = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wdq": dense_spec(d, cfg.q_lora, ("embed", "q_lora")),
        "q_norm": rmsnorm_spec(cfg.q_lora),
        "wuq": dense_spec(cfg.q_lora, h * qk_d, ("q_lora", "heads")),
        "wdkv": dense_spec(d, cfg.kv_lora, ("embed", "kv_lora")),
        "kv_norm": rmsnorm_spec(cfg.kv_lora),
        "wuk": dense_spec(cfg.kv_lora, h * cfg.qk_nope_dim,
                          ("kv_lora", "heads")),
        "wuv": dense_spec(cfg.kv_lora, h * cfg.v_head_dim,
                          ("kv_lora", "heads")),
        "wkr": dense_spec(d, cfg.qk_rope_dim, ("embed", None)),
        "wo": dense_spec(h * cfg.v_head_dim, d, ("heads", "embed")),
    }


def _mla_qkr(params, cfg, x, positions):
    """Shared q / rope-key computation. x [B,S,D]."""
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(params["q_norm"], dense(params["wdq"], x), cfg.norm_eps)
    q = shard_act(dense(params["wuq"], cq), BATCH, None, "model").reshape(
        b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim:]
    sin, cos = rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    kr = dense(params["wkr"], x).reshape(b, s, 1, cfg.qk_rope_dim)
    kr = apply_rope(kr, sin, cos)
    return q_nope, q_rope, kr, (sin, cos)


def mla_self_attn(params, cfg, x, *, positions, chunk_q, chunk_kv):
    """Training/prefill MLA in the expanded (naive) form."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, kr, _ = _mla_qkr(params, cfg, x, positions)
    ckv = rmsnorm(params["kv_norm"], dense(params["wdkv"], x), cfg.norm_eps)
    k_nope = dense(params["wuk"], ckv).reshape(b, s, h, cfg.qk_nope_dim)
    v = dense(params["wuv"], ckv).reshape(b, s, h, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr, (b, s, h, cfg.qk_rope_dim))], axis=-1)
    q = shard_act(q, BATCH, None, "model", None)
    k = shard_act(k, BATCH, None, "model", None)
    v = shard_act(v, BATCH, None, "model", None)
    o = blockwise_attn(q, k, v, causal=True, chunk_q=chunk_q,
                       chunk_kv=chunk_kv)
    return dense(params["wo"], o.reshape(b, s, -1))


def mla_decode_self_attn(params, cfg, x, ckv, kr, pos):
    """Decode with the compressed cache (c_kv + k_rope) and absorbed mats.

    ckv: [B,T,kv_lora]; kr: [B,T,rope_d]; pos: [] absolute position.
    Scores = q_nope W_uk^T . ckv + q_rope . k_rope;  out = (P . ckv) W_uv.
    Returns (out [B,1,D], ckv, kr updated).
    """
    b = x.shape[0]
    h = cfg.n_heads
    q_nope, q_rope, kr_new, _ = _mla_qkr(params, cfg, x, pos[None])
    ckv_new = rmsnorm(params["kv_norm"], dense(params["wdkv"], x),
                      cfg.norm_eps)
    t = ckv.shape[1]
    slot = jnp.minimum(pos, t - 1)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        ckv, ckv_new.astype(ckv.dtype), slot, 1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        kr, kr_new[:, :, 0].astype(kr.dtype), slot, 1)
    wuk = params["wuk"]["w"].reshape(cfg.kv_lora, h, cfg.qk_nope_dim)
    # Absorb W_uk into the query:  [B,1,H,nope] x [C,H,nope] -> [B,H,C]
    q_abs = jnp.einsum("bshn,chn->bhc", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    sc = jnp.einsum("bhc,btc->bht", q_abs, ckv.astype(jnp.float32))
    sc = sc + jnp.einsum("bshr,btr->bht", q_rope.astype(jnp.float32),
                         kr.astype(jnp.float32))
    sc = sc / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    ok = jnp.arange(t)[None, None, :] < (pos + 1)
    sc = jnp.where(ok, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    octx = jnp.einsum("bht,btc->bhc", p, ckv.astype(jnp.float32))
    wuv = params["wuv"]["w"].reshape(cfg.kv_lora, h, cfg.v_head_dim)
    o = jnp.einsum("bhc,chv->bhv", octx, wuv.astype(jnp.float32))
    out = dense(params["wo"], o.reshape(b, 1, -1).astype(x.dtype))
    return out, ckv, kr
