"""Feed-forward variants: SwiGLU (llama family), squared-ReLU (nemotron),
GELU (enc-dec)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_spec


def ffn_spec(d, d_ff, act: str):
    if act == "swiglu":
        return {
            "w_gate": dense_spec(d, d_ff, ("embed", "mlp")),
            "w_up": dense_spec(d, d_ff, ("embed", "mlp")),
            "w_down": dense_spec(d_ff, d, ("mlp", "embed")),
        }
    return {
        "w_up": dense_spec(d, d_ff, ("embed", "mlp")),
        "w_down": dense_spec(d_ff, d, ("mlp", "embed")),
    }


def ffn(params, x, act: str):
    if act == "swiglu":
        g = dense(params["w_gate"], x)
        u = dense(params["w_up"], x)
        h = jax.nn.silu(g) * u
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(dense(params["w_up"], x)))
    elif act == "gelu":
        h = jax.nn.gelu(dense(params["w_up"], x))
    else:
        raise ValueError(act)
    return dense(params["w_down"], h)
