"""Scanned transformer stacks for all six architecture families.

Every stack is built from *homogeneous scanned groups* so HLO size (and
compile time) is independent of depth:

  dense       L x [attn + ffn]
  moe         first_dense x [attn + ffn]  +  scan (L-fd) x [attn + MoE]
  vlm         scan G x [(k-1) self blocks + 1 gated cross-attn block]
  encdec      scan Le x [enc block]  +  scan Ld x [dec self + cross + ffn]
  ssm_hybrid  scan G x [k Mamba2 blocks + shared-attn invocation (LoRA)]
  xlstm       scan G x [(k-1) mLSTM + 1 sLSTM]  (or uniform mLSTM)

Decode steps mirror the same group structure with stacked caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models.attention import blockwise_attn, decode_attn, \
    gqa_decode_self_attn, gqa_project_qkv, gqa_self_attn, gqa_spec, \
    mla_decode_self_attn, mla_self_attn, mla_spec
from repro.models.ffn import ffn, ffn_spec
from repro.models.layers import ACT_DTYPE, BATCH, dense, rmsnorm, \
    rmsnorm_spec, rope_tables, shard_act
from repro.models.module import P
from repro.models.moe import moe_ffn, moe_spec

CACHE_DTYPE = jnp.bfloat16


def _remat(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ============================================================== dense block
def dense_block_spec(cfg):
    return {
        "attn_norm": rmsnorm_spec(cfg.d_model),
        "attn": gqa_spec(cfg),
        "ffn_norm": rmsnorm_spec(cfg.d_model),
        "ffn": ffn_spec(cfg.d_model, cfg.d_ff, cfg.act),
    }


def dense_block(p, cfg, run, x, positions):
    # Sequence-parallel residual: the saved-for-backward layer inputs are
    # sharded over ("model", seq) instead of replicated (Megatron SP) —
    # GSPMD places the gathers (measured better than explicit per-sublayer
    # AG/RS placement: see EXPERIMENTS.md §Perf iteration 1.2, refuted).
    # bf16 cast guards against f32 creep in the scan carry.  Norm outputs
    # are pinned seq-sharded so the SP->TP transition happens on the small
    # bf16 q/kv projections (all-to-all / kv-gather), never on the f32
    # norm internals (measured 159 GB/step of f32 residual gathers on yi).
    x = shard_act(x.astype(ACT_DTYPE), BATCH, "model", None)
    h = shard_act(rmsnorm(p["attn_norm"], x, cfg.norm_eps),
                  BATCH, "model", None)
    x = x + gqa_self_attn(p["attn"], cfg, h, positions=positions,
                          chunk_q=run.attn_chunk_q,
                          chunk_kv=run.attn_chunk_kv)
    h = shard_act(rmsnorm(p["ffn_norm"], x, cfg.norm_eps),
                  BATCH, "model", None)
    x = x + ffn(p["ffn"], h, cfg.act)
    return x


def dense_block_bidir(p, cfg, run, x, positions):
    """Encoder block: bidirectional self-attention (seamless-m4t encoder)."""
    x = shard_act(x.astype(ACT_DTYPE), BATCH, "model", None)
    x = x + gqa_self_attn(p["attn"], cfg, rmsnorm(p["attn_norm"], x,
                                                  cfg.norm_eps),
                          positions=positions, chunk_q=run.attn_chunk_q,
                          chunk_kv=run.attn_chunk_kv, causal=False)
    x = x + ffn(p["ffn"], rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg.act)
    return x


def dense_block_decode(p, cfg, x, kc, vc, pos):
    a, kc, vc = gqa_decode_self_attn(
        p["attn"], cfg, rmsnorm(p["attn_norm"], x, cfg.norm_eps), kc, vc,
        pos)
    x = x + a
    x = x + ffn(p["ffn"], rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg.act)
    return x, kc, vc


# ================================================================ moe block
def moe_block_spec(cfg):
    attn = mla_spec(cfg) if cfg.mla else gqa_spec(cfg)
    return {
        "attn_norm": rmsnorm_spec(cfg.d_model),
        "attn": attn,
        "ffn_norm": rmsnorm_spec(cfg.d_model),
        "moe": moe_spec(cfg),
    }


def moe_block(p, cfg, run, x, positions, mesh):
    x = shard_act(x.astype(ACT_DTYPE), BATCH, "model", None)
    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    if cfg.mla:
        a = mla_self_attn(p["attn"], cfg, h, positions=positions,
                          chunk_q=run.attn_chunk_q,
                          chunk_kv=run.attn_chunk_kv)
    else:
        a = gqa_self_attn(p["attn"], cfg, h, positions=positions,
                          chunk_q=run.attn_chunk_q,
                          chunk_kv=run.attn_chunk_kv)
    x = x + a
    y, aux = moe_ffn(p["moe"], cfg, rmsnorm(p["ffn_norm"], x, cfg.norm_eps),
                     mesh=mesh)
    return x + y, aux


def moe_block_decode(p, cfg, x, cache_slices, pos, mesh):
    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    if cfg.mla:
        a, ckv, kr = mla_decode_self_attn(p["attn"], cfg, h,
                                          cache_slices["ckv"],
                                          cache_slices["kr"], pos)
        new_cache = {"ckv": ckv, "kr": kr}
    else:
        a, kc, vc = gqa_decode_self_attn(p["attn"], cfg, h,
                                         cache_slices["k"],
                                         cache_slices["v"], pos)
        new_cache = {"k": kc, "v": vc}
    x = x + a
    y, _ = moe_ffn(p["moe"], cfg, rmsnorm(p["ffn_norm"], x, cfg.norm_eps),
                   mesh=mesh)
    return x + y, new_cache


# ============================================================== cross block
def cross_block_spec(cfg):
    return {
        "norm": rmsnorm_spec(cfg.d_model),
        "attn": gqa_spec(cfg, kv_d_in=cfg.d_vision),
        "gate": P((1,), (None,), init="zeros"),
        "ffn_norm": rmsnorm_spec(cfg.d_model),
        "ffn": ffn_spec(cfg.d_model, cfg.d_ff, cfg.act),
        "ffn_gate": P((1,), (None,), init="zeros"),
    }


def cross_block(p, cfg, run, x, img_kv):
    """Gated cross-attention (llama-3.2-vision style)."""
    x = shard_act(x.astype(ACT_DTYPE), BATCH, "model", None)
    k, v = img_kv
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    b, s, _ = x.shape
    hd = cfg.hd
    q = dense(p["attn"]["wq"], h).reshape(b, s, cfg.n_heads, hd)
    o = blockwise_attn(q, k, v, causal=False, chunk_q=run.attn_chunk_q,
                       chunk_kv=run.attn_chunk_kv)
    o = dense(p["attn"]["wo"], o.reshape(b, s, -1))
    x = x + jnp.tanh(p["gate"]).astype(x.dtype) * o
    x = x + jnp.tanh(p["ffn_gate"]).astype(x.dtype) * ffn(
        p["ffn"], rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg.act)
    return x


def cross_img_kv(p, cfg, img):
    """Precompute cross-attention K/V from vision embeddings [B,T,dv]."""
    b, t, _ = img.shape
    hd = cfg.hd
    k = dense(p["attn"]["wk"], img).reshape(b, t, cfg.n_kv_heads, hd)
    v = dense(p["attn"]["wv"], img).reshape(b, t, cfg.n_kv_heads, hd)
    return k, v


def cross_block_decode(p, cfg, x, img_k, img_v):
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    b = x.shape[0]
    q = dense(p["attn"]["wq"], h).reshape(b, 1, cfg.n_heads, cfg.hd)
    o = decode_attn(q, img_k, img_v, img_k.shape[1])
    o = dense(p["attn"]["wo"], o.reshape(b, 1, -1))
    x = x + jnp.tanh(p["gate"]).astype(x.dtype) * o
    x = x + jnp.tanh(p["ffn_gate"]).astype(x.dtype) * ffn(
        p["ffn"], rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg.act)
    return x


# ======================================================== ssm hybrid blocks
def shared_attn_spec(cfg):
    """zamba2 shared attention+ffn block (params shared across invocations;
    per-invocation LoRA adapters are scanned separately)."""
    return {
        "norm": rmsnorm_spec(cfg.d_model),
        "attn": gqa_spec(cfg),
        "ffn_norm": rmsnorm_spec(cfg.d_model),
        "ffn": ffn_spec(cfg.d_model, cfg.d_ff, cfg.act),
    }


def shared_lora_spec(cfg):
    r = cfg.shared_lora_rank
    d = cfg.d_model
    return {
        "a_q": P((d, r), ("embed", None), init="fanin", fan_in=d),
        "b_q": P((r, cfg.n_heads * cfg.hd), (None, "heads"), init="zeros"),
    }


def _shared_attn(shared, lora, cfg, run, x, positions):
    x = shard_act(x.astype(ACT_DTYPE), BATCH, "model", None)
    h = rmsnorm(shared["norm"], x, cfg.norm_eps)
    q_lora = jnp.einsum("...d,dr->...r", h, lora["a_q"].astype(h.dtype))
    q_extra = jnp.einsum("...r,rh->...h", q_lora,
                         lora["b_q"].astype(h.dtype))
    b, s, _ = x.shape
    hd = cfg.hd
    sin, cos = rope_tables(positions, hd, cfg.rope_theta)
    q, k, v = gqa_project_qkv(shared["attn"], cfg, h, rope=None)
    q = q + q_extra.reshape(b, s, cfg.n_heads, hd)
    from repro.models.attention import apply_rope
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    o = blockwise_attn(q, k, v, causal=True, chunk_q=run.attn_chunk_q,
                       chunk_kv=run.attn_chunk_kv)
    x = x + dense(shared["attn"]["wo"], o.reshape(b, s, -1))
    x = x + ffn(shared["ffn"], rmsnorm(shared["ffn_norm"], x, cfg.norm_eps),
                cfg.act)
    return x


def _shared_attn_decode(shared, lora, cfg, x, kc, vc, pos):
    h = rmsnorm(shared["norm"], x, cfg.norm_eps)
    q_extra = jnp.einsum("...r,rh->...h",
                         jnp.einsum("...d,dr->...r", h,
                                    lora["a_q"].astype(h.dtype)),
                         lora["b_q"].astype(h.dtype))
    b = x.shape[0]
    hd = cfg.hd
    from repro.models.attention import apply_rope
    sin, cos = rope_tables(pos[None], hd, cfg.rope_theta)
    q = dense(shared["attn"]["wq"], h).reshape(b, 1, cfg.n_heads, hd)
    q = q + q_extra.reshape(b, 1, cfg.n_heads, hd)
    k = dense(shared["attn"]["wk"], h).reshape(b, 1, cfg.n_kv_heads, hd)
    v = dense(shared["attn"]["wv"], h).reshape(b, 1, cfg.n_kv_heads, hd)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    t = kc.shape[1]
    slot = jnp.minimum(pos, t - 1)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
    o = decode_attn(q, kc, vc, pos + 1)
    x = x + dense(shared["attn"]["wo"], o.reshape(b, 1, -1))
    x = x + ffn(shared["ffn"], rmsnorm(shared["ffn_norm"], x, cfg.norm_eps),
                cfg.act)
    return x, kc, vc
