"""Request tracing for the geo serving stack (DESIGN.md §15).

The serving benchmarks can say *that* a latency SLO broke; this module
says *where* the milliseconds went.  A ``Tracer`` hands out one
``RequestTrace`` per sampled request; the serving layer records spans
against it as the request moves through the pipeline::

    request                      (root: submit -> future resolved)
      ├─ submit                  (client call -> accepted by the queue)
      ├─ queue_wait              (in the batcher, re-opened per retry)
      ├─ host_prepare            (HOST stage, per micro-batch)
      │    ├─ route              (region ownership masks)
      │    ├─ cache_lookup       (hot-cell probe, per region)
      │    └─ cache_learn        (interior-code inserts, per region)
      ├─ device_assign           (padded engine assign, per region)
      ├─ retry                   (instant: batch failed, slices requeued)
      └─ merge                   (ticket fills -> request completion)

Spans carry explicit parentage (``parent_id``), a monotonic
``time.perf_counter`` interval, the recording thread, and free-form
attributes (region, bucket, attempt, ...), so one request's timeline
reconstructs even when its micro-batches complete on different replica
threads or survive requeues and retries.

**Sampling** is head-based and atomic per request: the keep/drop
decision is made once, at ``start_trace``, with a deterministic
credit accumulator (exact long-run rate, no RNG); an unsampled request
gets ``None`` and *no* code path records a child span for it — whole
requests drop, orphan children are impossible by construction.  The
default ~1% rate keeps tracing on in production without drowning the
hot path (the overhead budget is enforced by
``benchmarks/trace_overhead.py``).

**Storage** is a bounded, lock-guarded ``SpanBuffer`` (drop-oldest,
drops counted) so a long-running server cannot leak memory through its
own observability.

**Export**: ``export_spans`` writes the raw span dump (JSON list);
``export_chrome`` writes the Chrome-trace / Perfetto event format
(``chrome://tracing`` opens it directly) with one *process* row per
request and one *thread* row per serving thread, which is exactly the
per-request timeline view.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Optional

__all__ = ["Span", "SpanBuffer", "RequestTrace", "Tracer"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished interval.  ``t0``/``t1`` are ``time.perf_counter``
    seconds (monotonic, comparable only within a process)."""

    trace_id: int
    span_id: int
    parent_id: Optional[int]
    name: str
    t0: float
    t1: float
    thread: str
    attrs: dict

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dur_ms"] = (self.t1 - self.t0) * 1e3
        return d


class SpanBuffer:
    """Bounded drop-oldest span store.  Appends and snapshots run under
    one lock; overflow is counted (``dropped``), never raised — tracing
    must not be able to fail the serve path."""

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._spans: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.dropped = 0

    def append(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(span)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def snapshot(self) -> list:
        """Stable copy of the buffered spans (oldest first)."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0


class RequestTrace:
    """One sampled request's span handle.  The root span's interval is
    [the ``t0`` given to ``start_trace``, the ``end()`` call]; children
    are recorded eagerly as their stages finish.  Thread-safe: span-id
    allocation and buffer appends go through the owning tracer's lock
    and lock-guarded buffer."""

    __slots__ = ("tracer", "trace_id", "root_id", "_t0", "_ended")

    def __init__(self, tracer: "Tracer", trace_id: int, root_id: int,
                 t0: float):
        self.tracer = tracer
        self.trace_id = trace_id
        self.root_id = root_id
        self._t0 = t0
        self._ended = False

    def span(self, name: str, t0: float, t1: float,
             parent: Optional[int] = None, **attrs) -> int:
        """Record one finished child span; returns its span id (usable
        as ``parent`` for sub-spans).  ``parent=None`` parents to the
        root span."""
        sid = self.tracer._next_span_id()
        self.tracer.buffer.append(Span(
            trace_id=self.trace_id, span_id=sid,
            parent_id=self.root_id if parent is None else parent,
            name=name, t0=float(t0), t1=float(t1),
            thread=threading.current_thread().name, attrs=dict(attrs)))
        return sid

    def event(self, name: str, **attrs) -> int:
        """Instant (zero-duration) child span at now — retries et al."""
        now = time.perf_counter()
        return self.span(name, now, now, **attrs)

    def end(self, t1: Optional[float] = None, **attrs) -> None:
        """Close the root span (records it).  Idempotent: a request can
        fail after partial service and both paths may try to close it —
        the first close wins, so every sampled request has exactly one
        root span."""
        with self.tracer._lock:
            if self._ended:
                return
            self._ended = True
            sid = self.root_id
        self.tracer.buffer.append(Span(
            trace_id=self.trace_id, span_id=sid, parent_id=None,
            name="request", t0=self._t0,
            t1=time.perf_counter() if t1 is None else float(t1),
            thread=threading.current_thread().name, attrs=dict(attrs)))


class Tracer:
    """Per-server span factory: head-based sampling + bounded buffer +
    exporters (see module docstring)."""

    def __init__(self, sample_rate: float = 0.01,
                 capacity: int = 1 << 16):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], "
                             f"got {sample_rate}")
        self.sample_rate = float(sample_rate)
        self.buffer = SpanBuffer(capacity)
        self._lock = threading.Lock()
        self._credit = 0.0          # deterministic sampling accumulator
        self._ids = 0               # shared trace/span id counter
        self.started = 0            # requests seen
        self.sampled = 0            # requests kept

    def _next_span_id(self) -> int:
        with self._lock:
            self._ids += 1
            return self._ids

    def start_trace(self, t0: Optional[float] = None
                    ) -> Optional[RequestTrace]:
        """The head-based sampling gate: returns a ``RequestTrace`` for
        a kept request, ``None`` for a dropped one.  The decision is a
        credit accumulator (+rate per request, spend 1.0 to sample), so
        exactly ``round(n * rate)`` of every n requests are kept, in a
        deterministic pattern — reproducible traces, no RNG on the hot
        path."""
        with self._lock:
            self.started += 1
            self._credit += self.sample_rate
            if self._credit < 1.0:
                return None
            self._credit -= 1.0
            self.sampled += 1
            self._ids += 2
            trace_id, root_id = self._ids - 1, self._ids
        return RequestTrace(self, trace_id, root_id,
                            time.perf_counter() if t0 is None else t0)

    # -- export --------------------------------------------------------------

    def spans_json(self) -> list:
        return [s.as_dict() for s in self.buffer.snapshot()]

    def export_spans(self, path: str) -> int:
        """Raw span dump: a JSON list of span dicts; returns span
        count."""
        spans = self.spans_json()
        with open(path, "w") as f:
            json.dump({"spans": spans, "dropped": self.buffer.dropped,
                       "started": self.started, "sampled": self.sampled},
                      f, indent=1)
        return len(spans)

    def chrome_events(self) -> list:
        """Chrome-trace events: one complete ("X") event per span, with
        ``pid`` = the request (so every request gets its own process row
        in chrome://tracing / Perfetto — the per-request timeline view)
        and ``tid`` = the serving thread, named via metadata events.
        Timestamps re-base to the earliest span so they start near 0."""
        spans = self.buffer.snapshot()
        if not spans:
            return []
        epoch = min(s.t0 for s in spans)
        tids: dict[str, int] = {}
        events = []
        seen_threads = set()
        for s in spans:
            tid = tids.setdefault(s.thread, len(tids) + 1)
            if (s.trace_id, tid) not in seen_threads:
                seen_threads.add((s.trace_id, tid))
                events.append({"ph": "M", "name": "thread_name",
                               "pid": s.trace_id, "tid": tid,
                               "args": {"name": s.thread}})
            args = {"trace_id": s.trace_id, "span_id": s.span_id,
                    "parent_id": s.parent_id}
            args.update(s.attrs)
            events.append({"ph": "X", "cat": "serve", "name": s.name,
                           "pid": s.trace_id, "tid": tid,
                           "ts": (s.t0 - epoch) * 1e6,
                           "dur": max((s.t1 - s.t0) * 1e6, 0.0),
                           "args": args})
        return events

    def export_chrome(self, path: str) -> int:
        """Chrome-trace file (open in chrome://tracing or Perfetto);
        returns the event count."""
        events = self.chrome_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f, indent=1)
        return len(events)

    def stats(self) -> dict:
        with self._lock:
            started, sampled = self.started, self.sampled
        return {"started": started, "sampled": sampled,
                "buffered": len(self.buffer),
                "dropped": self.buffer.dropped,
                "sample_rate": self.sample_rate}
