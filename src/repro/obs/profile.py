"""Opt-in ``jax.profiler`` hooks for the serving stack (DESIGN.md §15).

The tracer (obs/trace.py) attributes *host-observed* wall time; when a
device stage itself needs opening up (which kernel, which fusion, how
much HBM traffic), the JAX profiler is the right tool.  This module is
the thin, failure-proof seam between the two:

* ``device_annotation(name)`` — context manager wrapping
  ``jax.profiler.TraceAnnotation``, so device-stage assigns show up as
  named ranges in a captured device trace (TensorBoard / Perfetto).
  ``GeoServer`` applies it around every padded assign when
  ``ServeConfig.trace_device=True``.
* ``start_profile(logdir)`` / ``stop_profile()`` — the capture pair
  (``jax.profiler.start_trace``/``stop_trace``), exposed on
  ``GeoServer`` so a load run can bracket its SLO trial with a device
  trace capture.

Every entry point degrades to a no-op (with a one-line warning once)
if the profiler is unavailable or refuses — observability must never
be able to take the serve path down.
"""
from __future__ import annotations

import contextlib
import threading

__all__ = ["device_annotation", "start_profile", "stop_profile",
           "profiler_available"]

_warned = set()
_warn_lock = threading.Lock()


def _warn_once(key: str, msg: str) -> None:
    with _warn_lock:
        if key in _warned:
            return
        _warned.add(key)
    print(f"obs.profile: {msg}")


def profiler_available() -> bool:
    try:
        import jax.profiler  # noqa: F401
        return hasattr(jax.profiler, "TraceAnnotation")
    except Exception:                      # pragma: no cover - env-specific
        return False


@contextlib.contextmanager
def device_annotation(name: str):
    """Named profiler range around a device call; no-op when the
    profiler is unavailable."""
    try:
        import jax.profiler
        ctx = jax.profiler.TraceAnnotation(name)
    except Exception:                      # pragma: no cover - env-specific
        _warn_once("annotation", "jax.profiler.TraceAnnotation "
                                 "unavailable — device annotations off")
        yield
        return
    with ctx:
        yield


def start_profile(logdir: str) -> bool:
    """Begin a device trace capture into ``logdir``; True if it
    started.  Refusals (already active, missing profiler) warn once and
    return False instead of raising."""
    try:
        import jax.profiler
        jax.profiler.start_trace(logdir)
        return True
    except Exception as e:                 # pragma: no cover - env-specific
        _warn_once("start", f"start_trace failed ({e}) — profiling off")
        return False


def stop_profile() -> bool:
    """End the active capture; True if one was stopped."""
    try:
        import jax.profiler
        jax.profiler.stop_trace()
        return True
    except Exception as e:                 # pragma: no cover - env-specific
        _warn_once("stop", f"stop_trace failed ({e})")
        return False
