"""Log-bucketed latency histograms for per-stage attribution
(DESIGN.md §15).

``LatencyWindow`` (serving/metrics.py) keeps the last N raw samples —
exact percentiles, but window-local and per-sample memory.  Stage
attribution needs the opposite trade: every observation ever, O(1)
memory, mergeable across servers/replicas, and percentiles good enough
to ratchet on.  ``LatencyHistogram`` is that structure:

* **fixed log-spaced buckets**: bucket ``i`` covers
  ``(lo * g**i, lo * g**(i+1)]`` with growth ``g = 2 ** (1/per_octave)``
  — the default (1 µs .. 64 s, 4 buckets per octave) resolves any
  quantile to within ±9% of its true value, constant across nine
  decades of latency;
* **mergeable**: two histograms with the same layout merge by summing
  counts — associative and commutative, so replica- or region-local
  histograms aggregate in any order (tested);
* **bounded error**: ``quantile`` answers with the geometric midpoint
  of the owning bucket — exact p50/p99 *within bucket resolution*, the
  contract the bench breakdown columns ratchet on.

Thread safety: one lock per histogram guards observe/merge/snapshot
(the counts array is a read-modify-write).  ``observe`` is a couple of
float ops + one array increment — cheap enough to run unsampled on the
serve path.
"""
from __future__ import annotations

import math
import threading

import numpy as np

__all__ = ["LatencyHistogram", "DEFAULT_LO_S", "DEFAULT_HI_S",
           "DEFAULT_PER_OCTAVE"]

DEFAULT_LO_S = 1e-6          # first bucket upper bound: 1 µs
DEFAULT_HI_S = 64.0          # last finite bound covers >= 64 s
DEFAULT_PER_OCTAVE = 4       # buckets per factor-of-2 (±9% resolution)


class LatencyHistogram:
    """Fixed-layout log-bucketed histogram (see module docstring)."""

    def __init__(self, lo: float = DEFAULT_LO_S, hi: float = DEFAULT_HI_S,
                 per_octave: int = DEFAULT_PER_OCTAVE):
        if lo <= 0 or hi <= lo or per_octave < 1:
            raise ValueError(f"bad layout lo={lo} hi={hi} "
                             f"per_octave={per_octave}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.per_octave = int(per_octave)
        n = int(math.ceil(math.log2(hi / lo) * per_octave))
        # uppers[i] = inclusive upper bound of bucket i; bucket 0 also
        # absorbs everything <= lo (incl. 0), the last bucket is the
        # overflow (> uppers[-2], i.e. > hi).
        self.uppers = self.lo * np.exp2((np.arange(n) + 1.0)
                                        / self.per_octave)
        self.counts = np.zeros(n + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._lock = threading.Lock()

    def layout(self) -> tuple:
        return (self.lo, self.hi, self.per_octave)

    def observe(self, seconds: float) -> None:
        s = float(seconds)
        ix = int(np.searchsorted(self.uppers, s, side="left"))
        with self._lock:
            self.counts[ix] += 1
            self.count += 1
            self.sum += s
            if s > self.max:
                self.max = s

    # -- aggregation ---------------------------------------------------------

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """New histogram = self + other.  Same-layout only (counts are
        meaningless across bucket layouts); associative + commutative,
        so region/replica histograms fold in any order."""
        if self.layout() != other.layout():
            raise ValueError(f"cannot merge layouts {self.layout()} "
                             f"and {other.layout()}")
        out = LatencyHistogram(self.lo, self.hi, self.per_octave)
        with self._lock:
            a_counts, a_count = self.counts.copy(), self.count
            a_sum, a_max = self.sum, self.max
        with other._lock:
            out.counts = a_counts + other.counts
            out.count = a_count + other.count
            out.sum = a_sum + other.sum
            out.max = max(a_max, other.max)
        return out

    # -- reading -------------------------------------------------------------

    def quantile(self, q: float) -> float:
        """Seconds at quantile ``q`` in [0, 1]: the geometric midpoint
        of the bucket holding the q-th observation (upper bound for the
        unbounded overflow bucket) — exact within one bucket's ±half
        resolution.  0.0 when empty."""
        with self._lock:
            counts, total = self.counts.copy(), self.count
        if total == 0:
            return 0.0
        rank = min(max(q, 0.0), 1.0) * total
        cum = np.cumsum(counts)
        ix = int(np.searchsorted(cum, max(rank, 1), side="left"))
        if ix >= len(self.uppers):          # overflow bucket
            return float(self.uppers[-1])
        # geometric midpoint of (upper/g, upper]; bucket 0's lower edge
        # is 0, so its midpoint uses the same formula against lo.
        return float(self.uppers[ix] * 2 ** (-0.5 / self.per_octave))

    def cumulative(self) -> list:
        """Prometheus-shaped cumulative buckets:
        [(upper_bound_seconds, cumulative_count), ...], truncated after
        the first bucket that already holds every observation (the
        all-equal tail carries no information; ``+Inf`` is the
        exposition layer's job)."""
        with self._lock:
            counts, total = self.counts.copy(), self.count
        cum = np.cumsum(counts[:len(self.uppers)])
        out = []
        for upper, c in zip(self.uppers, cum):
            out.append((float(upper), int(c)))
            if c == total:
                break
        return out

    def snapshot_ms(self) -> dict:
        """JSON-ready summary in milliseconds (p50/p90/p99 at bucket
        resolution, exact count/mean/max)."""
        with self._lock:
            total, ssum, smax = self.count, self.sum, self.max
        if total == 0:
            return {"count": 0, "p50": None, "p90": None, "p99": None,
                    "mean": None, "max": None}
        return {"count": int(total),
                "p50": self.quantile(0.50) * 1e3,
                "p90": self.quantile(0.90) * 1e3,
                "p99": self.quantile(0.99) * 1e3,
                "mean": ssum / total * 1e3,
                "max": smax * 1e3}
