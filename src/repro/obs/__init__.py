"""Observability subsystem: request tracing, per-stage latency
histograms, and profiler hooks for the geo serving stack
(DESIGN.md §15).

Public surface:

    from repro.obs import Tracer                  # per-request spans
    from repro.obs import LatencyHistogram        # mergeable log buckets
    from repro.obs import device_annotation       # jax.profiler range
    from repro.obs import start_profile, stop_profile

The tracer attaches to a server (``GeoServer(..., tracer=Tracer())``)
and exports both a raw span dump and a Chrome-trace file; the
histograms back ``ServerMetrics``' per-stage breakdown and its
Prometheus-style ``expose_text()``.
"""
from repro.obs.hist import LatencyHistogram
from repro.obs.profile import (device_annotation, profiler_available,
                               start_profile, stop_profile)
from repro.obs.trace import RequestTrace, Span, SpanBuffer, Tracer

__all__ = [
    "LatencyHistogram", "RequestTrace", "Span", "SpanBuffer", "Tracer",
    "device_annotation", "profiler_available", "start_profile",
    "stop_profile",
]
