"""AdamW with global-norm clipping (hand-rolled; optax unavailable offline).

Optimizer state is a pytree congruent with params (fp32 m/v), so it inherits
the params' (FSDP + TP) shardings — ZeRO-style optimizer sharding for free.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state: OptState, params, run: RunConfig, lr):
    """One AdamW step; returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if run.grad_clip > 0 else 1.0
    step = state.step + 1
    b1, b2 = run.beta1, run.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        newp = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + 1e-8) + run.weight_decay * p.astype(
                jnp.float32))
        return newp.astype(p.dtype), m, v

    # Params/opt trees are nested dicts of arrays, so tuple leaves are
    # unambiguous here.
    out = jax.tree.map(upd, params, grads, state.m, state.v)
    is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_tup)
    return new_p, OptState(step=step, m=new_m, v=new_v), gnorm


def schedule(run: RunConfig, step):
    """Learning-rate schedules: cosine, WSD (MiniCPM), const."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(run.warmup_steps, 1), 1.0)
    if run.schedule == "const":
        return run.learning_rate * warm
    total = float(max(run.total_steps, 1))
    if run.schedule == "wsd":
        # Warmup -> Stable (80%) -> exponential Decay (last 20 %).
        decay_start = 0.8 * total
        in_decay = jnp.maximum(step - decay_start, 0.0) / (total * 0.2)
        decay = jnp.exp(-5.0 * in_decay)        # ~exp decay to ~0.7% of peak
        return run.learning_rate * warm * jnp.where(step < decay_start, 1.0,
                                                    decay)
    # cosine
    frac = jnp.clip(step / total, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return run.learning_rate * warm * (0.1 + 0.9 * cos)
