"""GeoAnalytics: per-block aggregation + windowed streaming analytics
(DESIGN.md §16).

Three layers: segment-reduce kernels (``repro.kernels.segment`` /
``ops.segment_reduce``), batch aggregation (``BlockAggregator``),
windowed streaming state (``WindowedAggregator``).  The serving layer
mounts the windowed layer behind ``ServeConfig(analytics=...)``.
"""
from repro.analytics.aggregate import BlockAggregator
from repro.analytics.sketch import DEF_BITS, DistinctSketch, splitmix64
from repro.analytics.window import (AnalyticsConfig, WindowedAggregator,
                                    WindowSnapshot, WindowState)

__all__ = [
    "AnalyticsConfig",
    "BlockAggregator",
    "DEF_BITS",
    "DistinctSketch",
    "WindowSnapshot",
    "WindowState",
    "WindowedAggregator",
    "splitmix64",
]
