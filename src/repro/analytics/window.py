"""Windowed streaming aggregation over timestamped point streams
(DESIGN.md §16).

``WindowedAggregator`` turns a stream of ``(timestamp, assigned block
ids, source ids)`` observations into per-window per-block statistics —
the mContain-style encounter/crowding workload the paper motivates:

  * **occupancy counts** per block per window (and crowding density,
    counts / block area, when the aggregator knows the geometry);
  * **distinct sources** per block per window via a linear-counting
    ``DistinctSketch`` (sketch.py) — mergeable, hash-only state;
  * **co-location / encounter counts**: distinct-pair counts
    ``C(d, 2)`` per block per window, d = the block's distinct-source
    estimate (two sources in the same block in the same window = one
    potential encounter pair);
  * **k-anonymity suppression**: blocks with fewer than ``k_anon``
    distinct sources in a window are suppressed from every published
    snapshot (the raw state keeps them — suppression is a publication
    rule, not a data loss).

**Window state machine.**  Internally everything is *tumbling panes* of
``slide_s`` seconds keyed by integer pane index ``floor(ts /
slide_s)``.  A window starting at pane ``w`` is the merge of panes
``[w, w + n_panes)`` where ``n_panes = window_s / slide_s`` (tumbling
windows are the ``n_panes == 1`` special case).  Pane state is
**mergeable** — counter sums and sketch ORs, the ``GeoStats.merge``
discipline: associative, commutative, non-mutating — which is what
makes sliding windows exact compositions of panes and lets concurrent
replica threads fold into one aggregator in any arrival order.

Event time, not arrival time, decides window membership, so the
pipeline tolerates out-of-order feeds: the watermark trails the max
observed timestamp by ``allowed_lateness_s``; an event whose *last*
covering window has already closed is dropped (counted in
``late_dropped``).  A window finalizes — its merged snapshot appended
to a bounded history — when the watermark passes its end; panes are
evicted once every window covering them has closed, so open state is
bounded by ``n_panes + lateness/slide`` panes regardless of stream
length.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.analytics.sketch import DEF_BITS, DistinctSketch


@dataclasses.dataclass(frozen=True)
class AnalyticsConfig:
    """Static windowed-analytics knobs (serving opts in via
    ``ServeConfig(analytics=AnalyticsConfig(...))``)."""

    window_s: float = 60.0             # window length (event time)
    slide_s: Optional[float] = None    # pane/slide; None = tumbling
    k_anon: int = 0                    # suppress blocks with fewer
    #                                    distinct sources (0 = off)
    sketch_bits: int = DEF_BITS        # distinct-sketch bitmap width
    allowed_lateness_s: Optional[float] = None  # None = window_s
    top_k: int = 10                    # rows in published snapshots
    max_finalized: int = 64            # finalized-window history bound
    # Serving timestamp source: the host stage stamps each batch once
    # with this clock (arrival order — see server._prepare_batch);
    # injectable for deterministic tests/replays.  Event time is
    # *wall* time on purpose: pane boundaries must line up across
    # processes and survive restarts, which monotonic clocks (origin
    # = process start) cannot do.
    clock: Callable[[], float] = time.time  # wallclock-ok: event time

    def resolve(self) -> tuple[float, int, float]:
        """(slide_s, n_panes, lateness_s) with validation."""
        slide = self.slide_s if self.slide_s is not None else self.window_s
        if slide <= 0 or self.window_s <= 0:
            raise ValueError(f"window_s/slide_s must be > 0, got "
                             f"{self.window_s}/{slide}")
        n_panes = round(self.window_s / slide)
        if n_panes < 1 or abs(n_panes * slide - self.window_s) > 1e-9:
            raise ValueError(f"window_s must be an integer multiple of "
                             f"slide_s, got {self.window_s}/{slide}")
        lateness = self.allowed_lateness_s \
            if self.allowed_lateness_s is not None else self.window_s
        if lateness < 0:
            raise ValueError(f"allowed_lateness_s must be >= 0, "
                             f"got {lateness}")
        return float(slide), int(n_panes), float(lateness)


class WindowState:
    """One pane's (or merged window's) mergeable state: per-block int64
    occupancy counts + the distinct-source sketch.  ``observe`` expects
    pre-validated ids in [0, n_blocks); ``merge`` returns a NEW state
    (sums and bitmap ORs — exactly associative and commutative, the
    GeoStats.merge discipline)."""

    __slots__ = ("counts", "sketch", "n_events")

    def __init__(self, n_blocks: int, sketch_bits: int = DEF_BITS, *,
                 counts: Optional[np.ndarray] = None,
                 sketch: Optional[DistinctSketch] = None,
                 n_events: int = 0):
        self.counts = counts if counts is not None \
            else np.zeros(n_blocks, np.int64)
        self.sketch = sketch if sketch is not None \
            else DistinctSketch(n_blocks, sketch_bits)
        self.n_events = n_events

    def observe(self, bids: np.ndarray,
                sources: Optional[np.ndarray] = None) -> None:
        np.add.at(self.counts, bids, 1)
        self.n_events += int(bids.size)
        if sources is not None:
            self.sketch.observe(bids, sources)

    def merge(self, other: "WindowState") -> "WindowState":
        return WindowState(len(self.counts),
                           counts=self.counts + other.counts,
                           sketch=self.sketch.merge(other.sketch),
                           n_events=self.n_events + other.n_events)


@dataclasses.dataclass
class WindowSnapshot:
    """One window's published view.  Arrays are [n_blocks]-shaped;
    ``suppressed`` marks active blocks below the k-anonymity threshold
    — ``top_k``/``as_dict`` (the serving surfaces) exclude them, the
    arrays keep them so tests and merges stay exact."""

    start: float
    end: float
    n_events: int
    counts: np.ndarray                  # [S] int64 occupancy
    distinct: np.ndarray                # [S] int64 distinct-source est.
    pairs: np.ndarray                   # [S] int64 encounter pairs
    suppressed: np.ndarray              # [S] bool
    density: Optional[np.ndarray]       # [S] f64, None without geometry
    k_anon: int

    def top_k(self, k: int = 10) -> list:
        """Top-k crowded publishable blocks (suppression applied),
        densest-by-count first."""
        ok = (self.counts > 0) & ~self.suppressed
        order = np.argsort(-self.counts[ok], kind="stable")
        rows = np.nonzero(ok)[0][order][:k]
        return [{"block": int(b), "count": int(self.counts[b]),
                 "distinct": int(self.distinct[b]),
                 "pairs": int(self.pairs[b]),
                 "density": (float(self.density[b])
                             if self.density is not None else None)}
                for b in rows]

    def as_dict(self, top_k: int = 10) -> dict:
        active = int((self.counts > 0).sum())
        return {"start": self.start, "end": self.end,
                "n_events": int(self.n_events),
                "active_blocks": active,
                "suppressed_blocks": int(self.suppressed.sum()),
                "k_anon": self.k_anon,
                "top": self.top_k(top_k)}


class WindowedAggregator:
    """The streaming per-block aggregator (see module docstring).

    Thread-safe: ``observe``/``snapshot``/``current`` run under one
    lock, and because pane folds are commutative sums, concurrent
    replica threads feeding batches out of completion order produce
    exactly the state an in-order feed would — window membership is
    decided by each batch's host-stage timestamp, not by who gets the
    lock first (DESIGN.md §16).
    """

    def __init__(self, n_blocks: int, cfg: Optional[AnalyticsConfig]
                 = None, areas: Optional[np.ndarray] = None):
        self.cfg = cfg or AnalyticsConfig()
        self.slide, self.n_panes, self.lateness = self.cfg.resolve()
        self.n_blocks = int(n_blocks)
        self.areas = None if areas is None \
            else np.asarray(areas, np.float64)
        if self.areas is not None:
            assert self.areas.shape == (self.n_blocks,), self.areas.shape
        self.panes: dict[int, WindowState] = {}     # guarded-by: _lock
        self.finalized: list[WindowSnapshot] = []   # guarded-by: _lock
        self.finalized_total = 0                    # guarded-by: _lock
        self.observed = 0                           # guarded-by: _lock
        self.off_map = 0                            # guarded-by: _lock
        self.late_dropped = 0                       # guarded-by: _lock
        self._max_ts = -math.inf                    # guarded-by: _lock
        self._last_emitted: Optional[int] = None    # guarded-by: _lock
        self._lock = threading.Lock()

    # -- feed --------------------------------------------------------------

    def observe(self, ts: float, bids, sources=None) -> int:
        """Fold one observation batch: ``bids`` [n] assigned block ids
        (< 0 / >= n_blocks counted as ``off_map`` and skipped),
        ``sources`` [n] optional source identities for the distinct
        sketch.  Returns rows absorbed (0 = the batch was beyond the
        lateness horizon and dropped)."""
        bids = np.asarray(bids).astype(np.int64).ravel()
        if sources is not None:
            sources = np.asarray(sources).ravel()
            assert sources.shape == bids.shape, (sources.shape,
                                                 bids.shape)
        with self._lock:
            self.observed += int(bids.size)
            self._max_ts = max(self._max_ts, float(ts))
            pane = math.floor(float(ts) / self.slide)
            if (pane + self.n_panes) * self.slide <= self._watermark():
                self.late_dropped += int(bids.size)
                self._advance()
                return 0
            valid = (bids >= 0) & (bids < self.n_blocks)
            self.off_map += int((~valid).sum())
            state = self.panes.get(pane)
            if state is None:
                state = self.panes[pane] = WindowState(
                    self.n_blocks, self.cfg.sketch_bits)
            state.observe(bids[valid],
                          None if sources is None else sources[valid])
            self._advance()
            return int(valid.sum())

    def advance(self, ts: float) -> int:
        """Push the watermark to ``ts - allowed_lateness`` without
        observing events (e.g. a quiet stream's periodic tick); returns
        windows finalized by the push."""
        with self._lock:
            before = self.finalized_total
            self._max_ts = max(self._max_ts, float(ts))
            self._advance()
            return self.finalized_total - before

    # -- state machine (lock held) ----------------------------------------

    def _watermark(self) -> float:
        return self._max_ts - self.lateness

    def _window_state(self, w: int) -> Optional[WindowState]:
        state = None
        for p in range(w, w + self.n_panes):
            pane = self.panes.get(p)
            if pane is not None:
                state = pane if state is None else state.merge(pane)
        return state

    def _advance(self) -> None:  # requires-lock: _lock
        wm = self._watermark()
        windows = sorted({w for p in self.panes
                          for w in range(p - self.n_panes + 1, p + 1)})
        for w in windows:
            if (w + self.n_panes) * self.slide > wm:
                break
            if self._last_emitted is not None and w <= self._last_emitted:
                continue
            state = self._window_state(w)
            if state is not None and state.n_events:
                self.finalized.append(self._snap(w, state))
                del self.finalized[:-self.cfg.max_finalized]
                self.finalized_total += 1
            self._last_emitted = w
        for p in [p for p in self.panes
                  if (p + self.n_panes) * self.slide <= wm]:
            del self.panes[p]

    def _snap(self, w: int, state: WindowState) -> WindowSnapshot:
        distinct = state.sketch.estimate_round()
        pairs = distinct * (distinct - 1) // 2
        if self.cfg.k_anon > 0:
            suppressed = (state.counts > 0) & (distinct < self.cfg.k_anon)
        else:
            suppressed = np.zeros(self.n_blocks, bool)
        density = None
        if self.areas is not None:
            with np.errstate(divide="ignore", invalid="ignore"):
                density = np.where(self.areas > 0,
                                   state.counts / self.areas, 0.0)
        return WindowSnapshot(start=w * self.slide,
                              end=w * self.slide + self.cfg.window_s,
                              n_events=state.n_events,
                              counts=state.counts, distinct=distinct,
                              pairs=pairs, suppressed=suppressed,
                              density=density, k_anon=self.cfg.k_anon)

    # -- read --------------------------------------------------------------

    def current(self) -> Optional[WindowSnapshot]:
        """The open window's live snapshot: the most-complete window
        containing the newest observed pane (None = no open state)."""
        with self._lock:
            if not self.panes or not math.isfinite(self._max_ts):
                return None
            w = math.floor(self._max_ts / self.slide) - self.n_panes + 1
            state = self._window_state(w)
            if state is None or not state.n_events:
                return None
            return self._snap(w, state)

    def snapshot(self) -> dict:
        """JSON-ready view: config echo, feed counters, the finalized
        window history (suppression applied to every published row) and
        the open window (DESIGN.md §16 schema; scripts/analytics_smoke.py
        checks it)."""
        with self._lock:
            fin = [s.as_dict(self.cfg.top_k) for s in self.finalized]
            if self.panes and math.isfinite(self._max_ts):
                w = math.floor(self._max_ts / self.slide) \
                    - self.n_panes + 1
                state = self._window_state(w)
                open_d = (self._snap(w, state).as_dict(self.cfg.top_k)
                          if state is not None and state.n_events
                          else None)
            else:
                open_d = None
            return {"config": {"window_s": self.cfg.window_s,
                               "slide_s": self.slide,
                               "k_anon": self.cfg.k_anon,
                               "sketch_bits": self.cfg.sketch_bits,
                               "lateness_s": self.lateness},
                    "observed": self.observed,
                    "off_map": self.off_map,
                    "late_dropped": self.late_dropped,
                    "open_panes": len(self.panes),
                    "finalized_total": self.finalized_total,
                    "finalized": fin,
                    "open": open_d}
