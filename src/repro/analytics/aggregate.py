"""Per-block batch aggregation on top of the segment-reduce kernels
(DESIGN.md §16).

``BlockAggregator`` is the stateless batch layer of the analytics
subsystem: it turns assigned block ids (from any ``GeoEngine``
strategy) into per-block statistics —

  * **occupancy counts** (host ``np.bincount`` or device
    ``ops.segment_reduce``, bit-identical);
  * **crowding density** = counts / block shoelace area
    (``geometry.polygon_areas``);
  * **weighted composite indices** (HVI-style): z-score per-block
    attribute columns across blocks, then blend with caller weights —
    the heat-vulnerability-index pattern of the census-block mapping
    literature;
  * a **fused assign→aggregate** path: the aggregation prologue is
    traced into the engine's assign program (invalid ids parked at
    ``n_blocks`` in the jit epilogue — XLA fuses the ``where`` into the
    existing kernels for free), and the reduction consumes the
    resulting id buffer without a host round trip: on TPU via the
    segment kernels (``ops.segment_counts``), on the CPU backend via a
    zero-copy dlpack view of the XLA buffer — no ``np.asarray`` copy,
    no validity mask, no fancy-index compaction, just one ``bincount``
    over pre-parked ids.  Counts are integer accumulations, so the
    fused path is bit-identical to the unfused
    assign → host-materialize → filter → bincount path by construction;
    what fusion removes is the per-batch materialization work (and, on
    accelerators, the [N] device→host transfer).

Streaming/windowed state lives in window.py; this module never holds
state between calls.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import polygon_areas
from repro.kernels import ops


class BlockAggregator:
    """Batch per-block reductions for a fixed map of ``n_blocks`` blocks.

    Construct directly from ``n_blocks`` (+ optional [n_blocks] areas),
    or via ``from_engine`` to pick up the engine's block count, census
    geometry, and a fused assign→aggregate path.
    """

    def __init__(self, n_blocks: int, areas: Optional[np.ndarray] = None,
                 *, backend: Optional[str] = None, engine=None):
        self.n_blocks = int(n_blocks)
        self.areas = None if areas is None \
            else np.asarray(areas, np.float64)
        if self.areas is not None:
            assert self.areas.shape == (self.n_blocks,), self.areas.shape
        self.backend = backend
        self.engine = engine
        self._fused_ids_jit = None

    @classmethod
    def from_engine(cls, engine, *, backend: Optional[str] = None
                    ) -> "BlockAggregator":
        block_parent, _ = engine.host_parents()
        areas = polygon_areas(engine.census.blocks) \
            if engine.census is not None else None
        return cls(len(block_parent), areas, backend=backend,
                   engine=engine)

    # -- batch reductions --------------------------------------------------

    def counts(self, bids) -> np.ndarray:
        """[n_blocks] int64 occupancy from host block ids (the unfused
        path: ids already on host).  Ids outside [0, n_blocks) — e.g.
        the engine's -1 "not on the map" — are skipped."""
        bids = np.asarray(bids).astype(np.int64).ravel()
        bids = bids[(bids >= 0) & (bids < self.n_blocks)]
        return np.bincount(bids, minlength=self.n_blocks)

    def reduce(self, ids, values=None) -> ops.SegmentReduce:
        """Device segment reduction (count/sum/min/max) over assigned
        ids — see ``ops.segment_reduce`` for the backend and
        bit-identity contract."""
        return ops.segment_reduce(ids, values, n_segments=self.n_blocks,
                                  backend=self.backend)

    def fused_ids(self, points) -> jnp.ndarray:
        """The fused program's first stage: one jitted computation of
        engine assign + the aggregation prologue (invalid block ids
        parked at ``n_blocks``), so the output buffer feeds
        ``reduce_counts`` with no host-side filtering.  Requires an
        engine (``from_engine``)."""
        if self.engine is None:
            raise ValueError("fused_ids needs an engine "
                             "(BlockAggregator.from_engine)")
        if self._fused_ids_jit is None:
            engine, n = self.engine, self.n_blocks

            @jax.jit
            def _fused(pts):
                bid = engine.assign(pts).block.astype(jnp.int32)
                return jnp.where((bid < 0) | (bid >= n), n, bid)

            self._fused_ids_jit = _fused
        return self._fused_ids_jit(points)

    def reduce_counts(self, parked_ids) -> np.ndarray:
        """[n_blocks] counts from a *parked* device id buffer
        (``fused_ids`` output: every id in [0, n_blocks], n_blocks =
        parked/invalid).  With an explicit kernel backend the segment
        kernels reduce on device; on the default CPU path the buffer is
        consumed through a zero-copy dlpack view — the id vector is
        never re-materialized, masked, or compacted on host."""
        if self.backend is not None:
            out = ops.segment_counts(parked_ids,
                                     n_segments=self.n_blocks,
                                     backend=self.backend)
            return np.asarray(out).astype(np.int64)
        if isinstance(parked_ids, jax.Array):
            ids = np.from_dlpack(parked_ids)    # zero-copy on CPU
        else:
            ids = np.asarray(parked_ids)
        return np.bincount(ids, minlength=self.n_blocks + 1)[
            :self.n_blocks]

    def fused_counts(self, points) -> np.ndarray:
        """assign→count without materializing the id vector on host:
        [N, 2] points -> [n_blocks] counts.  Bit-identical to
        ``counts(np.asarray(engine.assign(points).block))`` — integer
        accumulation is order-free — while skipping that path's
        per-batch host copy + filter (the ``analytics_perf``
        fused-vs-unfused row measures exactly this delta)."""
        return self.reduce_counts(self.fused_ids(points))

    # -- derived statistics ------------------------------------------------

    def density(self, counts) -> np.ndarray:
        """[n_blocks] float64 crowding density = counts / block area
        (zero-area blocks report 0).  Requires areas (``from_engine``
        with a census, or explicit ``areas=``)."""
        if self.areas is None:
            raise ValueError("density needs block areas")
        counts = np.asarray(counts, np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(self.areas > 0, counts / self.areas, 0.0)

    def weighted_index(self, columns, weights) -> np.ndarray:
        """HVI-style composite: z-score each [n_blocks] column across
        blocks (constant columns z-score to 0), blend with ``weights``
        [n_cols].  float64 throughout; returns [n_blocks]."""
        cols = np.asarray(columns, np.float64)
        if cols.ndim == 1:
            cols = cols[:, None]
        assert cols.shape[0] == self.n_blocks, cols.shape
        w = np.asarray(weights, np.float64).ravel()
        assert w.shape == (cols.shape[1],), (w.shape, cols.shape)
        mean = cols.mean(axis=0)
        std = cols.std(axis=0)
        std = np.where(std > 0, std, 1.0)
        return ((cols - mean) / std) @ w
