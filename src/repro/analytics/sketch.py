"""Distinct-count sketches for the analytics layer (DESIGN.md §16).

``DistinctSketch`` is a vectorized per-segment **linear counting**
sketch (Whang et al. '90): one ``bits``-wide bitmap per block, a
64-bit avalanche hash of the source id picks the bit, and the distinct
estimate is ``-m * ln(z / m)`` from the count of still-zero bits
``z``.  Linear counting beats HyperLogLog at the small cardinalities
per block a k-anonymity threshold cares about (it is near-exact until
the bitmap loads up — relative error ~ sqrt(m)*(e^t - t - 1)^0.5 / n
at load t = n/m), and its state is a plain bitmap: **mergeable by
bitwise OR**, exactly associative/commutative, which is what lets
sliding windows compose from tumbling panes (window.py) and replicas
feed one aggregator in any order.

Privacy angle (the mContain-style workload): per-block *source*
cardinality gates publication — blocks with fewer than k distinct
sources in a window are suppressed (window.py applies the threshold).
The sketch only ever holds hashed presence bits, never source ids.
Collisions can only under-estimate, so an estimate-based threshold is
conservative: it never publishes a block the exact count would have
suppressed.
"""
from __future__ import annotations

import numpy as np

DEF_BITS = 2048


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized SplitMix64 finalizer: uint64 -> well-mixed uint64.
    Deterministic across runs/platforms (pure integer arithmetic), which
    keeps sketch-based tests and snapshots reproducible."""
    x = np.asarray(x).astype(np.uint64)
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15))
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
    return x


class DistinctSketch:
    """Per-segment linear-counting bitmaps: [n_segments, bits/8] uint8.

    ``observe`` is vectorized over (segment id, source id) pairs;
    invalid segment ids (< 0 or >= n_segments) are ignored.  ``merge``
    returns a NEW sketch (bitwise OR — the GeoStats.merge discipline:
    non-mutating, associative, commutative)."""

    __slots__ = ("n_segments", "bits", "bitmap")

    def __init__(self, n_segments: int, bits: int = DEF_BITS,
                 bitmap: np.ndarray | None = None):
        if bits % 8 != 0 or bits <= 0:
            raise ValueError(f"bits must be a positive multiple of 8, "
                             f"got {bits}")
        self.n_segments = int(n_segments)
        self.bits = int(bits)
        if bitmap is None:
            bitmap = np.zeros((self.n_segments, self.bits // 8), np.uint8)
        assert bitmap.shape == (self.n_segments, self.bits // 8)
        self.bitmap = bitmap

    def observe(self, seg_ids, source_ids) -> None:
        seg = np.asarray(seg_ids).astype(np.int64).ravel()
        src = np.asarray(source_ids).astype(np.uint64).ravel()
        assert seg.shape == src.shape, (seg.shape, src.shape)
        ok = (seg >= 0) & (seg < self.n_segments)
        seg, src = seg[ok], src[ok]
        if not seg.size:
            return
        pos = (splitmix64(src) % np.uint64(self.bits)).astype(np.int64)
        np.bitwise_or.at(self.bitmap, (seg, pos >> 3),
                         (np.uint8(1) << (pos & 7).astype(np.uint8)))

    def merge(self, other: "DistinctSketch") -> "DistinctSketch":
        assert (self.n_segments, self.bits) == (other.n_segments,
                                                other.bits)
        return DistinctSketch(self.n_segments, self.bits,
                              np.bitwise_or(self.bitmap, other.bitmap))

    def estimate(self) -> np.ndarray:
        """[n_segments] float64 distinct-count estimates.  A saturated
        bitmap (zero empty bits) clamps at the sketch's resolution limit
        ``m * ln(m)`` — size ``bits`` ~10x the expected per-block
        cardinality to stay out of that regime."""
        set_bits = np.unpackbits(self.bitmap, axis=1).sum(axis=1)
        m = float(self.bits)
        z = (m - set_bits).astype(np.float64)
        with np.errstate(divide="ignore"):
            est = -m * np.log(np.maximum(z, 1.0) / m)
        return np.where(z > 0, est, m * np.log(m))

    def estimate_round(self) -> np.ndarray:
        """[n_segments] int64 rounded estimates (what thresholds use)."""
        return np.rint(self.estimate()).astype(np.int64)
