"""Pure-jnp oracles for the Pallas kernels.

These are the semantic references: every Pallas kernel in this package must
be allclose-equal to the corresponding function here (tests sweep shapes and
dtypes).  They are also the implementations used on non-TPU backends when
``REPRO_KERNELS=ref``.

Crossing-number test (paper §III-A, Shimrat '62): a point is inside a polygon
iff a ray extending in +x crosses the boundary an odd number of times.  Edge
(x1,y1)-(x2,y2) is crossed iff the edge straddles the point's y (half-open
rule: ``(y1 > py) != (y2 > py)``) and the intersection lies right of the
point.  The right-of test is done in the multiplication-only form

    (px - x1) * (y2 - y1)  <  (py - y1) * (x2 - x1)      [sign-adjusted]

which avoids the division of the textbook form — important both for TPU VPU
throughput and to keep degenerate (padding) edges well-defined.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cascade import OUTSIDE, morton


def crossings_one(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Crossing counts of N points against one shared edge table.

    Args:
      points: [N, 2] float.
      edges:  [E, 4] float (x1, y1, x2, y2); zero-length edges are ignored.
    Returns:
      [N] int32 crossing counts.
    """
    px = points[:, 0:1]
    py = points[:, 1:2]
    x1, y1, x2, y2 = (edges[None, :, 0], edges[None, :, 1],
                      edges[None, :, 2], edges[None, :, 3])
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1))
    return jnp.sum(cross, axis=1).astype(jnp.int32)


def pip_one(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Inside mask of N points against one polygon edge table."""
    return (crossings_one(points, edges) & 1).astype(jnp.bool_)


def crossings_gathered(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Crossing counts where each point has its own edge table.

    Args:
      points: [N, 2] float.
      edges:  [N, E, 4] float.
    Returns:
      [N] int32.
    """
    px = points[:, 0:1]
    py = points[:, 1:2]
    x1, y1, x2, y2 = (edges[..., 0], edges[..., 1],
                      edges[..., 2], edges[..., 3])
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1))
    return jnp.sum(cross, axis=1).astype(jnp.int32)


def pip_gathered(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    return (crossings_gathered(points, edges) & 1).astype(jnp.bool_)


def crossings_candidates(points: jnp.ndarray, first: jnp.ndarray,
                         count: jnp.ndarray, blocks: jnp.ndarray,
                         max_blocks: int) -> jnp.ndarray:
    """Oracle for the fused gather-PIP kernel (kernels/gather_pip.py).

    Args:
      points: [N, 2] float.
      first:  [N] i32 — first pool block of each point's candidate.
      count:  [N] i32 — blocks owned by the candidate (0 = no candidate).
      blocks: [NB, 4, BE] float blocked-CSR edge pool; block 0 MUST be
        all-zero (degenerate edges — the masked-gather target).
      max_blocks: static max of ``count`` over the pool.
    Returns:
      [N] int32 crossing counts.
    """
    b = jnp.arange(max_blocks, dtype=jnp.int32)[None, :]
    ix = jnp.where(b < count[:, None], first[:, None] + b, 0)
    g = blocks[jnp.clip(ix, 0, blocks.shape[0] - 1)]     # [N, MAXB, 4, BE]
    px = points[:, 0][:, None, None]
    py = points[:, 1][:, None, None]
    x1, y1, x2, y2 = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1))
    return jnp.sum(cross, axis=(1, 2)).astype(jnp.int32)


def pip_candidates(points: jnp.ndarray, first: jnp.ndarray,
                   count: jnp.ndarray, blocks: jnp.ndarray,
                   max_blocks: int) -> jnp.ndarray:
    return (crossings_candidates(points, first, count, blocks, max_blocks)
            & 1).astype(jnp.bool_)


def assign_cascade(points: jnp.ndarray, quant: jnp.ndarray,
                   cell_lo: jnp.ndarray, cell_hi: jnp.ndarray,
                   cell_val: jnp.ndarray, top_start: jnp.ndarray,
                   cand: jnp.ndarray, bbox: jnp.ndarray,
                   first: jnp.ndarray, count: jnp.ndarray,
                   blocks: jnp.ndarray, *, max_level: int, gbits: int,
                   search_iters: int, max_blocks: int):
    """Oracle for the one-pass fused cascade (kernels/cascade.py):
    vectorized jnp, op-for-op the kernel's per-point schedule — same
    quantize arithmetic, same fixed-iteration cell search, same
    slot-ordered bbox-gated candidate walk — so the two are bit-exact.

    Inputs must be pre-normalized like the kernel's (``ops.assign_cascade``
    does this): ``cand`` [B>=1, K>=1], ``search_iters`` already
    ``effective_iters``-adjusted.  Returns (bid, flags, nrest, nskip),
    each [N] i32 (see the kernel module docstring for the encoding).
    """
    n_cells = cell_lo.shape[0]
    span = jnp.float32(1 << max_level)
    fx = (points[:, 0].astype(jnp.float32) - quant[0]) * quant[2]
    fy = (points[:, 1].astype(jnp.float32) - quant[1]) * quant[3]
    in_ext = (fx >= 0.0) & (fx < span) & (fy >= 0.0) & (fy < span)
    nmax = (1 << max_level) - 1
    ix = jnp.clip(fx.astype(jnp.int32), 0, nmax)
    iy = jnp.clip(fy.astype(jnp.int32), 0, nmax)
    code = morton(ix, iy)

    if gbits > 0:
        shift = 2 * (max_level - gbits)
        bucket = (code >> shift).astype(jnp.int32)
        l = jnp.maximum(top_start[bucket] - 1, 0)
        h = top_start[bucket + 1]
    else:
        l = jnp.zeros_like(code)
        h = jnp.full_like(code, n_cells)
    for _ in range(search_iters):
        active = l < h
        mid = (l + h) // 2
        go_right = cell_lo[jnp.clip(mid, 0, n_cells - 1)] <= code
        nl = jnp.where(active & go_right, mid + 1, l)
        nh = jnp.where(active & ~go_right, mid, h)
        l, h = nl, nh
    cidx = jnp.clip(l - 1, 0, n_cells - 1)
    in_cell = (cell_lo[cidx] <= code) & (code <= cell_hi[cidx]) & in_ext
    v = jnp.where(in_cell, cell_val[cidx], jnp.int32(OUTSIDE))

    boundary = (v < 0) & (v > jnp.int32(OUTSIDE))
    brow = jnp.clip(-(v + 1), 0, cand.shape[0] - 1)
    n_poly = first.shape[0]
    px, py = points[:, 0].astype(jnp.float32), points[:, 1].astype(
        jnp.float32)
    best = jnp.full(points.shape[0], -1, jnp.int32)
    slot0_hit = jnp.zeros(points.shape[0], bool)
    nrest = jnp.zeros(points.shape[0], jnp.int32)
    nskip = jnp.zeros(points.shape[0], jnp.int32)
    for s in range(cand.shape[1]):
        pid = cand[brow, s]
        valid = boundary & (pid >= 0)
        if s > 0:
            nrest = nrest + valid.astype(jnp.int32)
        attempt = valid & (best < 0)
        safe = jnp.clip(pid, 0, n_poly - 1)
        bb = bbox[safe]
        inb = ((px > bb[:, 0]) & (px < bb[:, 1])
               & (py > bb[:, 2]) & (py < bb[:, 3]))
        do = attempt & inb
        nskip = nskip + (attempt & ~inb).astype(jnp.int32)
        nblk = jnp.where(do, count[safe], 0)
        cross = crossings_candidates(points.astype(jnp.float32),
                                     first[safe], nblk, blocks, max_blocks)
        inside = do & ((cross & 1) == 1)
        best = jnp.where(inside, pid, best)
        if s == 0:
            slot0_hit = inside

    fb0 = cand[brow, 0]
    fallback = jnp.where(fb0 >= 0, fb0, -1)
    resolved = jnp.where(best >= 0, best, fallback)
    bid = jnp.where(boundary, resolved, jnp.where(v >= 0, v, -1))
    flags = (boundary.astype(jnp.int32)
             | (slot0_hit.astype(jnp.int32) << 1))
    return (bid.astype(jnp.int32), flags, nrest, nskip)


def segment_reduce(ids: jnp.ndarray, values: jnp.ndarray,
                   n_segments: int):
    """Oracle for the segment-reduce kernel (kernels/segment.py).

    ``ids`` must be pre-masked by ``ops.segment_reduce``: invalid rows
    parked at segment ``n_segments`` (the extra scratch segment sliced
    off here).  Returns (count [S] i32, sum [S] f32, min [S] f32,
    max [S] f32); empty segments are (0, 0.0, +inf, -inf) — the same
    identities the kernel initializes its accumulators with.
    """
    ids = ids.astype(jnp.int32)
    values = values.astype(jnp.float32)
    num = n_segments + 1                  # + the park segment
    ones = jnp.ones(ids.shape, jnp.int32)
    count = jax.ops.segment_sum(ones, ids, num_segments=num)
    total = jax.ops.segment_sum(values, ids, num_segments=num)
    vmin = jax.ops.segment_min(values, ids, num_segments=num)
    vmax = jax.ops.segment_max(values, ids, num_segments=num)
    return (count[:n_segments], total[:n_segments],
            vmin[:n_segments], vmax[:n_segments])


def np_segment_reduce(ids, values, n_segments: int):
    """Host numpy ``bincount`` ground truth for segment reduction — THE
    semantics every backend must reproduce (tests compare all backends
    against this).  Rows with ids outside [0, n_segments) are ignored;
    sums accumulate in float64 and round once to f32 at the end, so any
    f32 reduction order that is exact (integer-valued data, counts) is
    bit-identical to it.
    """
    ids = np.asarray(ids)
    if values is None:
        values = np.zeros(ids.shape, np.float32)
    values = np.asarray(values)
    valid = (ids >= 0) & (ids < n_segments)
    ids = ids[valid].astype(np.int64)
    vals = values[valid].astype(np.float64)
    count = np.bincount(ids, minlength=n_segments).astype(np.int32)
    total = np.bincount(ids, weights=vals,
                        minlength=n_segments).astype(np.float32)
    vmin = np.full(n_segments, np.inf, np.float64)
    np.minimum.at(vmin, ids, vals)
    vmax = np.full(n_segments, -np.inf, np.float64)
    np.maximum.at(vmax, ids, vals)
    return count, total, vmin.astype(np.float32), vmax.astype(np.float32)


def bbox_mask(points: jnp.ndarray, boxes: jnp.ndarray) -> jnp.ndarray:
    """[N, M] int8 membership of N points in M shared boxes (open intervals).

    boxes: [M, 4] = (xmin, xmax, ymin, ymax).  This is the paper's sparse
    outer-product expression ``A_in`` realized densely.
    """
    px, py = points[:, 0:1], points[:, 1:2]
    m = ((px > boxes[None, :, 0]) & (px < boxes[None, :, 1]) &
         (py > boxes[None, :, 2]) & (py < boxes[None, :, 3]))
    return m.astype(jnp.int8)


def bbox_mask_gathered(points: jnp.ndarray, boxes: jnp.ndarray) -> jnp.ndarray:
    """[N, C] int8 membership where each point has its own C boxes [N, C, 4]."""
    px, py = points[:, 0:1], points[:, 1:2]
    m = ((px > boxes[..., 0]) & (px < boxes[..., 1]) &
         (py > boxes[..., 2]) & (py < boxes[..., 3]))
    return m.astype(jnp.int8)


def bbox_count_select(points: jnp.ndarray, boxes: jnp.ndarray):
    """Fused membership count + single-candidate select over gathered boxes.

    Args:
      points: [N, 2]; boxes: [N, C, 4] (padded boxes must be empty, e.g.
        xmin > xmax, so they never match).
    Returns:
      count: [N] int32 — number of boxes containing the point.
      sel:   [N] int32 — largest box slot containing the point, -1 if none.
             (When count == 1 this is *the* containing slot.)
    """
    m = bbox_mask_gathered(points, boxes)
    count = jnp.sum(m.astype(jnp.int32), axis=1)
    c = boxes.shape[1]
    iota = jnp.arange(c, dtype=jnp.int32)[None, :]
    sel = jnp.max(jnp.where(m != 0, iota, -1), axis=1)
    return count, sel.astype(jnp.int32)
