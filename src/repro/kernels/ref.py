"""Pure-jnp oracles for the Pallas kernels.

These are the semantic references: every Pallas kernel in this package must
be allclose-equal to the corresponding function here (tests sweep shapes and
dtypes).  They are also the implementations used on non-TPU backends when
``REPRO_KERNELS=ref``.

Crossing-number test (paper §III-A, Shimrat '62): a point is inside a polygon
iff a ray extending in +x crosses the boundary an odd number of times.  Edge
(x1,y1)-(x2,y2) is crossed iff the edge straddles the point's y (half-open
rule: ``(y1 > py) != (y2 > py)``) and the intersection lies right of the
point.  The right-of test is done in the multiplication-only form

    (px - x1) * (y2 - y1)  <  (py - y1) * (x2 - x1)      [sign-adjusted]

which avoids the division of the textbook form — important both for TPU VPU
throughput and to keep degenerate (padding) edges well-defined.
"""
from __future__ import annotations

import jax.numpy as jnp


def crossings_one(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Crossing counts of N points against one shared edge table.

    Args:
      points: [N, 2] float.
      edges:  [E, 4] float (x1, y1, x2, y2); zero-length edges are ignored.
    Returns:
      [N] int32 crossing counts.
    """
    px = points[:, 0:1]
    py = points[:, 1:2]
    x1, y1, x2, y2 = (edges[None, :, 0], edges[None, :, 1],
                      edges[None, :, 2], edges[None, :, 3])
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1))
    return jnp.sum(cross, axis=1).astype(jnp.int32)


def pip_one(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Inside mask of N points against one polygon edge table."""
    return (crossings_one(points, edges) & 1).astype(jnp.bool_)


def crossings_gathered(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Crossing counts where each point has its own edge table.

    Args:
      points: [N, 2] float.
      edges:  [N, E, 4] float.
    Returns:
      [N] int32.
    """
    px = points[:, 0:1]
    py = points[:, 1:2]
    x1, y1, x2, y2 = (edges[..., 0], edges[..., 1],
                      edges[..., 2], edges[..., 3])
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1))
    return jnp.sum(cross, axis=1).astype(jnp.int32)


def pip_gathered(points: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    return (crossings_gathered(points, edges) & 1).astype(jnp.bool_)


def crossings_candidates(points: jnp.ndarray, first: jnp.ndarray,
                         count: jnp.ndarray, blocks: jnp.ndarray,
                         max_blocks: int) -> jnp.ndarray:
    """Oracle for the fused gather-PIP kernel (kernels/gather_pip.py).

    Args:
      points: [N, 2] float.
      first:  [N] i32 — first pool block of each point's candidate.
      count:  [N] i32 — blocks owned by the candidate (0 = no candidate).
      blocks: [NB, 4, BE] float blocked-CSR edge pool; block 0 MUST be
        all-zero (degenerate edges — the masked-gather target).
      max_blocks: static max of ``count`` over the pool.
    Returns:
      [N] int32 crossing counts.
    """
    b = jnp.arange(max_blocks, dtype=jnp.int32)[None, :]
    ix = jnp.where(b < count[:, None], first[:, None] + b, 0)
    g = blocks[jnp.clip(ix, 0, blocks.shape[0] - 1)]     # [N, MAXB, 4, BE]
    px = points[:, 0][:, None, None]
    py = points[:, 1][:, None, None]
    x1, y1, x2, y2 = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    cross = straddle & ((lhs < rhs) == (y2 > y1))
    return jnp.sum(cross, axis=(1, 2)).astype(jnp.int32)


def pip_candidates(points: jnp.ndarray, first: jnp.ndarray,
                   count: jnp.ndarray, blocks: jnp.ndarray,
                   max_blocks: int) -> jnp.ndarray:
    return (crossings_candidates(points, first, count, blocks, max_blocks)
            & 1).astype(jnp.bool_)


def bbox_mask(points: jnp.ndarray, boxes: jnp.ndarray) -> jnp.ndarray:
    """[N, M] int8 membership of N points in M shared boxes (open intervals).

    boxes: [M, 4] = (xmin, xmax, ymin, ymax).  This is the paper's sparse
    outer-product expression ``A_in`` realized densely.
    """
    px, py = points[:, 0:1], points[:, 1:2]
    m = ((px > boxes[None, :, 0]) & (px < boxes[None, :, 1]) &
         (py > boxes[None, :, 2]) & (py < boxes[None, :, 3]))
    return m.astype(jnp.int8)


def bbox_mask_gathered(points: jnp.ndarray, boxes: jnp.ndarray) -> jnp.ndarray:
    """[N, C] int8 membership where each point has its own C boxes [N, C, 4]."""
    px, py = points[:, 0:1], points[:, 1:2]
    m = ((px > boxes[..., 0]) & (px < boxes[..., 1]) &
         (py > boxes[..., 2]) & (py < boxes[..., 3]))
    return m.astype(jnp.int8)


def bbox_count_select(points: jnp.ndarray, boxes: jnp.ndarray):
    """Fused membership count + single-candidate select over gathered boxes.

    Args:
      points: [N, 2]; boxes: [N, C, 4] (padded boxes must be empty, e.g.
        xmin > xmax, so they never match).
    Returns:
      count: [N] int32 — number of boxes containing the point.
      sel:   [N] int32 — largest box slot containing the point, -1 if none.
             (When count == 1 this is *the* containing slot.)
    """
    m = bbox_mask_gathered(points, boxes)
    count = jnp.sum(m.astype(jnp.int32), axis=1)
    c = boxes.shape[1]
    iota = jnp.arange(c, dtype=jnp.int32)[None, :]
    sel = jnp.max(jnp.where(m != 0, iota, -1), axis=1)
    return count, sel.astype(jnp.int32)
