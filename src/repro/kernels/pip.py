"""Pallas TPU kernels for the crossing-number point-in-polygon test.

This is the paper's compute hot-spot (§III-A): every point that survives the
bbox filter cascade is tested against candidate polygon edge tables.  The
paper's optimized CPU variant (y-sort + binary search over edges) is branchy
and serial; the TPU-native formulation is a dense ``points x edges`` parity
reduction on the VPU:

  * points tile   [BP, 2]   -> VMEM (BP on sublanes)
  * edge tile     [4, BE]   -> VMEM, struct-of-arrays layout so the edge
                               axis lands on the 128-wide lane dimension
  * crossing tile [BP, BE]  -> compare/multiply only (no division), then
                               reduced into an int32 accumulator [BP, 1]
                               that stays VMEM-resident across edge tiles.

The grid is (point_tiles, edge_tiles); the edge axis is ``arbitrary``
(sequential) so the output tile accumulates, the point axis is ``parallel``.
Degenerate (zero-length) padding edges produce no crossings by construction,
so ops.py can pad freely to tile multiples.

``*_kernel`` bodies are layout-transposed; use ops.py for the public API
(natural layouts, padding, interpret-mode switch, parity -> bool).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import CompilerParams

# Default tile sizes: BP on sublanes (multiple of 8), BE on lanes (multiple
# of 128).  VMEM footprint ~ BP*BE*4B per f32 temp; (256, 512) keeps the
# working set ~2-3 MiB.
DEF_BP = 256
DEF_BE = 512


def _cross_tile(px, py, x1, y1, x2, y2):
    """Crossing mask for a [BP, BE] tile (see kernels/ref.py for semantics)."""
    straddle = (y1 > py) != (y2 > py)
    lhs = (px - x1) * (y2 - y1)
    rhs = (py - y1) * (x2 - x1)
    return straddle & ((lhs < rhs) == (y2 > y1))


def _pip_one_kernel(pts_ref, edg_ref, out_ref):
    """One shared polygon: pts [BP, 2], edges [4, BE], out [BP, 1] i32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    px = pts_ref[:, 0:1]                      # [BP, 1]
    py = pts_ref[:, 1:2]
    x1 = edg_ref[0:1, :]                      # [1, BE]
    y1 = edg_ref[1:2, :]
    x2 = edg_ref[2:3, :]
    y2 = edg_ref[3:4, :]
    cross = _cross_tile(px, py, x1, y1, x2, y2)          # [BP, BE]
    out_ref[...] += jnp.sum(cross.astype(jnp.int32), axis=1, keepdims=True)


def _pip_gathered_kernel(pts_ref, edg_ref, out_ref):
    """Per-point polygons: pts [BP, 2], edges [BP, 4, BE], out [BP, 1] i32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    px = pts_ref[:, 0:1]
    py = pts_ref[:, 1:2]
    x1 = edg_ref[:, 0, :]                     # [BP, BE]
    y1 = edg_ref[:, 1, :]
    x2 = edg_ref[:, 2, :]
    y2 = edg_ref[:, 3, :]
    cross = _cross_tile(px, py, x1, y1, x2, y2)
    out_ref[...] += jnp.sum(cross.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bp", "be", "interpret"))
def crossings_one(points: jnp.ndarray, edges_t: jnp.ndarray,
                  bp: int = DEF_BP, be: int = DEF_BE,
                  interpret: bool = False) -> jnp.ndarray:
    """Crossing counts of [N, 2] points against one [4, E] edge table.

    N must be a multiple of bp and E of be (ops.py pads).  Returns [N] i32.
    """
    n = points.shape[0]
    e = edges_t.shape[1]
    grid = (n // bp, e // be)
    out = pl.pallas_call(
        _pip_one_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((4, be), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(points, edges_t)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("bp", "be", "interpret"))
def crossings_gathered(points: jnp.ndarray, edges_t: jnp.ndarray,
                       bp: int = DEF_BP, be: int = DEF_BE,
                       interpret: bool = False) -> jnp.ndarray:
    """Crossing counts where each point brings its own edges [N, 4, E]."""
    n = points.shape[0]
    e = edges_t.shape[2]
    grid = (n // bp, e // be)
    out = pl.pallas_call(
        _pip_gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 4, be), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(points, edges_t)
    return out[:, 0]
