"""Fused gather-PIP Pallas kernel: candidate ids in, crossing counts out.

The exact fast path used to run in two device steps: gather each compacted
point's candidate edge table out of ``[P, E, 4]`` into a ``[R, E, 4]``
buffer in HBM, then hand that buffer to the gathered crossing-number
kernel.  The gather output is touched exactly once, so the round-trip
through HBM is pure bandwidth waste — the paper's fast approach wins
precisely because candidate lookup and the crossing test stay fused
(§fast).  This kernel removes the round-trip: it consumes the per-point
candidate *ids* plus a blocked-CSR edge pool directly, and the BlockSpec
index map (driven by scalar-prefetched ids) DMAs each point's edge slice
straight from the pool into VMEM inside the grid loop.

Data layout (``EdgePool``, built host-side by ``build_edge_pool``):

  * ``blocks [NB, 4, BE]`` f32 — the edge pool.  Every polygon's
    non-degenerate edges are packed struct-of-arrays (x1/y1/x2/y2 on the
    4-axis, edges on the BE-wide lane axis), zero-padded to whole blocks.
    Block 0 is reserved all-zero: zero-length edges produce no crossings,
    so it doubles as the "no candidate" (id < 0) target and the oracle's
    masked-gather target.
  * ``first [P]`` / ``count [P]`` i32 — CSR row pointers in block units:
    polygon ``p`` owns pool blocks ``first[p] .. first[p]+count[p]-1``.

Kernel schedule: grid ``(R, max_blocks)``, one point per grid row.  The
scalar-prefetched ``(first, nblk)`` tables are available before the body
runs, so the pool's index map picks block ``first[r] + b`` (clamped to the
last owned block when ``b >= nblk[r]``; the ``@pl.when`` guard keeps the
over-range steps from accumulating).  Pallas double-buffers the block DMA
across grid steps and skips the fetch entirely when consecutive steps map
to the same block — candidate ids sorted (or merely spatially correlated,
as compacted boundary buffers are) amortize to near-zero edge traffic.

The trade: one point per step uses 1 of 8 sublanes, but the op is
bandwidth-bound — eliminating the HBM materialization beats lane
utilization, and the BE-wide lane axis keeps the VPU fed per step.

``crossings_candidates`` is layout-transposed like the other kernels; use
``ops.pip_candidates`` for the public API (id masking, parity -> bool,
backend dispatch).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

# Edges per pool block (the lane axis).  128 is the f32 lane minimum; the
# default trades padding waste (small polygons zero-fill one block) against
# grid steps for large polygons (ceil(E / BE) blocks each).
DEF_BE = 256


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EdgePool:
    """Blocked-CSR edge pool (see module docstring for the layout)."""

    blocks: Any     # [NB, 4, BE] f32 — block 0 reserved all-zero
    first: Any      # [P] i32 — first pool block of polygon p
    count: Any      # [P] i32 — pool blocks owned by polygon p
    # -- static --
    max_blocks: int = dataclasses.field(metadata=dict(static=True),
                                        default=1)
    be: int = dataclasses.field(metadata=dict(static=True), default=DEF_BE)

    def tree_flatten(self):
        return (self.blocks, self.first, self.count), \
            (self.max_blocks, self.be)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, max_blocks=aux[0], be=aux[1])

    @property
    def n_poly(self) -> int:
        return self.first.shape[0]

    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes)
                   for a in (self.blocks, self.first, self.count))


def build_edge_pool(edges: np.ndarray, be: int = DEF_BE) -> EdgePool:
    """Pack a dense ``[P, E, 4]`` edge table into a blocked-CSR EdgePool.

    Degenerate (zero-length) padding edges are dropped, so raggedness in
    the dense table becomes real memory savings; a polygon with ``e`` live
    edges owns ``ceil(e / be)`` blocks.  Host-side (numpy).
    """
    e = np.asarray(edges, np.float32)
    p = e.shape[0]
    live = ~((e[..., 0] == e[..., 2]) & (e[..., 1] == e[..., 3]))
    n_live = live.sum(axis=1).astype(np.int64) if p else np.zeros(0, np.int64)
    count = np.ceil(n_live / be).astype(np.int32)
    first = np.ones(p, np.int32)                 # block 0 is reserved
    if p:
        first[1:] += np.cumsum(count)[:-1].astype(np.int32)
    nb = 1 + int(count.sum())
    blocks = np.zeros((nb, 4, be), np.float32)
    if p and n_live.sum():
        # Vectorized pack: e[live] is polygon-major, so each live edge's
        # (block, lane) destination follows from its rank within its
        # polygon; destinations are unique, plain fancy assignment works.
        el = e[live]                                        # [total, 4]
        poly_of = np.repeat(np.arange(p), n_live)
        starts = np.concatenate([[0], np.cumsum(n_live)[:-1]])
        pos = np.arange(len(el)) - starts[poly_of]          # rank in poly
        blk = first[poly_of] + pos // be
        blocks[blk, :, pos % be] = el
    return EdgePool(blocks=jnp.asarray(blocks), first=jnp.asarray(first),
                    count=jnp.asarray(count),
                    max_blocks=max(int(count.max()) if p else 1, 1), be=be)


def _gather_pip_kernel(first_ref, nblk_ref, pts_ref, blk_ref, out_ref):
    """One point vs one prefetched edge block: pts [1, 2], blk [1, 4, BE],
    out [1, 1] i32 accumulated across the block axis of the grid."""
    r = pl.program_id(0)
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(b < nblk_ref[r])
    def _acc():
        px = pts_ref[:, 0:1]                  # [1, 1]
        py = pts_ref[:, 1:2]
        x1 = blk_ref[0, 0:1, :]               # [1, BE]
        y1 = blk_ref[0, 1:2, :]
        x2 = blk_ref[0, 2:3, :]
        y2 = blk_ref[0, 3:4, :]
        straddle = (y1 > py) != (y2 > py)
        lhs = (px - x1) * (y2 - y1)
        rhs = (py - y1) * (x2 - x1)
        cross = straddle & ((lhs < rhs) == (y2 > y1))
        out_ref[...] += jnp.sum(cross.astype(jnp.int32), axis=1,
                                keepdims=True)


@functools.partial(jax.jit, static_argnames=("max_blocks", "interpret"))
def crossings_candidates(first: jnp.ndarray, nblk: jnp.ndarray,
                         points: jnp.ndarray, blocks: jnp.ndarray,
                         max_blocks: int = 1,
                         interpret: bool = False) -> jnp.ndarray:
    """Crossing counts of [R, 2] points vs their own pool edge slices.

    ``first``/``nblk`` [R] i32 are per-point block ranges (already resolved
    from candidate ids by ops.py; nblk == 0 means no candidate).  Returns
    [R] i32.
    """
    r = points.shape[0]
    nb = blocks.shape[0]

    def blk_map(i, b, first_ref, nblk_ref):
        # Clamp over-range steps onto the last owned (or reserved) block:
        # the revisit costs no DMA and the @pl.when guard discards it.
        last = first_ref[i] + jnp.maximum(nblk_ref[i] - 1, 0)
        blk = jnp.where(b < nblk_ref[i], first_ref[i] + b, last)
        return (jnp.clip(blk, 0, nb - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, b, *_: (i, 0)),
            pl.BlockSpec((1, 4, blocks.shape[2]), blk_map),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, b, *_: (i, 0)),
    )
    out = pl.pallas_call(
        _gather_pip_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(first.astype(jnp.int32), nblk.astype(jnp.int32),
      points.astype(jnp.float32), blocks)
    return out[:, 0]
