"""Fused flash-attention Pallas kernel (TPU target, interpret-validated).

EXPERIMENTS.md §Perf iterations 1.1/1.3 measured that the XLA lowering of
the blockwise attention materializes every f32 score/probability tile in
HBM — the dominant memory-roofline term of all train/prefill cells — and
that no jnp-level rewrite removes them.  This kernel is the structural fix
(mirroring the paper's own simple->fast arc): the online-softmax state
(m, l, acc) lives in VMEM scratch across KV tiles, so per-tile scores never
touch HBM.

Forward-only: serving/prefill use it directly; training integration needs
a custom VJP with recomputation (future work, noted in DESIGN.md §8).

Layout: q/k/v as [BH, S, D] (batch*heads leading); grid (BH, nq, nk) with
the KV axis innermost/sequential.  Causal masking is computed from program
ids; padded tail positions are masked by sequence-length bounds.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

DEF_BQ = 256
DEF_BK = 256
NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, seq_len: int, bq: int, bk: int,
                  scale: float):
    i = pl.program_id(1)           # q tile
    j = pl.program_id(2)           # kv tile (sequential)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                      # [bq, D]
    k = k_ref[0]                                      # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kpos < seq_len
    if causal:
        ok &= kpos <= qpos
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                            # [bq, bk] f32, VMEM
    r = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * r + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * r + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attn_bhsd(q, k, v, *, causal: bool = True, bq: int = DEF_BQ,
                    bk: int = DEF_BK, interpret: bool = False):
    """q/k/v: [BH, S, D] (same S, pre-padded to tile multiples by ops.py).

    Returns [BH, S, D] in q.dtype.  Scores/softmax state stay in VMEM.
    """
    bh, s, d = q.shape
    assert k.shape == (bh, s, d) and v.shape == (bh, s, d)
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    assert s % bq_ == 0 and s % bk_ == 0, (s, bq_, bk_)
    grid = (bh, s // bq_, s // bk_)
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(_flash_kernel, causal=causal, seq_len=s,
                               bq=bq_, bk=bk_, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),      # m
            pltpu.VMEM((bq_, 1), jnp.float32),      # l
            pltpu.VMEM((bq_, d), jnp.float32),      # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attn(q, k, v, *, causal: bool = True, bq: int = DEF_BQ,
               bk: int = DEF_BK, interpret: bool = False):
    """Convenience wrapper: q [B,S,H,D], k/v [B,S,KH,D] (KV repeated to H).

    Requires S to be a multiple of the (auto-clamped) tile sizes — the
    production shapes are powers of two; ragged tails belong to the caller.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    assert s % bq_ == 0 and s % bk_ == 0, \
        f"seq {s} must be a multiple of the tile ({bq_}, {bk_})"
    q2 = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    k2 = jnp.moveaxis(k, 2, 1).reshape(b * h, s, d)
    v2 = jnp.moveaxis(v, 2, 1).reshape(b * h, s, d)
    o = flash_attn_bhsd(q2, k2, v2, causal=causal, bq=bq_, bk=bk_,
                        interpret=interpret)
    return jnp.moveaxis(o.reshape(b, h, s, d), 1, 2)


def make_flash_attn_trainable(*, causal: bool = True, bq: int = DEF_BQ,
                              bk: int = DEF_BK, interpret: bool = False,
                              chunk: int = 1024):
    """Training-capable flash attention: forward runs the fused Pallas
    kernel; backward recomputes through the checkpointed blockwise-jnp
    path (the standard recompute-based flash VJP, reusing the oracle as
    the gradient program — bitwise-compatible semantics, no saved score
    tiles).

    Returns f(q [B,S,H,D], k/v [B,S,KH,D]) -> [B,S,H,D].
    """
    from repro.models.attention import blockwise_attn

    def reference(q, k, v):
        kh = k.shape[2]
        g = q.shape[2] // kh
        k_ = jnp.repeat(k, g, axis=2) if g > 1 else k
        v_ = jnp.repeat(v, g, axis=2) if g > 1 else v
        return blockwise_attn(q, k_, v_, causal=causal,
                              chunk_q=min(chunk, q.shape[1]),
                              chunk_kv=min(chunk, q.shape[1]))

    @jax.custom_vjp
    def f(q, k, v):
        return flash_attn(q, k, v, causal=causal, bq=bq, bk=bk,
                          interpret=interpret)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(reference, q, k, v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f
