"""Pallas TPU kernels for the bounding-box filter (paper §III).

The paper's ``A_in`` candidate matrix is a sparse boolean outer product on
CPU (GraphBLAS); on TPU we realize it as dense VMEM tiles:

  * ``bbox_mask``          — [N, M] int8 membership tile against a shared box
                             table (the flat, top-of-hierarchy test).
  * ``bbox_count_select``  — fused row-count + containing-slot select over
                             *gathered* per-point box tables [N, 4, C]
                             (the hierarchical step: C = children of the
                             point's current parent).  Fusing avoids ever
                             materializing the [N, C] mask in HBM — the
                             common case (count == 1, paper: ~80 %) reads the
                             answer straight from the select lane.

Layouts are struct-of-arrays ([4, M] / [N, 4, C]) so the box axis sits on
VPU lanes.  Padded boxes must be empty (xmin > xmax): they never match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import CompilerParams

DEF_BP = 512
DEF_BM = 512


def _mask_tile(px, py, xmin, xmax, ymin, ymax):
    return (px > xmin) & (px < xmax) & (py > ymin) & (py < ymax)


def _bbox_mask_kernel(pts_ref, box_ref, out_ref):
    px = pts_ref[:, 0:1]
    py = pts_ref[:, 1:2]
    m = _mask_tile(px, py, box_ref[0:1, :], box_ref[1:2, :],
                   box_ref[2:3, :], box_ref[3:4, :])
    out_ref[...] = m.astype(jnp.int8)


def _bbox_count_select_kernel(pts_ref, box_ref, cnt_ref, sel_ref):
    px = pts_ref[:, 0:1]
    py = pts_ref[:, 1:2]
    m = _mask_tile(px, py, box_ref[:, 0, :], box_ref[:, 1, :],
                   box_ref[:, 2, :], box_ref[:, 3, :])      # [BP, C]
    cnt_ref[...] = jnp.sum(m.astype(jnp.int32), axis=1, keepdims=True)
    c = m.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, m.shape, 1)
    sel_ref[...] = jnp.max(jnp.where(m, iota, -1), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bp", "bm", "interpret"))
def bbox_mask(points: jnp.ndarray, boxes_t: jnp.ndarray,
              bp: int = DEF_BP, bm: int = DEF_BM,
              interpret: bool = False) -> jnp.ndarray:
    """[N, M] int8 membership of [N, 2] points in a shared [4, M] box table."""
    n = points.shape[0]
    m = boxes_t.shape[1]
    grid = (n // bp, m // bm)
    return pl.pallas_call(
        _bbox_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((4, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(points, boxes_t)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def bbox_count_select(points: jnp.ndarray, boxes_t: jnp.ndarray,
                      bp: int = DEF_BP, interpret: bool = False):
    """Fused count+select over gathered per-point boxes.

    Args:
      points:  [N, 2] f32.
      boxes_t: [N, 4, C] f32, C padded to a lane multiple with empty boxes.
    Returns:
      (count [N] i32, sel [N] i32) — sel is the largest containing slot,
      -1 when count == 0.
    """
    n = points.shape[0]
    c = boxes_t.shape[2]
    grid = (n // bp,)
    cnt, sel = pl.pallas_call(
        _bbox_count_select_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, 2), lambda i: (i, 0)),
            pl.BlockSpec((bp, 4, c), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(points, boxes_t)
    return cnt[:, 0], sel[:, 0]
