"""Public kernel API: natural layouts, padding, backend dispatch.

Backend selection (``REPRO_KERNELS`` env var or explicit ``backend=``):
  * ``pallas``    — compiled Pallas TPU kernels (the deployment target).
  * ``interpret`` — Pallas kernels under ``interpret=True`` (kernel body
                    executed in Python/XLA on CPU; used to validate the
                    kernels off-TPU, incl. in CI).
  * ``ref``       — pure-jnp oracles from ref.py (fast on CPU, and the
                    ground truth the kernels are tested against).
  * ``auto``      — ``pallas`` on TPU, ``ref`` elsewhere (default).
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bbox as bbox_kernels
from repro.kernels import cascade as cascade_kernels
from repro.kernels import gather_pip as gather_pip_kernels
from repro.kernels import pip as pip_kernels
from repro.kernels import ref
from repro.kernels import segment as segment_kernels
# re-export: ops.* is the one import surface strategy code and tests
# use for the edge-pool helpers (ops.DEF_BE, ops.build_edge_pool).
# geolint: ignore[unused-import] -- re-export through ops.*
from repro.kernels.gather_pip import (DEF_BE, EdgePool,  # noqa: F401
                                      build_edge_pool)
# (re-exported: ops is the one import surface strategy code uses)

# A padding point guaranteed outside every bbox / polygon we generate.
FAR = 1.0e30


def resolve_backend(backend: str | None = None) -> str:
    b = backend or os.environ.get("REPRO_KERNELS", "auto")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert b in ("pallas", "interpret", "ref"), b
    return b


def _pad_axis(x: jnp.ndarray, axis: int, mult: int, value) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pip_one(points: jnp.ndarray, edges: jnp.ndarray,
            backend: str | None = None) -> jnp.ndarray:
    """Inside mask of [N, 2] points vs one polygon's [E, 4] edge table."""
    b = resolve_backend(backend)
    if b == "ref":
        return ref.pip_one(points, edges)
    n = points.shape[0]
    bp, be = pip_kernels.DEF_BP, pip_kernels.DEF_BE
    pts = _pad_axis(points.astype(jnp.float32), 0, bp, FAR)
    edges_t = _pad_axis(edges.astype(jnp.float32).T, 1, be, 0.0)
    cross = pip_kernels.crossings_one(pts, edges_t,
                                      interpret=(b == "interpret"))
    return (cross[:n] & 1).astype(jnp.bool_)


def pip_gathered(points: jnp.ndarray, edges: jnp.ndarray,
                 backend: str | None = None) -> jnp.ndarray:
    """Inside mask where each point brings its own [E, 4] edges: [N, E, 4]."""
    b = resolve_backend(backend)
    if b == "ref":
        return ref.pip_gathered(points, edges)
    n = points.shape[0]
    bp, be = pip_kernels.DEF_BP, pip_kernels.DEF_BE
    pts = _pad_axis(points.astype(jnp.float32), 0, bp, FAR)
    edges_t = jnp.swapaxes(edges.astype(jnp.float32), 1, 2)   # [N, 4, E]
    edges_t = _pad_axis(_pad_axis(edges_t, 2, be, 0.0), 0, bp, 0.0)
    cross = pip_kernels.crossings_gathered(pts, edges_t,
                                           interpret=(b == "interpret"))
    return (cross[:n] & 1).astype(jnp.bool_)


def pip_candidates(points: jnp.ndarray, pids: jnp.ndarray, pool: EdgePool,
                   backend: str | None = None) -> jnp.ndarray:
    """Fused gather-PIP: inside mask of [N, 2] points vs their own
    candidate polygon ids [N] (id < 0 = no candidate, never inside).

    The candidate's edge slice is read straight out of ``pool``
    (blocked-CSR; see kernels/gather_pip.py) — no gathered [N, E, 4]
    edge table is ever materialized in HBM.
    """
    b = resolve_backend(backend)
    if pool.n_poly == 0:               # empty polygon table: nothing matches
        return jnp.zeros(points.shape[0], jnp.bool_)
    valid = pids >= 0
    safe = jnp.clip(pids, 0, max(pool.n_poly - 1, 0))
    first = jnp.where(valid, pool.first[safe], 0).astype(jnp.int32)
    nblk = jnp.where(valid, pool.count[safe], 0).astype(jnp.int32)
    if b == "ref":
        cross = ref.crossings_candidates(points, first, nblk, pool.blocks,
                                         pool.max_blocks)
    else:
        cross = gather_pip_kernels.crossings_candidates(
            first, nblk, points.astype(jnp.float32), pool.blocks,
            max_blocks=pool.max_blocks, interpret=(b == "interpret"))
    return (cross & 1).astype(jnp.bool_) & valid


def assign_cascade(points: jnp.ndarray, quant: jnp.ndarray,
                   cell_lo: jnp.ndarray, cell_hi: jnp.ndarray,
                   cell_val: jnp.ndarray, top_start: jnp.ndarray,
                   cand: jnp.ndarray, bbox: jnp.ndarray, pool: EdgePool, *,
                   max_level: int, gbits: int, search_iters: int,
                   backend: str | None = None):
    """One-pass fused cascade: [N, 2] points -> (bid, flags, nrest,
    nskip), each [N] i32 (kernels/cascade.py has the full encoding).

    The whole quantize -> cell lookup -> bbox filter -> PIP pipeline runs
    in one kernel (or its bit-exact ref oracle); no per-stage HBM
    intermediates.  ``bbox`` is the [P, 4] (xmin, xmax, ymin, ymax)
    table aligned with the pool's polygon ids.  Empty cell/candidate/
    polygon tables are normalized here so both backends see identical
    never-matching sentinels.
    """
    b = resolve_backend(backend)
    if cand.shape[0] == 0 or cand.shape[1] == 0:
        cand = jnp.full((1, max(cand.shape[1], 1)), -1, jnp.int32)
    if cell_lo.shape[0] == 0:
        # One unreachable row (lo > hi never brackets a code).
        cell_lo = jnp.ones((1,), jnp.int32)
        cell_hi = jnp.zeros((1,), jnp.int32)
        cell_val = jnp.zeros((1,), jnp.int32)
    first, count, blocks = pool.first, pool.count, pool.blocks
    if pool.n_poly == 0:
        first = jnp.zeros((1,), jnp.int32)
        count = jnp.zeros((1,), jnp.int32)
        bbox = jnp.array([[1.0, 0.0, 1.0, 0.0]], jnp.float32)  # empty box
    else:
        assert bbox.shape[0] == pool.n_poly, (bbox.shape, pool.n_poly)
    iters = cascade_kernels.effective_iters(cell_lo.shape[0], gbits,
                                            search_iters)
    if b == "ref":
        return ref.assign_cascade(
            points, quant, cell_lo, cell_hi, cell_val, top_start, cand,
            bbox, first, count, blocks, max_level=max_level, gbits=gbits,
            search_iters=iters, max_blocks=pool.max_blocks)
    return cascade_kernels.assign_cascade(
        points, quant, cell_lo, cell_hi, cell_val, top_start, cand, bbox,
        first, count, blocks, max_level=max_level, gbits=gbits,
        search_iters=iters, interpret=(b == "interpret"))


def bbox_mask(points: jnp.ndarray, boxes: jnp.ndarray,
              backend: str | None = None) -> jnp.ndarray:
    """[N, M] int8 membership of points in a shared [M, 4] box table."""
    b = resolve_backend(backend)
    if b == "ref":
        return ref.bbox_mask(points, boxes)
    n, m = points.shape[0], boxes.shape[0]
    bp, bm = bbox_kernels.DEF_BP, bbox_kernels.DEF_BM
    pts = _pad_axis(points.astype(jnp.float32), 0, bp, FAR)
    # Pad with empty boxes (xmin=1 > xmax=0).
    boxes_t = boxes.astype(jnp.float32).T                     # [4, M]
    pad = (-m) % bm
    if pad:
        empty = jnp.tile(jnp.array([[1.0], [0.0], [1.0], [0.0]],
                                   dtype=jnp.float32), (1, pad))
        boxes_t = jnp.concatenate([boxes_t, empty], axis=1)
    out = bbox_kernels.bbox_mask(pts, boxes_t,
                                 interpret=(b == "interpret"))
    return out[:n, :m]


def bbox_mask_gathered(points: jnp.ndarray, boxes: jnp.ndarray,
                       backend: str | None = None) -> jnp.ndarray:
    """[N, C] int8 membership in per-point gathered boxes [N, C, 4].

    All backends lower to the jnp reference: the comparison work is
    bandwidth-bound gather output XLA fuses into its consumers, so a Pallas
    kernel buys nothing here.  The signature still takes ``backend`` so
    callers route every geometry op through this module uniformly.
    """
    resolve_backend(backend)   # validate the override even though unused
    return ref.bbox_mask_gathered(points, boxes)


def bbox_count_select(points: jnp.ndarray, boxes: jnp.ndarray,
                      backend: str | None = None):
    """Fused count+select over per-point gathered boxes [N, C, 4].

    Padded slots must already be empty boxes; C is padded here to a lane
    multiple with empties.  Returns (count [N] i32, sel [N] i32).
    """
    b = resolve_backend(backend)
    if b == "ref":
        return ref.bbox_count_select(points, boxes)
    n, c = points.shape[0], boxes.shape[1]
    bp = bbox_kernels.DEF_BP
    pts = _pad_axis(points.astype(jnp.float32), 0, bp, FAR)
    boxes_t = jnp.swapaxes(boxes.astype(jnp.float32), 1, 2)   # [N, 4, C]
    cpad = (-c) % 128
    if cpad:
        empty = jnp.zeros((boxes_t.shape[0], 4, cpad), jnp.float32)
        empty = empty.at[:, 0, :].set(1.0)                    # xmin=1 > xmax=0
        boxes_t = jnp.concatenate([boxes_t, empty], axis=2)
    boxes_t = _pad_axis(boxes_t, 0, bp, 0.0)
    cnt, sel = bbox_kernels.bbox_count_select(pts, boxes_t,
                                              interpret=(b == "interpret"))
    return cnt[:n], sel[:n]


class SegmentReduce(NamedTuple):
    """Per-segment aggregates of ``segment_reduce`` (all [S]-shaped).
    ``min``/``max`` are only meaningful where ``count > 0`` (empty
    segments carry the +inf/-inf reduction identities)."""

    count: jnp.ndarray                 # i32
    sum: jnp.ndarray                   # f32
    min: jnp.ndarray                   # f32
    max: jnp.ndarray                   # f32


def segment_reduce(ids: jnp.ndarray, values: Optional[jnp.ndarray] = None,
                   *, n_segments: int, backend: str | None = None,
                   bp: int | None = None,
                   bs: int | None = None) -> SegmentReduce:
    """Per-block aggregation of assigned ids (DESIGN.md §16): count /
    sum / min / max of ``values`` grouped by ``ids`` over ``n_segments``
    blocks.  Rows with ids outside [0, n_segments) — the cascade's -1
    "off map" answer included — are ignored in every backend.

    ``values=None`` aggregates a zero column (callers wanting only
    occupancy counts).  The kernel path stable-sorts rows by id first
    (the sort-by-block-id layout kernels/segment.py expects); the ref
    path is the pure-jnp segment-op oracle.  Semantic ground truth is
    ``ref.np_segment_reduce`` (numpy bincount, f64 accumulate).
    """
    b = resolve_backend(backend)
    ids = ids.astype(jnp.int32)
    if values is None:
        values = jnp.zeros(ids.shape, jnp.float32)
    values = values.astype(jnp.float32)
    assert values.shape == ids.shape, (values.shape, ids.shape)
    # Park every invalid row at the scratch segment so all backends see
    # one normalized id range [0, n_segments].
    invalid = (ids < 0) | (ids >= n_segments)
    ids = jnp.where(invalid, n_segments, ids)
    if b == "ref":
        out = ref.segment_reduce(ids, values, n_segments)
    else:
        bp = bp or segment_kernels.DEF_BP
        bs = bs or segment_kernels.DEF_BS
        order = jnp.argsort(ids)           # jax sorts are stable
        ids_s = _pad_axis(ids[order], 0, bp, n_segments)
        vals_s = _pad_axis(values[order], 0, bp, 0.0)
        # Segments padded past the park id so parked/padded rows land in
        # a scratch block that the final slice drops.
        s_pad = ((n_segments + 1 + bs - 1) // bs) * bs
        out = segment_kernels.segment_reduce_sorted(
            ids_s.reshape(-1, bp), vals_s.reshape(-1, bp), s_pad,
            bp=bp, bs=bs, interpret=(b == "interpret"))
        out = tuple(o[:n_segments] for o in out)
    count, total, vmin, vmax = out
    # Normalize empty-segment sentinels once, after any backend, so the
    # three backends are identical by construction even if a backend's
    # reduction identity differs in sign-of-zero or NaN handling.
    empty = count == 0
    return SegmentReduce(
        count.astype(jnp.int32),
        jnp.where(empty, jnp.float32(0.0), total),
        jnp.where(empty, jnp.float32(jnp.inf), vmin),
        jnp.where(empty, jnp.float32(-jnp.inf), vmax))


def segment_counts(ids: jnp.ndarray, *, n_segments: int,
                   backend: str | None = None) -> jnp.ndarray:
    """[S] i32 occupancy counts of assigned ids (invalid ids ignored)."""
    return segment_reduce(ids, None, n_segments=n_segments,
                          backend=backend).count


def assign_aggregate(points: jnp.ndarray, quant: jnp.ndarray,
                     cell_lo: jnp.ndarray, cell_hi: jnp.ndarray,
                     cell_val: jnp.ndarray, top_start: jnp.ndarray,
                     cand: jnp.ndarray, bbox: jnp.ndarray, pool: EdgePool,
                     *, n_segments: int, max_level: int, gbits: int,
                     search_iters: int,
                     values: Optional[jnp.ndarray] = None,
                     backend: str | None = None):
    """Fused assign→aggregate: the one-pass cascade immediately followed
    by the segment reduction, composed device-side so the [N] id vector
    is never materialized back on host — only the [S] per-block
    aggregates (and the cascade's [N] stats words, if the caller keeps
    them) cross the boundary.  Under ``jax.jit`` the two stages compile
    into one XLA computation per backend.

    Returns ``(SegmentReduce, (bid, flags, nrest, nskip))`` — the raw
    cascade outputs ride along for ``onepass_stats`` accounting; callers
    that only fetch the aggregates never pay the [N] transfer.
    """
    bid, flags, nrest, nskip = assign_cascade(
        points, quant, cell_lo, cell_hi, cell_val, top_start, cand, bbox,
        pool, max_level=max_level, gbits=gbits, search_iters=search_iters,
        backend=backend)
    red = segment_reduce(bid, values, n_segments=n_segments,
                         backend=backend)
    return red, (bid, flags, nrest, nskip)


def edges_from_soup_np(verts: np.ndarray) -> np.ndarray:
    """[P, max_v+1, 2] padded rings -> [P, max_v, 4] edge tables (host)."""
    a = verts[:, :-1, :]
    c = verts[:, 1:, :]
    return np.concatenate([a, c], axis=-1)
