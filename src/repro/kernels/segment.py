"""Pallas TPU segment-reduce kernel for per-block aggregation
(DESIGN.md §16).

The analytics layer's device primitive: given assigned block ids
(``ops.assign_cascade`` / fast-exact output) and an optional per-point
value column, produce per-block ``count`` / ``sum`` / ``min`` / ``max``
— the occupancy and attribute aggregates the paper's downstream
workloads (crowding density, encounter counting) are built from.  The
id vector never has to leave the device: ``ops.assign_aggregate``
composes the cascade with this kernel so only the [S]-sized aggregate
crosses back to host.

Layout: the caller (``ops.segment_reduce``) stable-sorts rows by block
id, pads rows to a ``bp`` multiple (pad id = the park segment, sliced
off afterwards) and segments to a ``bs`` multiple, then hands the
kernel row tiles of shape [1, bp].  Grid is (segment tiles ×
row tiles): each step matches its row tile against its segment tile
with a broadcast-compare one-hot ([bp, bs] in VMEM, a pure VPU
reduction — counts/sums/extrema all reduce over the row axis), and
accumulates into the output block.  The row-tile axis is sequential
("arbitrary") because output blocks are revisited accumulators; the
segment-tile axis is parallel.  Sorting makes almost every (segment
tile, row tile) pair's one-hot all-false — on TPU those steps are
cheap VPU no-ops, and the sequential revisit order makes the f32 sum's
tile association deterministic for a given sorted layout.

Sentinels: empty segments report ``min = +inf`` / ``max = -inf`` —
the same identities ``jax.ops.segment_min``/``max`` use, so the ref
backend agrees bit-for-bit (``ops.segment_reduce`` additionally
normalizes them so every backend is identical by construction).

Bit-identity contract (tested in tests/test_analytics.py): ``count``,
``min`` and ``max`` are order-free and bit-identical across
pallas/interpret/ref and the numpy ``bincount`` oracle
(``ref.np_segment_reduce``); f32 ``sum`` is bit-identical whenever the
values are exactly representable sums (e.g. integer-valued f32 below
2**24 — the occupancy/count workloads), and reduction-order-rounded
otherwise (tested allclose).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import CompilerParams

DEF_BP = 512       # rows per grid step
DEF_BS = 512       # segments per grid step

_INF = float("inf")


def _segment_kernel(ids_ref, val_ref, cnt_ref, sum_ref, min_ref, max_ref,
                    *, bs: int):
    j = pl.program_id(0)               # segment tile (parallel)
    i = pl.program_id(1)               # row tile (sequential accumulate)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        min_ref[...] = jnp.full_like(min_ref, _INF)
        max_ref[...] = jnp.full_like(max_ref, -_INF)

    local = ids_ref[0, :] - j * bs                       # [bp] i32
    bp = local.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bp, bs), 1)
    onehot = local[:, None] == iota                      # [bp, bs] bool
    v = val_ref[0, :][:, None]                           # [bp, 1] f32
    cnt_ref[0, :] += jnp.sum(onehot.astype(jnp.int32), axis=0)
    sum_ref[0, :] += jnp.sum(jnp.where(onehot, v, 0.0), axis=0)
    min_ref[0, :] = jnp.minimum(
        min_ref[0, :], jnp.min(jnp.where(onehot, v, _INF), axis=0))
    max_ref[0, :] = jnp.maximum(
        max_ref[0, :], jnp.max(jnp.where(onehot, v, -_INF), axis=0))


@functools.partial(jax.jit,
                   static_argnames=("n_segments", "bp", "bs", "interpret"))
def segment_reduce_sorted(ids: jnp.ndarray, values: jnp.ndarray,
                          n_segments: int, bp: int = DEF_BP,
                          bs: int = DEF_BS, interpret: bool = False):
    """Per-segment (count, sum, min, max) over pre-sorted, pre-padded
    rows.

    Args:
      ids:    [T, bp] i32 — sorted block ids, rows padded with an
              out-of-range park id (>= ceil-padded segment count is
              fine: parked rows match no segment tile).
      values: [T, bp] f32 — value column aligned with ``ids`` (zeros
              when the caller only wants counts).
      n_segments: padded segment count (``bs`` multiple).
    Returns:
      (count [S] i32, sum [S] f32, min [S] f32, max [S] f32) with
      S = n_segments; empty segments are (0, 0.0, +inf, -inf).
    """
    t = ids.shape[0]
    assert ids.shape == values.shape, (ids.shape, values.shape)
    assert n_segments % bs == 0, (n_segments, bs)
    grid = (n_segments // bs, t)
    row_spec = pl.BlockSpec((1, ids.shape[1]), lambda j, i: (i, 0))
    out_spec = pl.BlockSpec((1, bs), lambda j, i: (j, 0))
    shape = (n_segments // bs, bs)
    cnt, tot, vmin, vmax = pl.pallas_call(
        functools.partial(_segment_kernel, bs=bs),
        grid=grid,
        in_specs=[row_spec, row_spec],
        out_specs=[out_spec, out_spec, out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct(shape, jnp.int32),
                   jax.ShapeDtypeStruct(shape, jnp.float32),
                   jax.ShapeDtypeStruct(shape, jnp.float32),
                   jax.ShapeDtypeStruct(shape, jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids.astype(jnp.int32), values.astype(jnp.float32))
    s = n_segments
    return (cnt.reshape(s), tot.reshape(s), vmin.reshape(s),
            vmax.reshape(s))
