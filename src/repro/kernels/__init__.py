# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from jax.experimental.pallas import tpu as _pltpu

# jax-version compat: jax < 0.5 names the Mosaic params TPUCompilerParams,
# newer jax CompilerParams.  Every kernel module imports it from here.
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")
