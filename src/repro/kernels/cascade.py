"""One-pass fused cascade kernel: quantize -> Morton/cell lookup -> bbox
filter -> point-in-polygon, one Pallas kernel (DESIGN.md §13).

The exact fast path still runs as separately-JIT'd stages: leaf codes,
cell lookup, boundary compaction, and the (fused) gather-PIP each
materialize their intermediates in HBM between XLA computations.  The
paper's fast approach wins precisely because the whole cascade stays in
registers/cache per point — this kernel is the TPU analogue: a point is
loaded once, and interior points ("true hits", the vast majority) finish
without touching HBM again.

Per grid step (one point):

  1. fixed-point quantize + Morton-interleave to a leaf code (scalar bit
     arithmetic, same fp32 ops as ``core.fast.quantize_codes``);
  2. locate the covering cell: top-grid bucket (2*gbits direct bits) then
     a fixed-iteration binary search over the VMEM-resident interval
     starts — identical integer logic to ``core.fast.locate_cells``;
  3. interior cell -> block id, done;  boundary cell -> walk the <= K
     candidate slots in order: a candidate whose bbox (VMEM [P, 4]
     table) strictly excludes the point is rejected without touching its
     edges; otherwise its blocked-CSR edge slice is DMA'd from the HBM
     ``EdgePool`` into a double-buffered VMEM scratch (block b+1 in
     flight while block b is tested) and the crossing-number test runs.
     First matching candidate wins; no match falls back to the slot-0
     centre owner (same policy as ``resolve_candidates(fallback=
     "first")``).

The candidate DMA is *data dependent* (the block range comes from the
in-kernel cell lookup), which a BlockSpec index map cannot express —
index maps run before the body.  Hence the manual ``make_async_copy``
double buffering; the pool stays in ``TPUMemorySpace.ANY`` (HBM) and
only the blocks a boundary point actually needs ever cross into VMEM.
Unlike the BlockSpec pipeline in kernels/gather_pip.py there is no
automatic revisit-skip across points, but interior points issue zero
copies, so total edge traffic is bounded by boundary traffic alone.

Outputs (all [N] i32; ``ops.assign_cascade`` is the public dispatch):

  * bid   — block id (-1 = off map / no covering cell / no candidate);
  * flags — bit 0: boundary-cell hit, bit 1: resolved by slot 0
            (the two bits ``core.resolve.onepass_stats`` needs to
            reproduce the two-phase schedule's n_pip accounting);
  * nrest — count of valid candidates in slots 1..K-1 (what phase 2
            *would* have tested had slot 0 missed);
  * nskip — candidate slots rejected by the bbox filter before any edge
            was fetched (observability: DMA avoided).

Scalar reads out of VMEM-resident tables keep the whole cascade in one
kernel; the interpret backend is the validation target off-TPU (tests
assert bit-identity vs the ``kernels.ref`` CSR oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

# Sentinel cell value for "off extent / no covering cell".  Must equal
# core.fast.OUTSIDE — core imports kernels (never the reverse), so the
# kernel package owns a copy and core asserts equality.
OUTSIDE = -2**30


def part1by1(x):
    """Spread the low 16 bits of ``x`` over even bit positions (works on
    scalars and arrays alike — the kernel uses the scalar form, the ref
    oracle the vector form)."""
    x = x & 0x0000FFFF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def morton(ix, iy):
    return (part1by1(iy) << 1) | part1by1(ix)


def effective_iters(n_cells: int, gbits: int, search_iters: int) -> int:
    """Binary-search iteration count for the in-kernel cell locate.  With
    a top grid (gbits > 0) the index's recorded per-bucket bound applies;
    without one the search spans the whole table, so the bound is
    log2(n_cells) — mirroring ``locate_cells``'s full searchsorted."""
    if gbits > 0:
        return max(1, int(search_iters))
    return max(1, int(np.ceil(np.log2(max(int(n_cells), 2)))))


def _pip_dma(pool_ref, buf, sems, first, nblk, px, py):
    """Crossing count of scalar point (px, py) vs pool blocks
    ``first .. first+nblk-1``, double-buffered HBM->VMEM.

    Block 0's copy is started before the loop; iteration b waits on its
    own buffer slot, immediately starts block b+1 into the other slot,
    then runs the crossing test on the just-landed block — the DMA for
    the next block overlaps the VPU work on the current one.  nblk == 0
    (interior point / bbox-rejected candidate) is a zero-trip loop: no
    copy is ever issued.
    """
    @pl.when(nblk > 0)
    def _prologue():
        pltpu.make_async_copy(pool_ref.at[first], buf.at[0],
                              sems.at[0]).start()

    def body(b, acc):
        slot = jax.lax.rem(b, 2)
        pltpu.make_async_copy(pool_ref.at[first + b], buf.at[slot],
                              sems.at[slot]).wait()
        nxt = jax.lax.rem(b + 1, 2)

        @pl.when(b + 1 < nblk)
        def _prefetch():
            pltpu.make_async_copy(pool_ref.at[first + b + 1], buf.at[nxt],
                                  sems.at[nxt]).start()

        x1 = buf[slot, 0:1, :]                    # [1, BE]
        y1 = buf[slot, 1:2, :]
        x2 = buf[slot, 2:3, :]
        y2 = buf[slot, 3:4, :]
        straddle = (y1 > py) != (y2 > py)
        lhs = (px - x1) * (y2 - y1)
        rhs = (py - y1) * (x2 - x1)
        cross = straddle & ((lhs < rhs) == (y2 > y1))
        return acc + jnp.sum(cross.astype(jnp.int32))

    return jax.lax.fori_loop(0, nblk, body, jnp.int32(0))


def _cascade_kernel(pts_ref, quant_ref, lo_ref, hi_ref, val_ref, top_ref,
                    cand_ref, bbox_ref, first_ref, count_ref, pool_ref,
                    bid_ref, flags_ref, nrest_ref, nskip_ref, buf, sems, *,
                    max_level, gbits, iters, k, n_cells, n_brows, n_poly):
    px = pts_ref[0, 0]
    py = pts_ref[0, 1]

    # -- stage 1: quantize + Morton (scalar twin of quantize_codes) --------
    span = jnp.float32(1 << max_level)
    fx = (px - quant_ref[0]) * quant_ref[2]
    fy = (py - quant_ref[1]) * quant_ref[3]
    in_ext = (fx >= 0.0) & (fx < span) & (fy >= 0.0) & (fy < span)
    nmax = jnp.int32((1 << max_level) - 1)
    ix = jnp.clip(fx.astype(jnp.int32), 0, nmax)
    iy = jnp.clip(fy.astype(jnp.int32), 0, nmax)
    code = morton(ix, iy)

    # -- stage 2: cell locate (bucket + fixed-iteration binary search) -----
    if gbits > 0:
        shift = 2 * (max_level - gbits)
        bucket = code >> shift
        lo0 = jnp.maximum(top_ref[bucket] - 1, 0)
        hi0 = top_ref[bucket + 1]
    else:
        lo0 = jnp.int32(0)
        hi0 = jnp.int32(n_cells)

    def search(_, lh):
        l, h = lh
        active = l < h
        mid = (l + h) // 2
        go_right = lo_ref[jnp.clip(mid, 0, n_cells - 1)] <= code
        nl = jnp.where(active & go_right, mid + 1, l)
        nh = jnp.where(active & ~go_right, mid, h)
        return nl, nh

    l, _ = jax.lax.fori_loop(0, iters, search, (lo0, hi0))
    cidx = jnp.clip(l - 1, 0, n_cells - 1)
    in_cell = (lo_ref[cidx] <= code) & (code <= hi_ref[cidx]) & in_ext
    v = jnp.where(in_cell, val_ref[cidx], jnp.int32(OUTSIDE))

    # -- stage 3+4: bbox filter + DMA'd PIP over the candidate slots -------
    boundary = (v < 0) & (v > jnp.int32(OUTSIDE))
    brow = jnp.clip(-(v + 1), 0, n_brows - 1)
    best = jnp.int32(-1)
    slot0_hit = boundary & False
    nrest = jnp.int32(0)
    nskip = jnp.int32(0)
    for s in range(k):
        pid = cand_ref[brow, s]
        valid = boundary & (pid >= 0)
        if s > 0:
            nrest = nrest + valid.astype(jnp.int32)
        attempt = valid & (best < 0)        # first match wins: early exit
        safe = jnp.clip(pid, 0, n_poly - 1)
        inb = ((px > bbox_ref[safe, 0]) & (px < bbox_ref[safe, 1])
               & (py > bbox_ref[safe, 2]) & (py < bbox_ref[safe, 3]))
        do = attempt & inb
        nskip = nskip + (attempt & ~inb).astype(jnp.int32)
        nblk = jnp.where(do, count_ref[safe], 0)
        cross = _pip_dma(pool_ref, buf, sems, first_ref[safe], nblk,
                         px, py)
        inside = do & ((cross & 1) == 1)
        best = jnp.where(inside, pid, best)
        if s == 0:
            slot0_hit = inside

    fb0 = cand_ref[brow, 0]
    fallback = jnp.where(fb0 >= 0, fb0, jnp.int32(-1))
    resolved = jnp.where(best >= 0, best, fallback)
    bid = jnp.where(boundary, resolved,
                    jnp.where(v >= 0, v, jnp.int32(-1)))
    bid_ref[0, 0] = bid
    flags_ref[0, 0] = (boundary.astype(jnp.int32)
                       | (slot0_hit.astype(jnp.int32) << 1))
    nrest_ref[0, 0] = nrest
    nskip_ref[0, 0] = nskip


def _whole(shape):
    """Full-array VMEM residency: one block covering the array, revisited
    every grid step (no per-step refetch)."""
    return pl.BlockSpec(shape, lambda i: (0,) * len(shape))


@functools.partial(jax.jit, static_argnames=("max_level", "gbits",
                                             "search_iters", "interpret"))
def assign_cascade(points, quant, cell_lo, cell_hi, cell_val, top_start,
                   cand, bbox, first, count, blocks, *, max_level: int,
                   gbits: int, search_iters: int, interpret: bool = False):
    """One-pass fused cascade over [N, 2] points (see module docstring).

    Inputs are assumed well-formed (``ops.assign_cascade`` normalizes
    empty tables before dispatch): ``cand`` [B>=1, K>=1] i32, ``bbox``
    [P, 4] f32 aligned with the pool's ``first``/``count`` [P] i32,
    ``blocks`` [NB, 4, BE] f32 with block 0 reserved all-zero.
    ``search_iters`` must already be ``effective_iters``-normalized.
    Returns (bid, flags, nrest, nskip), each [N] i32.
    """
    n = points.shape[0]
    be = blocks.shape[2]
    kernel = functools.partial(
        _cascade_kernel, max_level=max_level, gbits=gbits,
        iters=search_iters, k=cand.shape[1], n_cells=cell_lo.shape[0],
        n_brows=cand.shape[0], n_poly=first.shape[0])
    out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (i, 0)),             # point
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM),  # quant
            _whole(cell_lo.shape), _whole(cell_hi.shape),
            _whole(cell_val.shape), _whole(top_start.shape),
            _whole(cand.shape), _whole(bbox.shape),
            _whole(first.shape), _whole(count.shape),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),   # pool
        ],
        out_specs=tuple(pl.BlockSpec((1, 1), lambda i: (i, 0))
                        for _ in range(4)),
        out_shape=tuple(jax.ShapeDtypeStruct((n, 1), jnp.int32)
                        for _ in range(4)),
        scratch_shapes=[pltpu.VMEM((2, 4, be), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(points.astype(jnp.float32), quant.astype(jnp.float32),
      cell_lo.astype(jnp.int32), cell_hi.astype(jnp.int32),
      cell_val.astype(jnp.int32), top_start.astype(jnp.int32),
      cand.astype(jnp.int32), bbox.astype(jnp.float32),
      first.astype(jnp.int32), count.astype(jnp.int32),
      blocks.astype(jnp.float32))
    bid, flags, nrest, nskip = out
    return bid[:, 0], flags[:, 0], nrest[:, 0], nskip[:, 0]
