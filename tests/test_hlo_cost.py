"""The HLO cost walker must be exact on controlled probes — it is the
measurement layer behind §Roofline, so it gets its own tests
(EXPERIMENTS.md §Perf lesson iii)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import cost_of


def _compile(f, *shapes):
    return jax.jit(f).lower(*[jax.ShapeDtypeStruct(s, jnp.float32)
                              for s in shapes]).compile()


def test_scan_flops_multiplied_by_trip_count():
    def body(c, _):
        return c @ jnp.ones((128, 128)), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=48)
        return y

    r = cost_of(_compile(f, (128, 128)).as_text())
    want = 48 * 2 * 128 ** 3
    np.testing.assert_allclose(r["flops"], want, rtol=0.01)


def test_nested_scan_flops():
    def inner(c, _):
        return c @ jnp.ones((64, 64)), None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=8)
        return y, None

    def g(x):
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    r = cost_of(_compile(g, (64, 64)).as_text())
    np.testing.assert_allclose(r["flops"], 4 * 8 * 2 * 64 ** 3, rtol=0.01)


def test_plain_matmul_flops_and_traffic():
    def f(a, b):
        return a @ b

    c = _compile(f, (1024, 512), (512, 2048))
    r = cost_of(c.as_text())
    np.testing.assert_allclose(r["flops"], 2 * 1024 * 512 * 2048, rtol=0.01)
    # result is 1024x2048 f32 = 8 MiB -> traffic proxy counts 2x result.
    assert r["bytes"] >= 2 * 1024 * 2048 * 4


def test_ys_stacking_not_overcounted():
    """A scan stacking per-step outputs must count slices, not the whole
    stacked buffer per step (the 14x xlstm artifact)."""
    def body(c, _):
        c = c * 1.5
        return c, c

    def f(x):
        _, ys = jax.lax.scan(body, x, None, length=1024)
        return ys

    r = cost_of(_compile(f, (64, 4096)).as_text())
    stack_bytes = 1024 * 64 * 4096 * 4
    # Traffic must be O(stack) — buffer init (2x) + per-step slices (2x) +
    # per-step compute copies (~4x) — NOT O(steps * stack) = 1024x.
    assert r["bytes"] < 12 * stack_bytes, r["bytes"]
