"""GeoEngine facade: strategy agreement (simple == fast(exact) == hybrid),
hybrid accuracy ordering, the dispatch-routed sharded assign, off-extent
rejection, and fused-kernel routing (EngineConfig.fused).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fast as fast_mod
from repro.core.engine import EngineConfig, GeoEngine
from repro.launch.mesh import make_test_mesh

EXACT_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8)


@pytest.fixture(scope="module")
def engines(synth_small):
    census = synth_small.census
    simple = GeoEngine.build(census, "simple", EXACT_CFG)
    fast = GeoEngine.build(census, "fast", EXACT_CFG)
    # Reuse fast's covering so the hybrid build skips the host BFS.
    hybrid = GeoEngine.build(census, "hybrid", EXACT_CFG,
                             covering=fast.covering)
    return {"simple": simple, "fast": fast, "hybrid": hybrid}


def test_three_way_agreement_on_interior_points(engines, points_small):
    """simple == fast(exact) == hybrid on every non-boundary (true-hit)
    point; all three == ground truth there too."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    out = {name: np.asarray(eng.assign(pts).block)
           for name, eng in engines.items()}
    val = np.asarray(fast_mod.cell_values(engines["fast"].fast_index, pts))
    interior = val >= 0
    assert interior.mean() > 0.5          # the paper's true-hit majority
    np.testing.assert_array_equal(out["simple"][interior],
                                  out["fast"][interior])
    np.testing.assert_array_equal(out["fast"][interior],
                                  out["hybrid"][interior])
    np.testing.assert_array_equal(out["hybrid"][interior], bid[interior])


def test_hybrid_matches_fast_exact_everywhere_on_synth(engines,
                                                       points_small):
    """On the synthetic map generous caps make both hybrid and fast(exact)
    fully exact, so they agree on boundary points as well."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    f = engines["fast"].assign(pts)
    h = engines["hybrid"].assign(pts)
    np.testing.assert_array_equal(np.asarray(f.block), bid)
    np.testing.assert_array_equal(np.asarray(h.block), bid)
    np.testing.assert_array_equal(np.asarray(h.state), np.asarray(f.state))
    np.testing.assert_array_equal(np.asarray(h.county),
                                  np.asarray(f.county))


def test_hybrid_beats_approx_accuracy(engines, synth_small, points_small):
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    approx = GeoEngine.build(
        synth_small.census, "fast",
        EngineConfig(backend="ref", mode="approx", max_level=8),
        covering=engines["fast"].covering)
    acc_a = (np.asarray(approx.assign(pts).block) == bid).mean()
    acc_h = (np.asarray(engines["hybrid"].assign(pts).block) == bid).mean()
    assert acc_h >= acc_a


def test_assign_result_unpacks_like_legacy_tuple(engines, points_small):
    xy, *_ = points_small
    res = engines["simple"].assign(jnp.asarray(xy))
    s, c, b, stats = res
    assert np.asarray(s).shape == (len(xy),)
    assert int(stats.overflow) == 0
    assert int(stats.n_pip) > 0
    assert set(stats.extra) == {"state", "county", "block"}


def test_assign_sharded_matches_fast_exact(engines, points_small):
    """Dispatch-routed sharded lookup == single-mesh exact lookup (1-device
    mesh; conftest pins the process to one device)."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    mesh = make_test_mesh((1, 1))
    res = engines["fast"].assign_sharded(pts, mesh)
    np.testing.assert_array_equal(np.asarray(res.block), bid)
    assert int(res.stats.extra["n_dropped"]) == 0
    f = engines["fast"].assign(pts)
    np.testing.assert_array_equal(np.asarray(res.state),
                                  np.asarray(f.state))


def test_engine_validation():
    with pytest.raises(ValueError, match="unknown strategy"):
        GeoEngine("warp", EngineConfig())
    with pytest.raises(ValueError, match="needs a simple_index"):
        GeoEngine("simple", EngineConfig())
    with pytest.raises(ValueError, match="needs a fast_index"):
        GeoEngine("fast", EngineConfig())
    # The sharded plugin has no single-mesh assign; an engine built on
    # it would only fail at the first assign — reject at construction.
    with pytest.raises(ValueError, match="single-mesh"):
        GeoEngine("sharded", EngineConfig())


def test_assign_sharded_requires_model_axis(engines, points_small):
    xy, *_ = points_small
    mesh = make_test_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="model"):
        engines["fast"].assign_sharded(jnp.asarray(xy), mesh)


def _far_points(census, n_pad: int = 8):
    """Points far outside the map extent (padded so compaction caps and
    dispatch capacities stay sane)."""
    x0, x1, y0, y1 = census.extent
    w, h = x1 - x0, y1 - y0
    base = np.array([[x1 + w, (y0 + y1) / 2],       # east, clips onto border
                     [x0 - 2 * w, y0 - h],          # far southwest corner
                     [(x0 + x1) / 2, y1 + 0.5 * h],  # north
                     [x0 - 0.01 * w, (y0 + y1) / 2]], np.float32)  # grazing
    reps = int(np.ceil(n_pad / len(base)))
    return jnp.asarray(np.tile(base, (reps, 1))[:n_pad])


def test_off_extent_points_rejected_every_strategy(engines, synth_small):
    """ROADMAP extent-rejection item: quantization clips off-extent points
    onto the grid border, so without an explicit extent test a far-outside
    query lands in a border cell and gets that cell's block id.  Every
    strategy must return -1 instead — matching the simple cascade."""
    far = _far_points(synth_small.census, 64)
    for name, eng in engines.items():
        bid = np.asarray(eng.assign(far).block)
        np.testing.assert_array_equal(bid, -1, err_msg=name)


def test_off_extent_points_rejected_sharded(engines, synth_small):
    far = _far_points(synth_small.census, 64)
    mesh = make_test_mesh((1, 1))
    res = engines["fast"].assign_sharded(far, mesh)
    np.testing.assert_array_equal(np.asarray(res.block), -1)
    np.testing.assert_array_equal(np.asarray(res.state), -1)


def test_approx_mode_rejects_off_extent(engines, synth_small):
    approx = GeoEngine.build(
        synth_small.census, "fast",
        EngineConfig(backend="ref", mode="approx", max_level=8),
        covering=engines["fast"].covering)
    far = _far_points(synth_small.census, 64)
    np.testing.assert_array_equal(np.asarray(approx.assign(far).block), -1)


def test_fused_flag_matches_legacy_all_strategies(engines, synth_small,
                                                  points_small):
    """EngineConfig(fused=True) routes every strategy's candidate PIP
    through the fused gather-PIP kernel; assignments are identical."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    fused_cfg = dataclasses.replace(EXACT_CFG, fused=True)
    for name, eng in engines.items():
        feng = GeoEngine.build(synth_small.census, name, fused_cfg,
                               covering=engines["fast"].covering)
        np.testing.assert_array_equal(
            np.asarray(feng.assign(pts).block),
            np.asarray(eng.assign(pts).block), err_msg=name)


def test_fused_exact_matches_ground_truth(engines, synth_small,
                                          points_small):
    xy, bid, *_ = points_small
    fused_cfg = dataclasses.replace(EXACT_CFG, fused=True)
    eng = GeoEngine.build(synth_small.census, "fast", fused_cfg,
                          covering=engines["fast"].covering)
    np.testing.assert_array_equal(
        np.asarray(eng.assign(jnp.asarray(xy)).block), bid)


def test_fused_sharded_matches_ground_truth(engines, synth_small,
                                            points_small):
    """fused=True is honored by assign_sharded too (the pool rides the
    sharded index, replicated like block_edges)."""
    xy, bid, *_ = points_small
    fused_cfg = dataclasses.replace(EXACT_CFG, fused=True)
    eng = GeoEngine.build(synth_small.census, "fast", fused_cfg,
                          covering=engines["fast"].covering)
    res = eng.assign_sharded(jnp.asarray(xy), make_test_mesh((1, 1)))
    np.testing.assert_array_equal(np.asarray(res.block), bid)


def test_fused_without_pool_raises_at_construction(engines):
    """A fused config over a pool-less index is a *build-time* error
    (registry capability validation) — it must never survive to the
    first assign as a trace-time surprise."""
    with pytest.raises(ValueError, match="with_pool"):
        GeoEngine("fast", dataclasses.replace(EXACT_CFG, fused=True),
                  fast_index=engines["fast"].fast_index)
    # approx mode never PIPs, so fused needs no pool there.
    GeoEngine("fast",
              dataclasses.replace(EXACT_CFG, fused=True, mode="approx"),
              fast_index=engines["fast"].fast_index)


def test_third_party_strategy_registers_without_engine_changes(
        engines, points_small):
    """The registry is the engine's whole dispatch surface: a strategy
    registered from outside core/ builds, validates, and assigns through
    the unchanged GeoEngine."""
    from repro.core.registry import (Strategy, available_strategies,
                                     register_strategy)
    from repro.core.resolve import AssignResult, GeoStats

    @register_strategy("centre-owner", needs=("fast",))
    class CentreOwner(Strategy):
        def assign(self, indices, points, cfg):
            fcfg = dataclasses.replace(cfg.fast_cfg(), mode="approx")
            sid, cid, bid, st = fast_mod.assign_fast(indices.fast,
                                                     points, fcfg)
            return AssignResult(sid, cid, bid, GeoStats(
                n_need=st["n_boundary"], n_pip=st["n_pip"],
                overflow=st["overflow"], extra=st))

    assert "centre-owner" in available_strategies()
    xy, *_ = points_small
    eng = GeoEngine("centre-owner", EXACT_CFG,
                    fast_index=engines["fast"].fast_index)
    approx = GeoEngine("fast",
                       dataclasses.replace(EXACT_CFG, mode="approx"),
                       fast_index=engines["fast"].fast_index)
    np.testing.assert_array_equal(
        np.asarray(eng.assign(jnp.asarray(xy)).block),
        np.asarray(approx.assign(jnp.asarray(xy)).block))
