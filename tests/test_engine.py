"""GeoEngine facade: strategy agreement (simple == fast(exact) == hybrid),
hybrid accuracy ordering, and the dispatch-routed sharded assign.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fast as fast_mod
from repro.core.engine import EngineConfig, GeoEngine
from repro.launch.mesh import make_test_mesh

EXACT_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8)


@pytest.fixture(scope="module")
def engines(synth_small):
    census = synth_small.census
    simple = GeoEngine.build(census, "simple", EXACT_CFG)
    fast = GeoEngine.build(census, "fast", EXACT_CFG)
    # Reuse fast's covering so the hybrid build skips the host BFS.
    hybrid = GeoEngine.build(census, "hybrid", EXACT_CFG,
                             covering=fast.covering)
    return {"simple": simple, "fast": fast, "hybrid": hybrid}


def test_three_way_agreement_on_interior_points(engines, points_small):
    """simple == fast(exact) == hybrid on every non-boundary (true-hit)
    point; all three == ground truth there too."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    out = {name: np.asarray(eng.assign(pts).block)
           for name, eng in engines.items()}
    val = np.asarray(fast_mod.cell_values(engines["fast"].fast_index, pts))
    interior = val >= 0
    assert interior.mean() > 0.5          # the paper's true-hit majority
    np.testing.assert_array_equal(out["simple"][interior],
                                  out["fast"][interior])
    np.testing.assert_array_equal(out["fast"][interior],
                                  out["hybrid"][interior])
    np.testing.assert_array_equal(out["hybrid"][interior], bid[interior])


def test_hybrid_matches_fast_exact_everywhere_on_synth(engines,
                                                       points_small):
    """On the synthetic map generous caps make both hybrid and fast(exact)
    fully exact, so they agree on boundary points as well."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    f = engines["fast"].assign(pts)
    h = engines["hybrid"].assign(pts)
    np.testing.assert_array_equal(np.asarray(f.block), bid)
    np.testing.assert_array_equal(np.asarray(h.block), bid)
    np.testing.assert_array_equal(np.asarray(h.state), np.asarray(f.state))
    np.testing.assert_array_equal(np.asarray(h.county),
                                  np.asarray(f.county))


def test_hybrid_beats_approx_accuracy(engines, synth_small, points_small):
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    approx = GeoEngine.build(
        synth_small.census, "fast",
        EngineConfig(backend="ref", mode="approx", max_level=8),
        covering=engines["fast"].covering)
    acc_a = (np.asarray(approx.assign(pts).block) == bid).mean()
    acc_h = (np.asarray(engines["hybrid"].assign(pts).block) == bid).mean()
    assert acc_h >= acc_a


def test_assign_result_unpacks_like_legacy_tuple(engines, points_small):
    xy, *_ = points_small
    res = engines["simple"].assign(jnp.asarray(xy))
    s, c, b, stats = res
    assert np.asarray(s).shape == (len(xy),)
    assert int(stats.overflow) == 0
    assert int(stats.n_pip) > 0
    assert set(stats.extra) == {"state", "county", "block"}


def test_assign_sharded_matches_fast_exact(engines, points_small):
    """Dispatch-routed sharded lookup == single-mesh exact lookup (1-device
    mesh; conftest pins the process to one device)."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    mesh = make_test_mesh((1, 1))
    res = engines["fast"].assign_sharded(pts, mesh)
    np.testing.assert_array_equal(np.asarray(res.block), bid)
    assert int(res.stats.extra["n_dropped"]) == 0
    f = engines["fast"].assign(pts)
    np.testing.assert_array_equal(np.asarray(res.state),
                                  np.asarray(f.state))


def test_engine_validation():
    with pytest.raises(ValueError, match="unknown strategy"):
        GeoEngine("warp", EngineConfig())
    with pytest.raises(ValueError, match="needs a simple_index"):
        GeoEngine("simple", EngineConfig())
    with pytest.raises(ValueError, match="needs a fast_index"):
        GeoEngine("fast", EngineConfig())


def test_assign_sharded_requires_model_axis(engines, points_small):
    xy, *_ = points_small
    mesh = make_test_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="model"):
        engines["fast"].assign_sharded(jnp.asarray(xy), mesh)
