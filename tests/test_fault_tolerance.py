"""Fault-tolerance: checkpoint atomicity, bitwise restart, failure
injection, elastic re-sharding, deterministic data pipeline.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced_config
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.models.module import init_params
from repro.optim import adamw
from repro.runtime.driver import DriverConfig, train_loop
from repro.runtime.steps import make_train_step

RUN = RunConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32,
                ssm_chunk=16, learning_rate=1e-3, warmup_steps=2,
                total_steps=100)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.key(0))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(model, RUN))
    src = SyntheticLM(cfg=cfg, batch=4, seq=32, seed=3)
    return cfg, model, params, opt, step, src


def test_pipeline_is_stateless_and_deterministic(setup):
    *_, src = setup
    b1 = src.batch_at(17)
    b2 = src.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = src.batch_at(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_checkpoint_roundtrip_and_gc(tmp_path, setup):
    _, _, params, opt, *_ = setup
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"params": params, "opt": opt}
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]      # GC keeps 2
    back = mgr.restore(30, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_partial_checkpoint_visible(tmp_path, setup):
    _, _, params, opt, *_ = setup
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(5, {"params": params, "opt": opt})
    mgr.wait()
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    assert mgr.latest_step() == 5


def test_restart_is_bitwise_identical(tmp_path, setup):
    """Crash at step 7, restart from ckpt-5 -> same params as no-crash."""
    cfg, model, params0, opt0, step, src = setup
    d1 = DriverConfig(total_steps=10, ckpt_every=5,
                      ckpt_dir=str(tmp_path / "a"), log_every=100)
    p1, o1, h1 = train_loop(step, params0, opt0, src, d1,
                            log=lambda *_: None)
    d2 = DriverConfig(total_steps=10, ckpt_every=5,
                      ckpt_dir=str(tmp_path / "b"), log_every=100)
    p2, o2, h2 = train_loop(step, params0, opt0, src, d2,
                            fail_at={7}, log=lambda *_: None)
    assert h2["restarts"] == 1
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o1.step) == int(o2.step) == 10


def test_max_restarts_bounds_crash_loop(tmp_path, setup):
    cfg, model, params0, opt0, step, src = setup
    d = DriverConfig(total_steps=6, ckpt_every=100,
                     ckpt_dir=str(tmp_path / "c"), max_restarts=2,
                     log_every=100)
    # Failing every run of step 3 (no checkpoint in between, restart to 0,
    # injected failure fires once -> recovery succeeds with 1 restart).
    p, o, h = train_loop(step, params0, opt0, src, d, fail_at={3},
                         log=lambda *_: None)
    assert h["restarts"] == 1 and int(o.step) == 6


def test_elastic_restore_onto_new_mesh(tmp_path):
    """Save on 1 device, restore onto 8 fake devices with a (2,4) mesh and
    FSDP+TP shardings, then onto (4,2) — elastic re-scaling is a restore
    with new shardings, no format change (runs in a subprocess because the
    device count is locked at jax init)."""
    from subproc import assert_subprocess_ok
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced_config
from repro.launch.mesh import make_test_mesh
from repro.models.model import build_model
from repro.models.module import init_params
from repro.sharding.rules import param_shardings

cfg = get_reduced_config("qwen1.5-0.5b")
model = build_model(cfg)
params = init_params(model.specs, jax.random.key(0))
mgr = CheckpointManager({str(tmp_path)!r}, async_save=False)
mgr.save(1, {{"params": params}})
host = jax.tree.map(np.asarray, params)
for shape in ((2, 4), (4, 2)):
    mesh = make_test_mesh(shape)
    sh = param_shardings(model.specs, mesh)
    back = mgr.restore(1, {{"params": params}}, {{"params": sh}})
    for a, b, s in zip(jax.tree.leaves(host), jax.tree.leaves(back["params"]),
                       jax.tree.leaves(sh)):
        np.testing.assert_array_equal(a, np.asarray(b))
        assert b.sharding == s, (b.sharding, s)
print("ELASTIC_OK")
"""
    assert_subprocess_ok(code, "ELASTIC_OK")


def test_restore_without_shardings_preserves_mesh_placement(tmp_path):
    """The driver's crash-restore path passes ``shardings=None``; restore
    must put arrays back onto the like-tree's own committed shardings
    (FSDP layout survives a restart), not concentrate them on the default
    device."""
    from subproc import assert_subprocess_ok
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4))
sh = NamedSharding(mesh, PartitionSpec("data", None))
x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8), sh)
mgr = CheckpointManager({str(tmp_path)!r}, async_save=False)
mgr.save(3, {{"params": {{"w": x}}}})
back = mgr.restore(3, {{"params": {{"w": x}}}})   # no shardings argument
w = back["params"]["w"]
assert w.sharding.shard_shape(w.shape) == (4, 8), w.sharding
np.testing.assert_array_equal(np.asarray(w), np.asarray(x))
print("RESTORE_SHARDING_OK")
"""
    assert_subprocess_ok(code, "RESTORE_SHARDING_OK")
