"""Data pipeline + geo enrichment integration."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core.cells import build_cell_covering
from repro.core.enrich import enrich
from repro.core.fast import FastConfig, FastIndex
from repro.data.pipeline import GeoEnriched, SyntheticLM


def test_enrich_operator(synth_small):
    cov = build_cell_covering(synth_small.census, max_level=8)
    idx = FastIndex.from_covering(cov, synth_small.census, gbits=4)
    rng = np.random.default_rng(3)
    xy, bid, cid, sid = synth_small.sample_points(rng, 2048)
    out = enrich(idx, jnp.asarray(xy), FastConfig(mode="exact",
                                                  cap_boundary=1.0,
                                                  backend="ref"))
    np.testing.assert_array_equal(np.asarray(out["block"]), bid)
    np.testing.assert_array_equal(np.asarray(out["state"]), sid)
    ft = np.asarray(out["feature_token"])
    assert ((0 <= ft) & (ft <= 1024)).all()


def test_geo_enriched_pipeline_deterministic(synth_small):
    cov = build_cell_covering(synth_small.census, max_level=8)
    idx = FastIndex.from_covering(cov, synth_small.census, gbits=4)
    cfg = get_reduced_config("qwen1.5-0.5b")
    src = GeoEnriched(source=SyntheticLM(cfg=cfg, batch=4, seq=32, seed=1),
                      fast_index=idx, fast_cfg=FastConfig(mode="approx"))
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    np.testing.assert_array_equal(np.asarray(a["geo_block"]),
                                  np.asarray(b["geo_block"]))
    # Enrichment actually joined: most sampled points land in a block.
    assert (np.asarray(a["geo_block"]) >= 0).mean() > 0.5
