"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward and one decode step on CPU, asserting shapes and no NaNs.
Full configs are exercised only by the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.configs.base import RunConfig, shapes_for
from repro.models.model import build_model, input_specs
from repro.models.module import init_params

RUN = RunConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32,
                ssm_chunk=16)
B, S = 2, 32


def _batch(cfg):
    batch = {"tokens": jnp.full((B, S), 3, jnp.int32)}
    if cfg.family == "vlm":
        batch["img"] = jnp.ones((B, cfg.n_img_tokens, cfg.d_vision),
                                jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_forward_and_decode(name):
    cfg = get_reduced_config(name)
    m = build_model(cfg)
    params = init_params(m.specs, jax.random.key(0))
    logits, aux = m.forward(params, RUN, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    cache = m.init_cache(B, 64)
    for _ in range(2):
        lg, cache = m.decode_step(params, RUN,
                                  jnp.full((B, 1), 3, jnp.int32), cache)
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert int(cache["pos"]) == 2


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the exact published hyperparameters."""
    cfg = get_config(name)
    expect = {
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (name, got, expect)
    if name == "deepseek-v2-236b":
        assert cfg.mla and cfg.kv_lora == 512
        assert cfg.n_experts == 160 and cfg.top_k == 6
        assert cfg.n_shared_experts == 2
    if name == "mixtral-8x7b":
        assert cfg.n_experts == 8 and cfg.top_k == 2
        assert cfg.sliding_window == 4096
    if name == "zamba2-1.2b":
        assert cfg.ssm_state == 64
    # long_500k applicability (DESIGN.md §4).
    subq = name in ("zamba2-1.2b", "xlstm-1.3b", "mixtral-8x7b")
    assert cfg.subquadratic == subq
    n_shapes = 4 if subq else 3
    assert len(shapes_for(cfg)) == n_shapes


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_input_specs_no_allocation(name):
    cfg = get_reduced_config(name)
    m = build_model(cfg)
    for shape in shapes_for(cfg):
        small = type(shape)(shape.name, 64, 2, shape.kind)
        specs = input_specs(cfg, small, model=m)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
