"""GeoServer serving subsystem (DESIGN.md §10): bucket-ladder batching,
padded-assign stats purity, hot-cell cache exactness, bit-identity with
direct GeoEngine.assign, backpressure, metrics schema, and multi-region
routing edge cases.
"""
import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, GeoEngine
from repro.core.resolve import ResolveStats
from repro.core.synth import build_synth_census
from repro.serving import (GeoServer, MicroBatcher, QueueFull, ServeConfig,
                           bucket_for)

EXACT_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8)
FUSED_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8,
                         fused=True)
BUCKETS = (64, 256, 1024)
# Mixed request sizes exercising every bucket, splits, and coalescing.
STREAM = (1, 7, 300, 555, 1024, 113)


@pytest.fixture(scope="module")
def engines(synth_small):
    census = synth_small.census
    fast = GeoEngine.build(census, "fast", FUSED_CFG)
    return {
        "simple": GeoEngine.build(census, "simple", EXACT_CFG),
        "fast_fused": fast,
        "hybrid": GeoEngine.build(census, "hybrid", EXACT_CFG,
                                  covering=fast.covering),
    }


def _serve_stream(server, xy):
    off, outs = 0, []
    for n in STREAM:
        res = server.submit(xy[off:off + n])
        outs.append(res)
        off += n
    return off, outs


# -- batcher unit tests ------------------------------------------------------

def test_bucket_for_ladder():
    assert bucket_for(1, BUCKETS) == 64
    assert bucket_for(64, BUCKETS) == 64
    assert bucket_for(65, BUCKETS) == 256
    assert bucket_for(1024, BUCKETS) == 1024
    assert bucket_for(5000, BUCKETS) == 1024      # oversize -> top (split)


def test_batcher_coalesces_fifo_and_splits():
    b = MicroBatcher(buckets=BUCKETS, max_queue_points=1 << 16)
    sizes = (10, 50, 1100, 30)                    # 1100 must split
    for i, n in enumerate(sizes):
        pts = np.full((n, 2), float(i), np.float32)
        assert b.put(f"t{i}", pts)
    batches = b.drain()
    assert b.queued_points == 0 and len(b) == 0
    # Unpadded coalesced batches, capped at the top bucket (padding
    # happens at the device edge — see batcher.py docstring).
    assert [len(mb.points) for mb in batches] == [1024, 166]
    # FIFO order and request-side offsets survive the split.
    flat = [(t, ro, ln) for mb in batches for (t, ro, _, ln) in mb.parts]
    assert flat == [("t0", 0, 10), ("t1", 0, 50), ("t2", 0, 964),
                    ("t2", 964, 136), ("t3", 0, 30)]


def test_batcher_validation():
    with pytest.raises(ValueError, match="buckets"):
        MicroBatcher(buckets=(256, 64))
    with pytest.raises(ValueError, match="policy"):
        MicroBatcher(policy="drop")


def test_batcher_oldest_age_lifecycle():
    """The deadline clock arms on the first put, survives further puts,
    and clears on drain (requeue re-arms it)."""
    b = MicroBatcher(buckets=BUCKETS)
    assert b.oldest_age_s() == 0.0
    b.put("t0", np.zeros((4, 2), np.float32))
    time.sleep(0.002)
    age = b.oldest_age_s()
    assert age > 0.0
    b.put("t1", np.zeros((4, 2), np.float32))
    assert b.oldest_age_s() >= age            # later put can't reset it
    b.drain()
    assert b.oldest_age_s() == 0.0
    b.requeue([("t0", np.zeros((4, 2), np.float32), 0)])
    assert b.oldest_age_s() >= 0.0 and len(b) == 1


# -- deadline flush (ServeConfig.max_delay_ms) -------------------------------

def test_deadline_flush_on_enqueue(engines, points_small):
    """With a zero deadline, every arrival finds the oldest request
    overdue: enqueue itself flushes, no submit needed, and the flush is
    counted as deadline-triggered."""
    xy, *_ = points_small
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=False,
                                   max_delay_ms=0.0))
    ticket = server.enqueue(xy[:37])
    assert ticket.done
    snap = server.snapshot()
    assert snap["counters"]["deadline_flushes"] >= 1
    direct = engines["fast_fused"].assign(jnp.asarray(xy[:37]))
    np.testing.assert_array_equal(ticket.result().block,
                                  np.asarray(direct.block))


def test_deadline_poll_serves_stranded_trickle(engines, points_small):
    """A lone queued request past its deadline is served by poll() —
    the timer path an async front-end drives in idle gaps."""
    xy, *_ = points_small
    # Deadline far above scheduling jitter so the not-due assertion
    # can't flake on a loaded machine.
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=False,
                                   max_delay_ms=200.0))
    ticket = server.enqueue(xy[:3])           # young: enqueue won't flush
    assert not ticket.done
    assert server.poll() == 0                 # not due yet
    time.sleep(0.25)
    assert server.poll() == 1                 # overdue: one micro-batch
    assert ticket.done
    assert server.snapshot()["counters"]["deadline_flushes"] == 1


def test_no_deadline_means_no_arrival_flush(engines, points_small):
    xy, *_ = points_small
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=False))
    ticket = server.enqueue(xy[:5])
    assert not ticket.done and server.poll() == 0
    server.flush()
    assert ticket.done


# -- padded assign: stats purity (satellite) ---------------------------------

@pytest.mark.parametrize("name", ["simple", "fast_fused", "hybrid"])
def test_assign_padded_stats_pure_and_pads_minus_one(engines, points_small,
                                                     name):
    """Trailing pad rows must come back -1 in all three id arrays and
    must not perturb a single GeoStats counter vs the unpadded call."""
    eng = engines[name]
    xy, *_ = points_small
    n = 2000
    direct = eng.assign(jnp.asarray(xy[:n]))
    padded = np.zeros((2048, 2), np.float32)
    padded[:n] = xy[:n]
    res = eng.assign_padded(jnp.asarray(padded), n)
    for field in ("state", "county", "block"):
        got = np.asarray(getattr(res, field))
        np.testing.assert_array_equal(got[:n],
                                      np.asarray(getattr(direct, field)))
        np.testing.assert_array_equal(got[n:], -1)
    assert res.stats.as_dict() == direct.stats.as_dict()


def test_assign_padded_full_batch_is_identity(engines, points_small):
    eng = engines["fast_fused"]
    xy, *_ = points_small
    direct = eng.assign(jnp.asarray(xy))
    res = eng.assign_padded(jnp.asarray(xy), len(xy))
    np.testing.assert_array_equal(np.asarray(res.block),
                                  np.asarray(direct.block))
    assert res.stats.as_dict() == direct.stats.as_dict()


# -- serving bit-identity ----------------------------------------------------

@pytest.mark.parametrize("name", ["simple", "fast_fused", "hybrid"])
@pytest.mark.parametrize("cache", [False, True])
def test_server_bit_identical_to_direct_assign(engines, points_small, name,
                                               cache):
    """Mixed-size request streams through the server == direct
    GeoEngine.assign on the same points, cache on and off; a second
    pass (cache warm) stays identical."""
    eng = engines[name]
    xy, *_ = points_small
    direct = eng.assign(jnp.asarray(xy))
    server = GeoServer(eng, ServeConfig(buckets=BUCKETS, cache=cache))
    server.warm()
    off, outs = _serve_stream(server, xy)
    for field in ("state", "county", "block"):
        got = np.concatenate([np.asarray(getattr(r, field)) for r in outs])
        np.testing.assert_array_equal(
            got, np.asarray(getattr(direct, field))[:off], err_msg=name)
    res2 = server.submit(xy[:off])
    np.testing.assert_array_equal(res2.block,
                                  np.asarray(direct.block)[:off])
    if cache:
        snap = server.cache_snapshot()
        assert snap["hits"] > 0
        assert snap["hit_rate"] > 0


def test_server_preserves_partial_assignments(engines, synth_small):
    """The simple cascade can resolve a point's state yet lose it at the
    county/block level (bbox gaps on uniform traffic); serving must
    return that partial answer bit-identically — state/county come from
    the engine for miss rows, never a re-derivation from block == -1."""
    x0, x1, y0, y1 = synth_small.census.extent
    rng = np.random.default_rng(9)
    pts = np.stack([rng.uniform(x0, x1, 3000),
                    rng.uniform(y0, y1, 3000)], -1).astype(np.float32)
    eng = engines["simple"]
    direct = eng.assign(jnp.asarray(pts))
    partial = ((np.asarray(direct.state) >= 0)
               & (np.asarray(direct.block) < 0))
    assert partial.any()            # the scenario exists on this traffic
    for cache in (False, True):
        server = GeoServer(eng, ServeConfig(buckets=BUCKETS, cache=cache))
        res = server.submit(pts)
        for field in ("state", "county", "block"):
            np.testing.assert_array_equal(
                getattr(res, field),
                np.asarray(getattr(direct, field)), err_msg=field)


def test_flush_requeues_unserved_work_on_engine_error(engines,
                                                      points_small,
                                                      monkeypatch):
    """A flush that dies mid-serve must not lose drained requests: the
    failed batch requeues, the exception propagates, and a later flush
    serves everything."""
    xy, *_ = points_small
    eng = engines["fast_fused"]
    server = GeoServer(eng, ServeConfig(buckets=BUCKETS, cache=False))
    ticket = server.enqueue(xy[:100])
    monkeypatch.setattr(
        eng, "assign_padded",
        lambda points, n_valid: (_ for _ in ()).throw(
            RuntimeError("device lost")))
    with pytest.raises(RuntimeError, match="device lost"):
        server.flush()
    assert not ticket.done
    assert server.batcher.queued_points == 100
    assert server.snapshot()["counters"]["failed_flushes"] == 1
    monkeypatch.undo()
    server.flush()
    assert ticket.done
    np.testing.assert_array_equal(
        ticket.result().block,
        np.asarray(eng.assign(jnp.asarray(xy[:100])).block))


def test_server_stats_merge_across_microbatches(engines, points_small):
    """The server's running GeoStats (merged per micro-batch) totals the
    same counters as one direct assign over the served points."""
    eng = engines["fast_fused"]
    xy, *_ = points_small
    server = GeoServer(eng, ServeConfig(buckets=BUCKETS, cache=False))
    off, _ = _serve_stream(server, xy)
    merged = server.stats[0].as_dict()
    direct = eng.assign(jnp.asarray(xy[:off])).stats.as_dict()
    # Micro-batching changes how work is batched, not how much: the
    # boundary count is batching-invariant (and with full caps so is
    # everything that feeds it).
    assert merged["n_boundary"] == direct["n_boundary"]
    assert merged["overflow"] == direct["overflow"] == 0
    assert merged["phase2_miss"] == direct["phase2_miss"]


def test_resolve_stats_merge_counters():
    """ResolveStats.merge sums every counter (the micro-batch
    aggregation contract, same as GeoStats.merge above)."""
    a = ResolveStats(n_need=1, n_pip=2, overflow=3, phase2_miss=4)
    b = ResolveStats(n_need=10, n_pip=20, overflow=30, phase2_miss=40)
    assert a.merge(b).as_dict() == {"n_need": 11, "n_pip": 22,
                                    "overflow": 33, "phase2_miss": 44}


# -- hot-cell cache ----------------------------------------------------------

def test_cache_learns_only_interior_cells(engines, points_small):
    xy, *_ = points_small
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=True))
    server.submit(xy[:1000])
    cache = server.regions[0].cache
    assert len(cache) > 0
    codes = np.fromiter(cache._map.keys(), np.int64)
    vals = np.fromiter(cache._map.values(), np.int64)
    safe = cache.table.interior_value(codes.astype(np.int32))
    np.testing.assert_array_equal(safe, vals)     # all interior, all exact
    assert np.all(vals >= 0)


def test_cache_eviction_bounds_entries(engines, points_small):
    xy, *_ = points_small
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=True,
                                   cache_capacity=8))
    server.submit(xy[:1000])
    cache = server.regions[0].cache
    assert len(cache) <= 8
    assert cache.evictions > 0
    snap = server.snapshot()
    # Cache absolutes are gauges (the cache owns them; a clear would
    # rewind a counter) — see metrics.observe_cache.
    assert snap["gauges"]["cache_evictions"] == cache.evictions


def test_off_extent_points_not_cached_and_serve_minus_one(engines,
                                                          synth_small):
    x0, x1, y0, y1 = synth_small.census.extent
    w, h = x1 - x0, y1 - y0
    far = np.array([[x1 + w, (y0 + y1) / 2], [x0 - 2 * w, y0 - h]],
                   np.float32)
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=True))
    for _ in range(2):                            # second pass: still miss
        res = server.submit(far)
        np.testing.assert_array_equal(res.block, -1)
        np.testing.assert_array_equal(res.state, -1)
        # region == -1 means "in no region's extent" for single-region
        # servers too (uniform ServeResult contract).
        np.testing.assert_array_equal(res.region, -1)
    assert len(server.regions[0].cache) == 0
    assert server.cache_snapshot()["hits"] == 0


# -- backpressure ------------------------------------------------------------

def test_backpressure_shed_raises_queue_full(engines, points_small):
    xy, *_ = points_small
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, max_queue_points=100,
                                   policy="shed", cache=False))
    server.enqueue(xy[:80])
    with pytest.raises(QueueFull):
        server.enqueue(xy[80:160])
    assert server.snapshot()["counters"]["shed_requests"] == 1
    server.flush()                                # first request survives
    res = server.submit(xy[:10])                  # and serving continues
    assert len(res.block) == 10


def test_backpressure_block_flushes_inline(engines, points_small):
    xy, *_ = points_small
    eng = engines["fast_fused"]
    server = GeoServer(eng, ServeConfig(buckets=BUCKETS,
                                        max_queue_points=100,
                                        policy="block", cache=False))
    t1 = server.enqueue(xy[:80])
    t2 = server.enqueue(xy[80:160])               # overflow -> inline flush
    assert t1.done                                # first batch was served
    server.flush()
    direct = np.asarray(eng.assign(jnp.asarray(xy[:160])).block)
    np.testing.assert_array_equal(
        np.concatenate([t1.result().block, t2.result().block]), direct)


# -- metrics -----------------------------------------------------------------

def test_metrics_snapshot_schema_and_json(engines, points_small):
    xy, *_ = points_small
    server = GeoServer(engines["hybrid"],
                       ServeConfig(buckets=BUCKETS, cache=True))
    server.warm()
    _serve_stream(server, xy)
    # The bare registry is already fresh after a flush (cache counters
    # are pushed, not pulled) — metrics.to_json() alone must be accurate.
    raw = server.metrics.snapshot()
    assert raw["gauges"]["cache_misses"] > 0      # absolutes live in gauges
    snap = server.snapshot()
    c, d = snap["counters"], snap["derived"]
    assert c["requests"] == len(STREAM)
    assert c["points_in"] == c["points_served"] == sum(STREAM)
    for key in ("geo_phase2_miss", "geo_overflow", "geo_n_boundary",
                "geo_n_pip", "cache_hits_total", "cache_misses_total",
                "batches", "padded_slots", "valid_slots"):
        assert key in c, key
    for key in ("cache_hits", "cache_misses", "cache_evictions"):
        assert key in snap["gauges"], key
    # The serving-side monotonic twins count per-point traffic; the
    # cache's own absolutes (gauges) count deduplicated probes — so
    # traffic >= probes, and both are positive here.
    assert c["cache_hits_total"] >= snap["gauges"]["cache_hits"] > 0
    assert c["cache_misses_total"] >= snap["gauges"]["cache_misses"] > 0
    for key in ("cache_hit_rate", "batch_fill_ratio", "boundary_fraction",
                "pip_per_point"):
        assert key in d, key
    assert 0 < d["batch_fill_ratio"] <= 1
    lat = snap["latency_ms"]
    assert lat["count_total"] == lat["count_window"] == len(STREAM)
    assert 0 <= lat["p50"] <= lat["p99"] <= lat["max"]
    assert snap["gauges"]["queue_depth_points"] == 0
    for stage in ("queue_wait", "host_prepare", "device_assign", "merge",
                  "request"):
        assert snap["stages"][stage]["count"] > 0, stage
    json.loads(server.metrics.to_json())          # JSON-renderable


def test_warm_compiles_every_bucket(engines):
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=False))
    times = server.warm()
    assert set(times) == set(BUCKETS)
    assert all(t >= 0 for t in times.values())


def test_empty_flush_and_empty_request(engines):
    server = GeoServer(engines["fast_fused"],
                       ServeConfig(buckets=BUCKETS, cache=False))
    assert server.flush() == 0                    # empty queue: no-op
    res = server.submit(np.empty((0, 2), np.float32))
    assert res.block.shape == (0,)
    assert res.latency_s == 0.0
    assert server.flush() == 0


# -- multi-region routing ----------------------------------------------------

@pytest.fixture(scope="module")
def two_regions():
    """Two regional censuses with extents sharing the x = -100 border."""
    scA = build_synth_census(seed=3, n_states=2, counties_per_state=2,
                             blocks_per_county=4,
                             extent=(-120.0, -100.0, 30.0, 45.0))
    scB = build_synth_census(seed=4, n_states=2, counties_per_state=2,
                             blocks_per_county=4,
                             extent=(-100.0, -80.0, 30.0, 45.0))
    cfg = EngineConfig(backend="ref", cap_boundary=1.0, max_level=8)
    return (scA, GeoEngine.build(scA.census, "fast", cfg),
            scB, GeoEngine.build(scB.census, "fast", cfg))


def test_router_merges_regions_in_input_order(two_regions):
    scA, engA, scB, engB = two_regions
    server = GeoServer([engA, engB],
                       ServeConfig(buckets=BUCKETS, cache=False))
    xyA, bidA, *_ = scA.sample_points(np.random.default_rng(1), 100)
    xyB, bidB, *_ = scB.sample_points(np.random.default_rng(2), 100)
    inter = np.empty((200, 2), np.float32)        # interleave A/B points
    inter[0::2], inter[1::2] = xyA, xyB
    res = server.submit(inter)
    np.testing.assert_array_equal(res.region[0::2], 0)
    np.testing.assert_array_equal(res.region[1::2], 1)
    np.testing.assert_array_equal(
        res.block[0::2], np.asarray(engA.assign(jnp.asarray(xyA)).block))
    np.testing.assert_array_equal(
        res.block[1::2], np.asarray(engB.assign(jnp.asarray(xyB)).block))


def test_router_point_in_no_region_is_minus_one(two_regions):
    _, engA, _, engB = two_regions
    server = GeoServer([engA, engB],
                       ServeConfig(buckets=BUCKETS, cache=False))
    nowhere = np.array([[-150.0, 37.0], [0.0, 0.0], [-90.0, 70.0]],
                       np.float32)
    res = server.submit(nowhere)
    np.testing.assert_array_equal(res.block, -1)
    np.testing.assert_array_equal(res.state, -1)
    np.testing.assert_array_equal(res.region, -1)


def test_router_shared_border_deterministic_single_owner(two_regions):
    """A point on the shared extent border gets exactly one owner, the
    same one on every submit, and the result equals that region's own
    direct assign."""
    _, engA, _, engB = two_regions
    server = GeoServer([engA, engB],
                       ServeConfig(buckets=BUCKETS, cache=False))
    border = np.array([[-100.0, 37.5], [-100.0, 33.0]], np.float32)
    first = server.submit(border)
    assert np.all(first.region >= 0)              # someone owns it
    assert len(np.unique(first.region)) == 1      # exactly one region
    for _ in range(3):
        again = server.submit(border)
        np.testing.assert_array_equal(again.region, first.region)
        np.testing.assert_array_equal(again.block, first.block)
    owner = [engA, engB][int(first.region[0])]
    np.testing.assert_array_equal(
        first.block, np.asarray(owner.assign(jnp.asarray(border)).block))


def test_router_overlapping_extents_first_region_wins(two_regions):
    """With overlapping extents the list order is the deterministic
    tiebreak: region 0 owns the overlap."""
    scA, engA, scB, engB = two_regions
    xyA, *_ = scA.sample_points(np.random.default_rng(5), 50)
    server = GeoServer([engA, engA],              # total overlap
                       ServeConfig(buckets=BUCKETS, cache=False))
    res = server.submit(xyA)
    np.testing.assert_array_equal(res.region, 0)


def test_router_empty_flush_multi_region(two_regions):
    _, engA, _, engB = two_regions
    server = GeoServer([engA, engB],
                       ServeConfig(buckets=BUCKETS, cache=False))
    assert server.flush() == 0
    res = server.submit(np.empty((0, 2), np.float32))
    assert res.block.shape == (0,)
