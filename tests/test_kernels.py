"""Per-kernel validation: Pallas (interpret=True) vs the ref.py oracle,
sweeping shapes and dtypes, plus fp64 host-oracle ground truth and
hypothesis property tests on the crossing-number geometry.

The property tests require ``hypothesis``; without it they are not
collected and a single placeholder skip reports their absence.  The
oracle/shape tests always run (the interpret backend works on CPU).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:          # pragma: no cover - CI image has no hypothesis
    hypothesis = st = None

from repro.core.geometry import point_in_polygon_host
from repro.kernels import ops, ref

if hypothesis is not None:
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.load_profile("ci")


def star_polygon(rng, n_verts, cx=0.0, cy=0.0, r0=0.5, r1=1.5):
    """Random star-shaped (hence simple) polygon with n_verts vertices."""
    th = np.sort(rng.uniform(0, 2 * np.pi, n_verts))
    # Ensure distinct angles.
    th += np.arange(n_verts) * 1e-9
    r = rng.uniform(r0, r1, n_verts)
    return np.stack([cx + r * np.cos(th), cy + r * np.sin(th)], -1)


def ring_to_edges(ring):
    nxt = np.roll(ring, -1, axis=0)
    return np.concatenate([ring, nxt], axis=-1).astype(np.float32)


# ---------------------------------------------------------------- pip_one
@pytest.mark.parametrize("n_pts", [7, 256, 1000])
@pytest.mark.parametrize("n_verts", [3, 17, 600])
def test_pip_one_shapes(n_pts, n_verts):
    rng = np.random.default_rng(n_pts * 1000 + n_verts)
    ring = star_polygon(rng, n_verts)
    pts = rng.uniform(-2, 2, (n_pts, 2)).astype(np.float32)
    edges = ring_to_edges(ring)
    want = np.asarray(ref.pip_one(jnp.asarray(pts), jnp.asarray(edges)))
    got = np.asarray(ops.pip_one(jnp.asarray(pts), jnp.asarray(edges),
                                 backend="interpret"))
    np.testing.assert_array_equal(got, want)
    # fp64 host oracle (points are generic, nowhere near edges w.p. 1).
    host = point_in_polygon_host(pts[:, 0], pts[:, 1], ring)
    assert (got == host).mean() > 0.999


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("n_pts,n_edges", [(64, 40), (300, 513)])
def test_pip_gathered_matches_ref(n_pts, n_edges, dtype):
    rng = np.random.default_rng(0)
    pts = rng.uniform(-2, 2, (n_pts, 2)).astype(dtype)
    # Each point gets its own random star polygon, padded with degenerate
    # (zero-length) edges like production edge tables.
    edges = np.zeros((n_pts, n_edges, 4), dtype)
    for i in range(n_pts):
        nv = int(rng.integers(3, min(n_edges, 12) + 1))
        e = ring_to_edges(star_polygon(rng, nv))
        edges[i, :nv] = e
        edges[i, nv:] = e[0, 0:1].repeat(4)[None, :] * 0 + np.array(
            [e[0, 0], e[0, 1], e[0, 0], e[0, 1]], dtype)
    want = np.asarray(ref.pip_gathered(jnp.asarray(pts), jnp.asarray(edges)))
    got = np.asarray(ops.pip_gathered(jnp.asarray(pts), jnp.asarray(edges),
                                      backend="interpret"))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------- fused gather-PIP (CSR)
@pytest.fixture(scope="module")
def ragged_world():
    """Ragged polygons (one spanning multiple 128-edge pool blocks), their
    dense [P, E, 4] table, and a be=128 EdgePool built from it."""
    rng = np.random.default_rng(11)
    nvs = [5, 17, 40, 300, 9, 128]
    rings = [star_polygon(rng, nv, cx=(i % 3) * 1.5, cy=(i // 3) * 1.5)
             for i, nv in enumerate(nvs)]
    e = max(nvs)
    dense = np.zeros((len(rings), e, 4), np.float32)
    for p, ring in enumerate(rings):
        er = ring_to_edges(ring)
        dense[p, :len(er)] = er
        # Degenerate padding edges, as production tables carry.
        dense[p, len(er):] = np.array([er[0, 0], er[0, 1],
                                       er[0, 0], er[0, 1]], np.float32)
    pool = ops.build_edge_pool(dense, be=128)
    return rings, dense, pool


def test_edge_pool_layout(ragged_world):
    rings, dense, pool = ragged_world
    first = np.asarray(pool.first)
    count = np.asarray(pool.count)
    blocks = np.asarray(pool.blocks)
    # Block 0 is the reserved all-zero block (the no-candidate target).
    assert (blocks[0] == 0).all()
    # ceil(live_edges / be) blocks per polygon, contiguous from block 1.
    nvs = [len(r) for r in rings]
    np.testing.assert_array_equal(count, np.ceil(np.array(nvs) / 128))
    np.testing.assert_array_equal(first, 1 + np.concatenate(
        [[0], np.cumsum(count)[:-1]]))
    assert pool.max_blocks == int(count.max())


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_pip_candidates_matches_host_oracle(ragged_world, backend):
    """Fused gather-PIP == per-point fp64-free pip_one ground truth,
    including multi-block polygons and id -1 (never inside)."""
    rings, dense, pool = ragged_world
    rng = np.random.default_rng(5)
    n = 64
    pts = rng.uniform(-2, 4, (n, 2)).astype(np.float32)
    pids = rng.integers(-1, len(rings), n).astype(np.int32)
    got = np.asarray(ops.pip_candidates(jnp.asarray(pts),
                                        jnp.asarray(pids), pool,
                                        backend=backend))
    want = np.zeros(n, bool)
    for i in range(n):
        if pids[i] >= 0:
            want[i] = bool(np.asarray(ref.pip_one(
                jnp.asarray(pts[i:i + 1]),
                jnp.asarray(ring_to_edges(rings[pids[i]]))))[0])
    np.testing.assert_array_equal(got, want)


def test_pip_candidates_interpret_bitexact_vs_ref(ragged_world):
    """The acceptance bar: the Pallas kernel under interpret matches the
    CSR ref oracle bit-exactly (same fp32 arithmetic, same results)."""
    rings, dense, pool = ragged_world
    rng = np.random.default_rng(6)
    n = 96
    pts = rng.uniform(-3, 5, (n, 2)).astype(np.float32)
    pids = rng.integers(-1, len(rings), n).astype(np.int32)
    a = np.asarray(ops.pip_candidates(jnp.asarray(pts), jnp.asarray(pids),
                                      pool, backend="interpret"))
    b = np.asarray(ops.pip_candidates(jnp.asarray(pts), jnp.asarray(pids),
                                      pool, backend="ref"))
    np.testing.assert_array_equal(a, b)


def test_pip_candidates_matches_legacy_gather_flow(ragged_world):
    """Fused path == the two-step gather-edges-then-pip_gathered flow it
    replaces, on identical candidate ids."""
    rings, dense, pool = ragged_world
    rng = np.random.default_rng(7)
    n = 64
    pts = rng.uniform(-2, 4, (n, 2)).astype(np.float32)
    pids = rng.integers(0, len(rings), n).astype(np.int32)
    gathered = dense[pids]                       # the HBM buffer we remove
    legacy = np.asarray(ref.pip_gathered(jnp.asarray(pts),
                                         jnp.asarray(gathered)))
    fused = np.asarray(ops.pip_candidates(jnp.asarray(pts),
                                          jnp.asarray(pids), pool,
                                          backend="ref"))
    np.testing.assert_array_equal(fused, legacy)


def test_edge_pool_empty_table():
    pool = ops.build_edge_pool(np.zeros((0, 4, 4), np.float32))
    out = np.asarray(ops.pip_candidates(
        jnp.zeros((3, 2), jnp.float32),
        jnp.full((3,), -1, jnp.int32), pool, backend="ref"))
    assert not out.any()


# ------------------------------------------------------------------ bbox
@pytest.mark.parametrize("n_pts,n_boxes", [(10, 3), (600, 130), (512, 512)])
def test_bbox_mask_shapes(n_pts, n_boxes):
    rng = np.random.default_rng(n_pts + n_boxes)
    pts = rng.uniform(-2, 2, (n_pts, 2)).astype(np.float32)
    lo = rng.uniform(-2, 1.5, (n_boxes, 2))
    wh = rng.uniform(0.1, 1.0, (n_boxes, 2))
    boxes = np.stack([lo[:, 0], lo[:, 0] + wh[:, 0],
                      lo[:, 1], lo[:, 1] + wh[:, 1]], -1).astype(np.float32)
    want = np.asarray(ref.bbox_mask(jnp.asarray(pts), jnp.asarray(boxes)))
    got = np.asarray(ops.bbox_mask(jnp.asarray(pts), jnp.asarray(boxes),
                                   backend="interpret"))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_pts,c", [(16, 4), (300, 33), (512, 128)])
def test_bbox_count_select_shapes(n_pts, c):
    rng = np.random.default_rng(n_pts + c)
    pts = rng.uniform(-2, 2, (n_pts, 2)).astype(np.float32)
    lo = rng.uniform(-2, 1.5, (n_pts, c, 2))
    wh = rng.uniform(0.1, 1.5, (n_pts, c, 2))
    boxes = np.stack([lo[..., 0], lo[..., 0] + wh[..., 0],
                      lo[..., 1], lo[..., 1] + wh[..., 1]],
                     -1).astype(np.float32)
    wc, ws = ref.bbox_count_select(jnp.asarray(pts), jnp.asarray(boxes))
    gc, gs = ops.bbox_count_select(jnp.asarray(pts), jnp.asarray(boxes),
                                   backend="interpret")
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


def test_pip_point_outside_bbox_is_outside():
    rng = np.random.default_rng(3)
    ring = star_polygon(rng, 12)
    far = np.array([[10.0, 10.0], [-10.0, 0.0], [0.0, 99.0]], np.float32)
    got = np.asarray(ref.pip_one(jnp.asarray(far),
                                 jnp.asarray(ring_to_edges(ring))))
    assert not got.any()


# --------------------------------------------------------------- property
if hypothesis is None:
    def test_property_suite_requires_hypothesis():
        """Visible marker that the 4 property tests below are absent."""
        pytest.skip("hypothesis not installed; property tests omitted")

if hypothesis is not None:
    @hypothesis.given(
        n_verts=st.integers(3, 40),
        n_pts=st.integers(1, 50),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_pip_property_matches_fp64_host(n_verts, n_pts, seed):
        """Kernel agrees with the fp64 host oracle on random stars."""
        rng = np.random.default_rng(seed)
        ring = star_polygon(rng, n_verts)
        pts = rng.uniform(-2, 2, (n_pts, 2))
        host = point_in_polygon_host(pts[:, 0], pts[:, 1], ring)
        got = np.asarray(ref.pip_one(jnp.asarray(pts.astype(np.float32)),
                                     jnp.asarray(ring_to_edges(ring))))
        # fp32 vs fp64 can disagree only within ~1e-6 of an edge; measure-
        # zero for uniform points, but tolerate a single straddler.
        assert (got == host).mean() >= 1.0 - 1.0 / max(n_pts, 1) * 0.999 \
            or (got == host).all()

    @hypothesis.given(
        n_verts=st.integers(3, 30),
        seed=st.integers(0, 2**31 - 1),
        dx=st.floats(-5, 5), dy=st.floats(-5, 5),
    )
    def test_pip_translation_invariance(n_verts, seed, dx, dy):
        rng = np.random.default_rng(seed)
        ring = star_polygon(rng, n_verts)
        pts = rng.uniform(-2, 2, (16, 2)).astype(np.float32)
        base = np.asarray(ref.pip_one(jnp.asarray(pts),
                                      jnp.asarray(ring_to_edges(ring))))
        shift = np.array([dx, dy], np.float32)
        moved = np.asarray(ref.pip_one(jnp.asarray(pts + shift),
                                       jnp.asarray(ring_to_edges(
                                           (ring + shift)
                                           .astype(np.float64)))))
        # Allow fp rounding flips right at edges: >= 15/16 agreement.
        assert (base == moved).sum() >= 15

    @hypothesis.given(
        n_verts=st.integers(3, 30),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_pip_orientation_invariance(n_verts, seed):
        """Reversing the ring (CW vs CCW) must not change inside/out."""
        rng = np.random.default_rng(seed)
        ring = star_polygon(rng, n_verts)
        pts = rng.uniform(-2, 2, (32, 2)).astype(np.float32)
        a = np.asarray(ref.pip_one(jnp.asarray(pts),
                                   jnp.asarray(ring_to_edges(ring))))
        b = np.asarray(ref.pip_one(jnp.asarray(pts),
                                   jnp.asarray(ring_to_edges(ring[::-1]))))
        np.testing.assert_array_equal(a, b)

    @hypothesis.given(seed=st.integers(0, 2**31 - 1))
    def test_bbox_count_matches_mask_rowsum(seed):
        rng = np.random.default_rng(seed)
        pts = rng.uniform(-2, 2, (64, 2)).astype(np.float32)
        lo = rng.uniform(-2, 1.5, (64, 8, 2))
        wh = rng.uniform(0.1, 1.5, (64, 8, 2))
        boxes = np.stack([lo[..., 0], lo[..., 0] + wh[..., 0],
                          lo[..., 1], lo[..., 1] + wh[..., 1]],
                         -1).astype(np.float32)
        cnt, sel = ref.bbox_count_select(jnp.asarray(pts),
                                         jnp.asarray(boxes))
        mask = np.asarray(ref.bbox_mask_gathered(jnp.asarray(pts),
                                                 jnp.asarray(boxes)))
        np.testing.assert_array_equal(np.asarray(cnt), mask.sum(1))
        has = mask.any(1)
        sel = np.asarray(sel)
        assert (sel[~has] == -1).all()
        rows = np.arange(64)[has]
        assert mask[rows, sel[has]].all()
