"""GeoAnalytics battery (DESIGN.md §16).

Covers the three layers bottom-up:

* segment-reduce kernels — bit-identity vs the numpy bincount oracle
  across backends (order-free stats always; f32 sums bit-exact on
  integer-valued inputs, allclose in general), invalid-id parking,
  fused assign→aggregate vs unfused host bincount;
* windowed streaming — rotation/eviction under out-of-order
  timestamps, late-drop accounting, sketch error bounds, k-anonymity
  suppression, merged-window associativity;
* serving — served-vs-direct aggregation equality with the cache on
  and off, sync and async (8 submitters), and the analytics
  observability surface.
"""
import concurrent.futures as cf

import numpy as np
import jax.numpy as jnp
import pytest

from repro.analytics import (AnalyticsConfig, BlockAggregator,
                             DistinctSketch, WindowedAggregator,
                             WindowState)
from repro.core.engine import GeoEngine
from repro.core.geometry import polygon_areas
from repro.kernels import ops
from repro.kernels.ref import np_segment_reduce
from repro.serving import (AnalyticsConfig as ServingAnalyticsConfig,
                           AsyncGeoServer, FrontendConfig, GeoServer,
                           ServeConfig)

# ---------------------------------------------------------------------------
# Layer 1: segment-reduce kernels
# ---------------------------------------------------------------------------


def _mixed_ids(rng, n, n_segments):
    """Ids spanning valid range plus out-of-range rows on both sides."""
    ids = rng.integers(-2, n_segments + 2, size=n)
    return ids.astype(np.int32)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_segment_reduce_bitexact_vs_oracle(backend):
    """Integer-valued f32 workload (the occupancy shape): every output
    — count, sum, min, max — bit-identical to the numpy oracle."""
    rng = np.random.default_rng(0)
    n, s = 3000, 257
    ids = _mixed_ids(rng, n, s)
    vals = rng.integers(-50, 50, size=n).astype(np.float32)
    out = ops.segment_reduce(jnp.asarray(ids), jnp.asarray(vals),
                             n_segments=s, backend=backend,
                             bp=128, bs=128)
    ref = np_segment_reduce(ids, vals, s)
    np.testing.assert_array_equal(np.asarray(out.count), ref[0])
    np.testing.assert_array_equal(np.asarray(out.sum), ref[1])
    np.testing.assert_array_equal(np.asarray(out.min), ref[2])
    np.testing.assert_array_equal(np.asarray(out.max), ref[3])


def test_segment_reduce_backends_bitexact_orderfree():
    """count/min/max are order-free: bit-identical ref vs interpret even
    on general floats; general f32 sums agree to rounding."""
    rng = np.random.default_rng(1)
    n, s = 2500, 130
    ids = _mixed_ids(rng, n, s)
    vals = rng.normal(size=n).astype(np.float32)
    a = ops.segment_reduce(jnp.asarray(ids), jnp.asarray(vals),
                           n_segments=s, backend="ref")
    b = ops.segment_reduce(jnp.asarray(ids), jnp.asarray(vals),
                           n_segments=s, backend="interpret",
                           bp=128, bs=128)
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))
    np.testing.assert_array_equal(np.asarray(a.min), np.asarray(b.min))
    np.testing.assert_array_equal(np.asarray(a.max), np.asarray(b.max))
    np.testing.assert_allclose(np.asarray(a.sum), np.asarray(b.sum),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_segment_reduce_empty_and_invalid(backend):
    """All-invalid ids -> zero counts and the empty-segment sentinels
    (sum 0, min +inf, max -inf) on every backend."""
    ids = jnp.array([-1, -5, 99, 100], jnp.int32)
    out = ops.segment_reduce(ids, None, n_segments=8, backend=backend,
                             bp=128, bs=128)
    assert np.asarray(out.count).sum() == 0
    assert (np.asarray(out.sum) == 0.0).all()
    assert np.isposinf(np.asarray(out.min)).all()
    assert np.isneginf(np.asarray(out.max)).all()


def test_fused_assign_aggregate_matches_unfused(synth_small,
                                                points_small):
    """The tentpole identity: fused assign→segment-count equals
    assign → host transfer → np.bincount, bit for bit, for every
    point (counts are integer accumulations — order-free)."""
    engine = GeoEngine.build(synth_small.census, "fast")
    pts = points_small[0][:2048]
    agg = BlockAggregator.from_engine(engine)
    fused = np.asarray(agg.fused_counts(jnp.asarray(pts)))
    bid = np.asarray(engine.assign(jnp.asarray(pts)).block)
    unfused = agg.counts(bid)
    np.testing.assert_array_equal(fused, unfused)
    assert fused.sum() == (bid >= 0).sum()


# ---------------------------------------------------------------------------
# Layer 2: aggregation + windows + sketches
# ---------------------------------------------------------------------------


def test_block_aggregator_density_and_index(synth_small):
    areas = polygon_areas(synth_small.census.blocks)
    n = len(areas)
    agg = BlockAggregator(n, areas)
    counts = np.arange(n)
    dens = agg.density(counts)
    assert dens.shape == (n,)
    nz = areas > 0
    np.testing.assert_allclose(dens[nz], counts[nz] / areas[nz])
    # HVI-style composite: z-scored columns blend linearly; a constant
    # column contributes exactly zero.
    rng = np.random.default_rng(2)
    cols = np.stack([rng.normal(size=n), np.full(n, 7.0)], axis=1)
    idx = agg.weighted_index(cols, [0.6, 0.4])
    z = (cols[:, 0] - cols[:, 0].mean()) / cols[:, 0].std()
    np.testing.assert_allclose(idx, 0.6 * z, atol=1e-12)


def test_window_rotation_out_of_order():
    """Tumbling windows with lateness: out-of-order events inside the
    horizon land in their event-time window; beyond it they drop."""
    cfg = AnalyticsConfig(window_s=10.0, allowed_lateness_s=5.0,
                          sketch_bits=256)
    agg = WindowedAggregator(4, cfg)
    agg.observe(1.0, [0], [1])
    agg.observe(12.0, [1], [2])
    agg.observe(3.0, [0], [3])       # out of order, within lateness
    assert agg.finalized_total == 0  # wm = 12 - 5 < 10: window 0 open
    agg.observe(16.0, [2], [4])      # wm = 11: window [0,10) closes
    assert agg.finalized_total == 1
    assert agg.finalized[0].counts.tolist() == [2, 0, 0, 0]
    assert 0 not in agg.panes        # pane evicted with its window
    n = agg.observe(4.0, [3], [5])   # beyond horizon now
    assert n == 0 and agg.late_dropped == 1
    assert agg.observed == 5


def test_window_sliding_composes_panes():
    """Sliding window = merge of tumbling panes: every finalized
    2-pane window equals the sum of its panes' exact counts."""
    cfg = AnalyticsConfig(window_s=10.0, slide_s=5.0,
                          allowed_lateness_s=0.0, sketch_bits=256)
    agg = WindowedAggregator(3, cfg)
    per_pane = {0: [0, 0], 1: [1], 2: [2, 2, 2], 3: [0]}
    for pane, bids in per_pane.items():
        agg.observe(pane * 5.0 + 1.0, bids, list(range(len(bids))))
    agg.advance(40.0)
    by_start = {s.start: s for s in agg.finalized}
    for w in (0, 1, 2):
        merged = np.bincount(per_pane[w] + per_pane[w + 1], minlength=3)
        np.testing.assert_array_equal(by_start[w * 5.0].counts, merged)
    assert len(agg.panes) == 0       # everything evicted


def test_window_state_merge_associative():
    """WindowState.merge is exactly associative (counter sums + bitmap
    ORs) — the property sliding windows and replica feeds rely on."""
    rng = np.random.default_rng(3)
    states = []
    for _ in range(3):
        st = WindowState(16, 256)
        st.observe(rng.integers(0, 16, 40),
                   rng.integers(0, 1000, 40))
        states.append(st)
    a, b, c = states
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    np.testing.assert_array_equal(left.counts, right.counts)
    np.testing.assert_array_equal(left.sketch.bitmap,
                                  right.sketch.bitmap)
    assert left.n_events == right.n_events
    # and non-mutating: the inputs kept their own event counts
    assert sum(s.n_events for s in states) == left.n_events


def test_sketch_error_bound_seeded():
    """Linear counting at ~12% load: relative error well under 10% on a
    seeded stream (deterministic — splitmix64 has no salt)."""
    rng = np.random.default_rng(4)
    sk = DistinctSketch(4, 4096)
    for seg, n_distinct in ((0, 500), (1, 50), (2, 1)):
        src = rng.integers(0, n_distinct, size=4 * n_distinct) \
            + seg * 10_000
        sk.observe(np.full(src.shape, seg), src)
        true = len(np.unique(src))
        est = sk.estimate()[seg]
        assert abs(est - true) <= max(0.1 * true, 1.0), (seg, est, true)
    assert sk.estimate()[3] == 0.0   # untouched segment


def test_k_anonymity_suppression():
    """Blocks below k distinct sources are suppressed from every
    published view but kept in the raw arrays."""
    cfg = AnalyticsConfig(window_s=10.0, allowed_lateness_s=0.0,
                          k_anon=3, sketch_bits=512)
    agg = WindowedAggregator(3, cfg)
    # block 0: 5 distinct sources; block 1: 1 source, many events
    agg.observe(1.0, [0] * 5 + [1] * 20,
                [10, 11, 12, 13, 14] + [99] * 20)
    agg.observe(12.0, [2], [1])      # rotate window 0 out
    snap = agg.finalized[0]
    assert snap.suppressed.tolist() == [False, True, False]
    assert snap.counts[1] == 20      # raw state intact
    top = snap.top_k(10)
    assert [row["block"] for row in top] == [0]
    assert snap.as_dict()["suppressed_blocks"] == 1
    # pairs: C(5,2) potential encounters in block 0
    assert snap.pairs[0] == 10


def test_window_snapshot_schema():
    agg = WindowedAggregator(4, AnalyticsConfig(window_s=5.0,
                                                sketch_bits=256))
    agg.observe(1.0, [0, 1], [1, 2])
    snap = agg.snapshot()
    for key in ("config", "observed", "off_map", "late_dropped",
                "open_panes", "finalized_total", "finalized", "open"):
        assert key in snap, key
    assert snap["open"]["n_events"] == 2
    assert snap["observed"] == 2 and snap["open_panes"] == 1


# ---------------------------------------------------------------------------
# Layer 3: serving integration
# ---------------------------------------------------------------------------


def _analytics_cfg():
    tick = [1000.0]
    return ServingAnalyticsConfig(window_s=60.0, sketch_bits=512,
                                  clock=lambda: tick[0])


@pytest.fixture(scope="module")
def serving_engine(synth_small):
    return GeoEngine.build(synth_small.census, "fast")


@pytest.mark.parametrize("cache", [True, False])
def test_served_equals_direct_sync(serving_engine, points_small, cache):
    """Every served batch feeds the window; after synchronous submits
    the open window's counts equal a direct engine assign + bincount,
    exactly — cache hits and device answers alike."""
    pts = points_small[0][:1500]
    server = GeoServer(serving_engine,
                       ServeConfig(cache=cache, analytics=_analytics_cfg()))
    direct = np.asarray(serving_engine.assign(jnp.asarray(pts)).block)
    for i in range(0, len(pts), 250):
        server.submit(pts[i:i + 250])
    ana = server.regions[0].analytics
    expect = np.bincount(direct[direct >= 0], minlength=ana.n_blocks)
    cur = ana.current()
    np.testing.assert_array_equal(cur.counts, expect)
    assert cur.n_events == int((direct >= 0).sum())
    assert cur.density is not None   # engine census -> areas wired


@pytest.mark.timeout(120)
def test_served_equals_direct_async(serving_engine, points_small):
    """8 concurrent submitters, 2 replicas: after drain, the analytics
    state equals the direct aggregation — arrival order decided window
    membership and the folds commute, so the race is harmless."""
    pts = points_small[0][:1600]
    direct = np.asarray(serving_engine.assign(jnp.asarray(pts)).block)
    with AsyncGeoServer(serving_engine,
                        ServeConfig(cache=True,
                                    analytics=_analytics_cfg()),
                        frontend=FrontendConfig(n_submitters=8,
                                                n_replicas=2)) as server:
        with cf.ThreadPoolExecutor(8) as pool:
            futs = [pool.submit(server.submit, pts[i:i + 100])
                    for i in range(0, len(pts), 100)]
            for f in futs:
                f.result(timeout=60)
        server.drain(timeout=60)
        ana = server.regions[0].analytics
        expect = np.bincount(direct[direct >= 0],
                             minlength=ana.n_blocks)
        cur = ana.current()
        np.testing.assert_array_equal(cur.counts, expect)
        # 16 requests -> distinct-source estimates bounded by 16
        assert int(cur.distinct.max()) <= 16


def test_serving_analytics_observability(serving_engine, points_small):
    """snapshot_analytics() returns the per-region schema and the
    analytics gauges/stage land in the exposition text."""
    pts = points_small[0][:300]
    server = GeoServer(serving_engine,
                       ServeConfig(analytics=_analytics_cfg()))
    server.submit(pts)
    snap = server.snapshot_analytics()
    assert snap is not None and len(snap["regions"]) == 1
    assert snap["regions"][0]["observed"] == 300
    text = server.metrics_text()
    for needle in ("analytics_points", "analytics_open_panes",
                   "analytics_windows_finalized",
                   "analytics_late_dropped",
                   "analytics_suppressed_blocks",
                   "analytics_observe"):
        assert needle in text, needle
    # analytics off -> no surface
    plain = GeoServer(serving_engine, ServeConfig())
    assert plain.snapshot_analytics() is None


def test_serving_analytics_unowned_points_not_folded(serving_engine):
    """Points outside every region's extent belong to no region's
    aggregator — they are not folded (the router's region == -1 already
    accounts for them) and no window opens."""
    far = np.full((8, 2), 500.0, np.float32)
    server = GeoServer(serving_engine,
                       ServeConfig(analytics=_analytics_cfg()))
    res = server.submit(far)
    assert (res.region == -1).all()
    snap = server.snapshot_analytics()["regions"][0]
    assert snap["observed"] == 0 and snap["off_map"] == 0
    assert snap["open"] is None      # nothing landed in a window
