"""Observability battery (DESIGN.md §15): tracer span trees through the
sync and concurrent serve paths, histogram algebra, metrics semantics,
Prometheus exposition, profiler hooks, and the bit-identity guarantee
with tracing on at 100% sampling.

The concurrent stress (8 producers, 50% sampling, requeues in flight)
asserts the span-tree invariants the Chrome-trace validator
(scripts/check_trace.py) enforces on the verify smoke: exactly one root
per completed request, children nested inside their root's interval,
retried batches produce linked retry spans, and sampling drops whole
requests atomically — never orphan children.
"""
import json
import threading
import time
from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, GeoEngine
from repro.obs import (LatencyHistogram, SpanBuffer, Tracer,
                       device_annotation, profiler_available)
from repro.obs.trace import Span
from repro.serving import (AsyncGeoServer, FrontendConfig, GeoServer,
                           ServeConfig)
from repro.serving.metrics import LatencyWindow, ServerMetrics

EXACT_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8,
                         fused=True)
BUCKETS = (64, 256, 1024)
STREAM = (1, 7, 300, 555, 1024, 113)

# Child nesting tolerance: spans stamp time.perf_counter monotonically
# in program order, so exact containment should hold; allow float slack.
EPS_S = 1e-9


@pytest.fixture(scope="module")
def engine(synth_small):
    return GeoEngine.build(synth_small.census, "fast", EXACT_CFG)


def _mk_span(i, trace_id=1, parent=None, name="s"):
    return Span(trace_id=trace_id, span_id=i, parent_id=parent,
                name=name, t0=float(i), t1=float(i + 1),
                thread="t", attrs={})


def _by_trace(spans):
    groups = defaultdict(list)
    for s in spans:
        groups[s.trace_id].append(s)
    return groups


def _assert_tree_invariants(spans):
    """The span-tree invariants for a set of *completed* traces."""
    for tid, group in _by_trace(spans).items():
        roots = [s for s in group if s.parent_id is None]
        assert len(roots) == 1, \
            f"trace {tid}: {len(roots)} roots (want exactly 1)"
        root = roots[0]
        assert root.name == "request"
        ids = {s.span_id for s in group}
        for s in group:
            if s is root:
                continue
            assert s.parent_id in ids, \
                f"trace {tid}: {s.name} parent {s.parent_id} unresolved"
            assert s.t0 >= root.t0 - EPS_S and s.t1 <= root.t1 + EPS_S, \
                f"trace {tid}: {s.name} outside root interval"
            assert s.t1 >= s.t0 - EPS_S


# -- histogram algebra -------------------------------------------------------

def test_hist_quantile_within_bucket_resolution():
    h = LatencyHistogram()
    rng = np.random.default_rng(0)
    samples = rng.uniform(1e-4, 1e-1, 4096)
    for s in samples:
        h.observe(s)
    # Geometric-midpoint answers are exact within one bucket's half
    # width: a factor of 2**(1/(2*per_octave)) (~9% at 4/octave).
    tol = 2 ** (0.5 / h.per_octave)
    for q in (0.5, 0.9, 0.99):
        exact = np.quantile(samples, q)
        approx = h.quantile(q)
        assert exact / tol <= approx <= exact * tol, (q, exact, approx)


def test_hist_merge_matches_single_feed_and_is_associative():
    rng = np.random.default_rng(1)
    parts = [rng.uniform(1e-5, 1.0, 257) for _ in range(3)]
    hs = []
    for p in parts:
        h = LatencyHistogram()
        for s in p:
            h.observe(s)
        hs.append(h)
    direct = LatencyHistogram()
    for s in np.concatenate(parts):
        direct.observe(s)
    ab_c = hs[0].merge(hs[1]).merge(hs[2])
    a_bc = hs[0].merge(hs[1].merge(hs[2]))
    for m in (ab_c, a_bc):
        np.testing.assert_array_equal(m.counts, direct.counts)
        assert m.count == direct.count
        assert m.max == direct.max
        assert m.sum == pytest.approx(direct.sum)


def test_hist_merge_rejects_layout_mismatch():
    with pytest.raises(ValueError, match="layout"):
        LatencyHistogram().merge(LatencyHistogram(per_octave=8))


def test_hist_overflow_and_empty():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0
    assert h.snapshot_ms()["count"] == 0
    assert h.snapshot_ms()["p99"] is None
    h.observe(1e9)                     # beyond hi -> overflow bucket
    assert h.counts[-1] == 1
    assert h.quantile(0.99) == float(h.uppers[-1])
    assert h.snapshot_ms()["max"] == pytest.approx(1e12)  # ms, exact


def test_hist_cumulative_truncates_after_covering_bucket():
    h = LatencyHistogram()
    h.observe(2e-6)                    # bucket upper exactly 2e-06
    cum = h.cumulative()
    assert cum[-1] == (pytest.approx(2e-6), 1)
    assert all(c == 0 for _, c in cum[:-1])
    assert len(cum) == 4               # 4 buckets/octave, one octave up


# -- metrics semantics (satellites 1 + 2) ------------------------------------

def test_latency_window_reports_both_counts():
    w = LatencyWindow(window=8)
    for i in range(20):
        w.observe(0.001 * (i + 1))
    snap = w.snapshot_ms()
    assert snap["count_total"] == 20
    assert snap["count_window"] == 8   # percentiles cover only these
    assert snap["p50"] == pytest.approx(
        np.percentile(np.arange(13, 21) * 1.0, 50))


def test_observe_cache_gauges_survive_rewind():
    """Cache absolutes are gauges: a cache clear rewinds them without
    corrupting any counter a scraper might diff."""
    m = ServerMetrics()
    m.observe_cache({"hits": 50, "misses": 10, "insertions": 8,
                     "evictions": 1, "entries": 7})
    assert m.gauges["cache_hits"] == 50
    counters_before = dict(m.counters)
    m.observe_cache({"hits": 2, "misses": 1, "insertions": 1,
                     "evictions": 0, "entries": 1})   # post-clear
    assert m.gauges["cache_hits"] == 2                # gauge follows
    assert m.counters == counters_before              # counters untouched
    snap = m.snapshot()
    assert snap["derived"]["cache_hit_rate"] == pytest.approx(2 / 3)


def test_serving_cache_totals_are_monotonic(engine, points_small):
    """The serving-side cache_*_total counters increment at observation
    sites and never rewind, even when the cache itself is cleared."""
    xy, *_ = points_small
    server = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=True))
    server.submit(xy[:500])
    c1 = server.metrics.counters["cache_misses_total"]
    assert c1 > 0
    cache = server.regions[0].cache   # simulate a cache clear/restart
    cache._map.clear()
    cache.hits = cache.misses = 0
    server.submit(xy[:500])
    assert server.metrics.counters["cache_misses_total"] > c1
    # while the gauge absolutes rewound with the clear:
    assert server.snapshot()["gauges"]["cache_misses"] < \
        server.metrics.counters["cache_misses_total"]


def test_expose_text_golden():
    m = ServerMetrics()
    m.inc("requests", 3)
    m.inc("points_in", 42)
    m.set_gauge("queue_depth_points", 0)
    m.observe_stage("merge", 2e-6)     # lands exactly on a bucket upper
    got = m.expose_text()
    assert got == (
        "# TYPE points_in_total counter\n"
        "points_in_total 42\n"
        "# TYPE requests_total counter\n"
        "requests_total 3\n"
        "# TYPE queue_depth_points gauge\n"
        "queue_depth_points 0\n"
        "# TYPE stage_latency_seconds histogram\n"
        'stage_latency_seconds_bucket{stage="merge",le="1.18921e-06"} 0\n'
        'stage_latency_seconds_bucket{stage="merge",le="1.41421e-06"} 0\n'
        'stage_latency_seconds_bucket{stage="merge",le="1.68179e-06"} 0\n'
        'stage_latency_seconds_bucket{stage="merge",le="2e-06"} 1\n'
        'stage_latency_seconds_bucket{stage="merge",le="+Inf"} 1\n'
        'stage_latency_seconds_sum{stage="merge"} 2e-06\n'
        'stage_latency_seconds_count{stage="merge"} 1\n')


def test_expose_text_sanitizes_metric_names():
    m = ServerMetrics()
    m.inc("weird name-1!", 2)
    txt = m.expose_text()
    assert "weird_name_1__total 2" in txt


# -- span plumbing -----------------------------------------------------------

def test_span_buffer_bounded_drop_oldest():
    buf = SpanBuffer(capacity=4)
    for i in range(6):
        buf.append(_mk_span(i))
    assert len(buf) == 4
    assert buf.dropped == 2
    assert [s.span_id for s in buf.snapshot()] == [2, 3, 4, 5]
    buf.clear()
    assert len(buf) == 0 and buf.dropped == 0


def test_tracer_sampling_is_deterministic_and_exact():
    tr = Tracer(sample_rate=0.25)
    kept = [tr.start_trace() is not None for _ in range(100)]
    assert sum(kept) == 25             # exact long-run rate
    # credit accumulator: every 4th request sampled, deterministically
    assert kept == [((i + 1) % 4 == 0) for i in range(100)]
    assert Tracer(sample_rate=0.0).start_trace() is None
    assert Tracer(sample_rate=1.0).start_trace() is not None
    with pytest.raises(ValueError):
        Tracer(sample_rate=1.5)


def test_request_trace_parentage_and_idempotent_end():
    tr = Tracer(sample_rate=1.0)
    t0 = time.perf_counter()
    rt = tr.start_trace(t0)
    host = rt.span("host_prepare", t0 + 0.01, t0 + 0.02)
    rt.span("route", t0 + 0.011, t0 + 0.015, parent=host, region=0)
    rt.end(t0 + 0.05, n_points=3)
    rt.end(t0 + 9.0)                   # second close must be a no-op
    spans = tr.buffer.snapshot()
    assert [s.name for s in spans] == ["host_prepare", "route", "request"]
    _assert_tree_invariants(spans)
    root = spans[-1]
    assert root.t1 == t0 + 0.05 and root.attrs == {"n_points": 3}
    route = spans[1]
    assert route.parent_id == host and route.attrs["region"] == 0
    assert spans[0].parent_id == root.span_id


def test_chrome_export_shape(tmp_path):
    tr = Tracer(sample_rate=1.0)
    rt = tr.start_trace(time.perf_counter())
    rt.span("queue_wait", rt._t0, rt._t0 + 0.001)
    rt.end(rt._t0 + 0.002)
    path = str(tmp_path / "trace.json")
    n = tr.export_chrome(path)
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert len(events) == n
    xs = [e for e in events if e["ph"] == "X"]
    metas = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"queue_wait", "request"}
    assert metas and metas[0]["name"] == "thread_name"
    for e in xs:                       # pid = the request's trace id
        assert e["pid"] == rt.trace_id
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["args"]["trace_id"] == rt.trace_id


# -- serve-path integration --------------------------------------------------

def test_sync_serving_bit_identical_with_full_tracing(engine,
                                                      points_small):
    """Acceptance: tracing at 100% sampling changes no served bit."""
    xy, *_ = points_small
    tracer = Tracer(sample_rate=1.0)
    traced = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=True),
                       tracer=tracer)
    plain = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=True))
    off = 0
    for size in STREAM:
        req = xy[off:off + size]
        off += size
        rt = traced.submit(req)
        rp = plain.submit(req)
        direct = engine.assign(jnp.asarray(req))
        np.testing.assert_array_equal(rt.block, np.asarray(direct.block))
        np.testing.assert_array_equal(rt.state, np.asarray(direct.state))
        np.testing.assert_array_equal(rt.block, rp.block)
    assert tracer.stats()["sampled"] == len(STREAM)
    spans = tracer.buffer.snapshot()
    _assert_tree_invariants(spans)
    roots = [s for s in spans if s.parent_id is None]
    assert len(roots) == len(STREAM)
    names = {s.name for s in spans}
    assert {"request", "submit", "queue_wait", "host_prepare", "route",
            "cache_lookup", "device_assign", "merge"} <= names


def test_sync_stage_histograms_always_on(engine, points_small):
    """Per-stage histograms record with NO tracer attached."""
    xy, *_ = points_small
    server = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=False))
    server.submit(xy[:200])
    stages = server.snapshot()["stages"]
    for stage in ("queue_wait", "host_prepare", "device_assign", "merge",
                  "request"):
        assert stages[stage]["count"] > 0, stage
        assert stages[stage]["p99"] >= 0


def test_tracer_off_records_nothing(engine, points_small):
    xy, *_ = points_small
    tracer = Tracer(sample_rate=0.0)
    server = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=False),
                       tracer=tracer)
    server.submit(xy[:100])
    assert len(tracer.buffer) == 0
    assert tracer.stats()["started"] == 1
    assert tracer.stats()["sampled"] == 0


def test_metrics_text_endpoint(engine, points_small):
    xy, *_ = points_small
    server = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=True))
    server.submit(xy[:100])
    txt = server.metrics_text()
    assert "requests_total 1" in txt
    assert "cache_misses gauge" in txt
    assert 'stage_latency_seconds_bucket{stage="device_assign"' in txt
    assert txt.count('le="+Inf"') >= 5     # every serve stage renders


@pytest.mark.timeout(60)
def test_async_tracing_stress_span_tree_invariants(engine, points_small):
    """8 producers, 50% sampling: every sampled request yields exactly
    one root, children nest, whole requests drop atomically."""
    xy, *_ = points_small
    tracer = Tracer(sample_rate=0.5, capacity=1 << 15)
    n_producers, per_producer = 8, 12
    sizes = [1, 9, 33, 120, 300]
    with AsyncGeoServer(
            engine, ServeConfig(buckets=BUCKETS, cache=True,
                                max_delay_ms=1.0),
            frontend=FrontendConfig(n_replicas=2, n_submitters=4),
            tracer=tracer) as server:
        results, errors = [], []
        lock = threading.Lock()

        def producer(pid):
            rng = np.random.default_rng(pid)
            try:
                futs = []
                for i in range(per_producer):
                    size = sizes[rng.integers(0, len(sizes))]
                    start = rng.integers(0, len(xy) - size)
                    futs.append((start, size,
                                 server.submit_async(
                                     xy[start:start + size])))
                for start, size, fut in futs:
                    res = fut.result(timeout=30)
                    with lock:
                        results.append((start, size, res))
            except Exception as e:     # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=producer, args=(p,))
                   for p in range(n_producers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(45)
        assert not errors
        server.drain(timeout=30)
    n_requests = n_producers * per_producer
    assert len(results) == n_requests
    # Bit-identity held under concurrency + tracing:
    for start, size, res in results:
        direct = np.asarray(
            engine.assign(jnp.asarray(xy[start:start + size])).block)
        np.testing.assert_array_equal(res.block, direct)
    # Span-tree invariants over everything recorded:
    st = tracer.stats()
    assert st["started"] == n_requests
    assert st["sampled"] == n_requests // 2    # deterministic 50%
    assert st["dropped"] == 0
    spans = tracer.buffer.snapshot()
    _assert_tree_invariants(spans)
    groups = _by_trace(spans)
    assert len(groups) == st["sampled"]        # whole-request sampling
    for group in groups.values():              # every trace completed
        names = {s.name for s in group}
        assert "merge" in names and "queue_wait" in names


class _FlakyAssign:
    """Thread-safe assign_padded wrapper failing the first ``n_fail``
    calls (mirrors test_frontend's helper)."""

    def __init__(self, engine, n_fail):
        self._orig = engine.assign_padded
        self._lock = threading.Lock()
        self.n_fail = n_fail
        self.calls = 0

    def __call__(self, points, n_valid):
        with self._lock:
            self.calls += 1
            fail = self.calls <= self.n_fail
        if fail:
            raise RuntimeError("device lost")
        return self._orig(points, n_valid)


@pytest.mark.timeout(30)
def test_retry_produces_linked_retry_span(engine, points_small,
                                          monkeypatch):
    """A failed-then-recovered batch records an instant retry span in
    the request's trace and later spans carry the attempt number."""
    xy, *_ = points_small
    tracer = Tracer(sample_rate=1.0)
    monkeypatch.setattr(engine, "assign_padded", _FlakyAssign(engine, 1))
    with AsyncGeoServer(engine,
                        ServeConfig(buckets=BUCKETS, cache=False,
                                    max_delay_ms=1.0),
                        tracer=tracer) as srv:
        res = srv.submit_async(xy[:100]).result(timeout=15)
    monkeypatch.undo()
    np.testing.assert_array_equal(
        res.block, np.asarray(engine.assign(jnp.asarray(xy[:100])).block))
    spans = tracer.buffer.snapshot()
    _assert_tree_invariants(spans)
    retries = [s for s in spans if s.name == "retry"]
    assert len(retries) == 1
    assert retries[0].attrs["attempt"] == 1
    assert retries[0].t0 == retries[0].t1      # instant event
    # post-retry serve stages carry the attempt attribute
    attempted = [s for s in spans
                 if s.attrs.get("attempt") == 1 and s.name != "retry"]
    assert {"queue_wait", "host_prepare"} <= {s.name for s in attempted}


@pytest.mark.timeout(30)
def test_shed_request_closes_trace_without_orphans(engine, points_small):
    xy, *_ = points_small
    tracer = Tracer(sample_rate=1.0)
    server = GeoServer(engine,
                       ServeConfig(buckets=BUCKETS, cache=False,
                                   max_queue_points=100, policy="shed"),
                       tracer=tracer)
    server.enqueue(xy[:80])
    from repro.serving import QueueFull
    with pytest.raises(QueueFull):
        server.enqueue(xy[80:200])
    server.flush()
    spans = tracer.buffer.snapshot()
    _assert_tree_invariants(spans)
    sheds = [s for s in spans
             if s.parent_id is None and s.attrs.get("error")]
    assert len(sheds) == 1
    assert sheds[0].attrs["error"] == "QueueFull"


# -- profiler hooks + engine stage timer -------------------------------------

def test_device_annotation_is_exception_safe():
    with device_annotation("geo_test/b256"):
        x = 1 + 1
    assert x == 2
    assert isinstance(profiler_available(), bool)


def test_trace_device_config_serves_identically(engine, points_small):
    xy, *_ = points_small
    server = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=False,
                                           trace_device=True))
    res = server.submit(xy[:128])
    direct = np.asarray(engine.assign(jnp.asarray(xy[:128])).block)
    np.testing.assert_array_equal(res.block, direct)


def test_engine_stage_timer_hook(engine, points_small):
    xy, *_ = points_small
    calls = []
    engine.stage_timer = lambda stage, s, **kw: calls.append(
        (stage, s, kw))
    try:
        engine.assign_padded(jnp.asarray(np.zeros((64, 2), np.float32)),
                             10)
    finally:
        engine.stage_timer = None
    assert len(calls) == 1
    stage, seconds, kw = calls[0]
    assert stage == "assign_padded"
    assert seconds > 0
    assert kw == {"batch": 64}


# -- the exported-trace validator itself -------------------------------------

def test_check_trace_validator_on_live_export(engine, points_small,
                                              tmp_path):
    """scripts/check_trace.py accepts a real export and rejects a
    corrupted one."""
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "check_trace", os.path.join(os.path.dirname(__file__), "..",
                                    "scripts", "check_trace.py"))
    check_trace = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(check_trace)

    xy, *_ = points_small
    tracer = Tracer(sample_rate=1.0)
    server = GeoServer(engine, ServeConfig(buckets=BUCKETS, cache=True),
                       tracer=tracer)
    for size in STREAM:
        server.submit(xy[:size])
    good = str(tmp_path / "good.json")
    tracer.export_chrome(good)
    check_trace.main(good)                     # must not exit

    doc = json.load(open(good))
    doc["traceEvents"] = [e for e in doc["traceEvents"]
                          if e.get("name") != "request"]
    bad = str(tmp_path / "bad.json")
    json.dump(doc, open(bad, "w"))
    with pytest.raises(SystemExit):
        check_trace.main(bad)
