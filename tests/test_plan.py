"""GeoPlan auto-planner + GeoIndexSet artifact (DESIGN.md §11):
plan/explicit bit-identity across maps, batch sizes, and cache settings;
capability-constrained replanning; save/load round trips (bit-identical
assignments, schema-version rejection) incl. GeoServer cold start.
"""
import dataclasses
import json
import os
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.artifact import (ARRAYS_NAME, MANIFEST_NAME,
                                 SCHEMA_VERSION, GeoIndexSet)
from repro.core.engine import EngineConfig, GeoEngine
from repro.core.plan import (HYBRID_BOUNDARY_FRAC, SHARD_MIN_POINTS,
                             covering_boundary_fraction, plan_for)
from repro.core.synth import build_synth_census
from repro.serving import GeoServer, ServeConfig

CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                   cap_block=1.0, cap_boundary=1.0, max_level=7)


def _assert_assign_equal(a, b):
    for field in ("state", "county", "block"):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)
    assert a.stats.as_dict() == b.stats.as_dict()


# -- planner unit behaviour --------------------------------------------------

def test_covering_boundary_fraction_area_weighted():
    """Interior cells count their whole leaf span; boundary cells are
    leaves — the fraction is area-share, not cell-count-share."""
    cov = SimpleNamespace(lo=np.array([0, 16, 17]),
                          hi=np.array([15, 16, 17]),
                          val=np.array([3, -1, -2]))
    assert covering_boundary_fraction(cov) == pytest.approx(2 / 18)


def test_plan_picks_hybrid_on_heavy_boundary_and_fast_on_light():
    light = SimpleNamespace(lo=np.array([0, 64]), hi=np.array([63, 64]),
                            val=np.array([1, -1]))
    heavy = SimpleNamespace(lo=np.array([0, 4]), hi=np.array([3, 7]),
                            val=np.array([1, -1]))
    p_light = plan_for(EngineConfig(), covering=light, device_kind="cpu")
    p_heavy = plan_for(EngineConfig(), covering=heavy, device_kind="cpu")
    assert p_light.strategy == "fast"
    assert p_heavy.strategy == "hybrid"
    assert p_heavy.boundary_fraction >= HYBRID_BOUNDARY_FRAC
    assert any("boundary fraction" in r for r in p_heavy.reasons)


def test_plan_fuses_on_tpu_not_cpu():
    cov = SimpleNamespace(lo=np.array([0, 64]), hi=np.array([63, 64]),
                          val=np.array([1, -1]))
    assert plan_for(EngineConfig(), covering=cov,
                    device_kind="tpu").fused
    assert not plan_for(EngineConfig(), covering=cov,
                        device_kind="cpu").fused


def test_plan_respects_capabilities():
    """Replanning against a built artifact never emits a plan the
    artifact cannot execute: no fast index -> cascade; no pool and no
    census to build one -> fused dropped even on TPU (with a census the
    pool is buildable via ensure(), so fused stays)."""
    caps_simple_only = {"census": True, "covering": False, "simple": True,
                        "fast": False, "simple_pool": False,
                        "fast_pool": False}
    p = plan_for(EngineConfig(), capabilities=caps_simple_only,
                 device_kind="tpu")
    assert p.strategy == "simple" and p.fused    # pool buildable
    cov = SimpleNamespace(lo=np.array([0, 64]), hi=np.array([63, 64]),
                          val=np.array([1, -1]))
    # No pool and no census to build one from -> fused dropped on TPU.
    caps_no_pool = {"census": False, "covering": True, "simple": False,
                    "fast": True, "simple_pool": False,
                    "fast_pool": False}
    p = plan_for(EngineConfig(), covering=cov,
                 capabilities=caps_no_pool, device_kind="tpu")
    assert p.strategy == "fast" and not p.fused
    assert any("pool" in r or "unusable" in r for r in p.reasons)
    # With the census present the pool is buildable (ensure() attaches
    # it after planning), so a TPU cold start keeps the fused kernel.
    caps_buildable = dict(caps_no_pool, census=True)
    p = plan_for(EngineConfig(), covering=cov,
                 capabilities=caps_buildable, device_kind="tpu")
    assert p.fused


def test_plan_recommends_sharding_on_big_batches_only():
    cov = SimpleNamespace(lo=np.array([0, 64]), hi=np.array([63, 64]),
                          val=np.array([1, -1]))
    big = plan_for(EngineConfig(), covering=cov, device_kind="cpu",
                   n_points=SHARD_MIN_POINTS, n_devices=4)
    small = plan_for(EngineConfig(), covering=cov, device_kind="cpu",
                     n_points=1024, n_devices=4)
    assert big.sharded and big.n_shards == 4
    assert not small.sharded and small.n_shards == 1


def test_explicit_build_records_pinned_plan(synth_small):
    eng = GeoEngine.build(synth_small.census, "simple", CFG)
    info = eng.explain()
    assert info["strategy"] == "simple" and info["auto"] is False
    # Capability-constrained replanning for a batch hint cannot leave
    # what the engine has built (no covering here -> cascade).
    hint = eng.explain(n_points=100_000)
    assert hint["strategy"] == "simple"


# -- auto == explicit bit-identity (satellite property test) -----------------

@pytest.mark.parametrize("seed,shape", [
    (3, dict(n_states=4, counties_per_state=3, blocks_per_county=6)),
    (9, dict(n_states=6, counties_per_state=2, blocks_per_county=10)),
])
def test_auto_plan_bit_identical_to_explicit(seed, shape):
    """Across maps with different (random) extents and batch sizes, the
    auto-built engine names a plan, and an engine explicitly configured
    to that plan produces bit-identical assignments and stats."""
    sc = build_synth_census(seed=seed, **shape)
    auto = GeoEngine.build(sc.census, "auto", CFG)
    info = auto.explain()
    assert info["auto"] is True and info["strategy"] in (
        "simple", "fast", "hybrid")
    explicit = GeoEngine.build(sc.census, info["strategy"],
                               auto.plan.apply(CFG),
                               covering=auto.covering)
    rng = np.random.default_rng(seed)
    for n in (64, 1000, 4096):
        xy, *_ = sc.sample_points(rng, n)
        _assert_assign_equal(auto.assign(jnp.asarray(xy)),
                             explicit.assign(jnp.asarray(xy)))


@pytest.mark.parametrize("cache", [False, True])
def test_auto_served_bit_identical_to_direct(synth_small, points_small,
                                             cache):
    """The auto plan holds through the serving stack, cache on and off:
    served ids == the auto engine's own direct assign."""
    auto = GeoEngine.build(synth_small.census, "auto",
                           dataclasses.replace(CFG, max_level=8))
    server = GeoServer(auto, ServeConfig(buckets=(64, 256, 1024),
                                         cache=cache))
    xy, *_ = points_small
    res = server.submit(xy[:900])
    direct = auto.assign(jnp.asarray(xy[:900]))
    np.testing.assert_array_equal(res.block, np.asarray(direct.block))
    np.testing.assert_array_equal(res.state, np.asarray(direct.state))


# -- GeoIndexSet artifact ----------------------------------------------------

def test_index_set_save_load_round_trip(synth_small, points_small,
                                        tmp_path):
    """Reloaded artifact -> re-derived indices -> bit-identical
    assignments, for the cascade and the (fused) cell index alike."""
    path = str(tmp_path / "art")
    idx = GeoIndexSet.build(synth_small.census,
                            components=("simple", "fast"),
                            pools=("simple", "fast"), max_level=7)
    idx.save(path)
    assert os.path.exists(os.path.join(path, MANIFEST_NAME))
    assert os.path.exists(os.path.join(path, ARRAYS_NAME))
    loaded = GeoIndexSet.load(path)
    assert loaded.max_level == 7
    np.testing.assert_array_equal(loaded.covering.lo, idx.covering.lo)
    np.testing.assert_array_equal(loaded.covering.val, idx.covering.val)
    assert loaded.census.extent == synth_small.census.extent
    xy, *_ = points_small
    pts = jnp.asarray(xy[:1500])
    fused_cfg = dataclasses.replace(CFG, fused=True)
    for strategy, cfg in (("simple", CFG), ("fast", fused_cfg),
                          ("hybrid", CFG)):
        before = GeoEngine.from_index_set(idx, strategy, cfg)
        after = GeoEngine.from_index_set(loaded, strategy, cfg)
        _assert_assign_equal(before.assign(pts), after.assign(pts))


def test_index_set_rejects_wrong_schema_and_foreign_dirs(synth_small,
                                                         tmp_path):
    path = str(tmp_path / "art")
    GeoIndexSet.build(synth_small.census, components=("fast",),
                      max_level=7).save(path)
    mpath = os.path.join(path, MANIFEST_NAME)
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["schema_version"] = SCHEMA_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="schema_version"):
        GeoIndexSet.load(path)
    with pytest.raises(ValueError, match="manifest"):
        GeoIndexSet.load(str(tmp_path / "empty"))


def test_geoserver_cold_start_from_artifact(synth_small, points_small,
                                            tmp_path):
    """The acceptance path: save, reload through GeoServer.from_artifact,
    and serve bit-identically to a server built from the live census."""
    path = str(tmp_path / "art")
    cfg = dataclasses.replace(CFG, max_level=8)
    live_eng = GeoEngine.build(synth_small.census, "auto", cfg)
    live_eng.indices.save(path)
    live = GeoServer(live_eng, ServeConfig(buckets=(64, 256, 1024)))
    cold = GeoServer.from_artifact(path, strategy="auto", engine_cfg=cfg,
                                   cfg=ServeConfig(buckets=(64, 256,
                                                            1024)))
    assert cold.regions[0].engine.explain()["strategy"] == \
        live_eng.explain()["strategy"]
    xy, *_ = points_small
    for lo, hi in ((0, 700), (700, 703), (703, 2048)):
        a = live.submit(xy[lo:hi])
        b = cold.submit(xy[lo:hi])
        np.testing.assert_array_equal(a.block, b.block)
        np.testing.assert_array_equal(a.county, b.county)
        np.testing.assert_array_equal(a.state, b.state)
        np.testing.assert_array_equal(a.region, b.region)


def test_engine_build_auto_names_plan(synth_small):
    """Acceptance: build(census, strategy='auto') returns a working
    engine whose explain() names the chosen plan with reasons."""
    eng = GeoEngine.build(synth_small.census, "auto", CFG)
    info = eng.explain()
    assert info["strategy"] in ("simple", "fast", "hybrid")
    assert info["reasons"] and all(isinstance(r, str)
                                   for r in info["reasons"])
    assert json.loads(json.dumps(info)) == info      # JSON-clean
    assert eng.strategy == info["strategy"]
