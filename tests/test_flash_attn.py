"""Fused flash-attention Pallas kernel vs the blockwise-jnp oracle,
sweeping shapes, tiles, GQA ratios and dtypes (interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attn
from repro.models.attention import blockwise_attn


@pytest.mark.parametrize("b,s,h,kh,d", [
    (2, 64, 4, 4, 16),
    (1, 128, 4, 2, 32),
    (2, 256, 8, 1, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_blockwise(b, s, h, kh, d, causal):
    rng = np.random.default_rng(b * s + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    want = blockwise_attn(q, k, v, causal=causal, chunk_q=32, chunk_kv=32)
    got = flash_attn(q, k, v, causal=causal, bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (64, 64)])
def test_flash_tile_invariance(bq, bk):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    a = flash_attn(q, k, v, bq=64, bk=64, interpret=True)
    c = flash_attn(q, k, v, bq=bq, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)


def test_flash_trainable_grads_match_blockwise():
    """custom-VJP flash: value from the kernel, grads match the blockwise
    reference's grads exactly (backward recomputes through it)."""
    import jax

    from repro.kernels.flash_attn import make_flash_attn_trainable
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    f = make_flash_attn_trainable(causal=True, bq=32, bk=32,
                                  interpret=True, chunk=32)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.square(f(q, k, v)))

    def loss_ref(q, k, v):
        kk = jnp.repeat(k, 2, axis=2)
        vv = jnp.repeat(v, 2, axis=2)
        return jnp.sum(jnp.square(
            blockwise_attn(q, kk, vv, causal=True, chunk_q=32,
                           chunk_kv=32)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


def test_flash_bf16_inputs():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.bfloat16)
    want = blockwise_attn(q, k, v, causal=True, chunk_q=32, chunk_kv=32)
    got = flash_attn(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got).astype(np.float32),
        np.asarray(want).astype(np.float32), atol=3e-2, rtol=3e-2)
    assert got.dtype == jnp.bfloat16
