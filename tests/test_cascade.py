"""One-pass fused cascade (kernels/cascade.py, strategy "fast_onepass"):
backend bit-identity (interpret vs the ref oracle), engine-level
agreement with the simple / fast / hybrid drivers, accounting parity
(``onepass_stats`` vs the two-phase schedule), exactness where
fast_exact's compaction caps overflow, padded / off-extent handling, and
the autotune manifest round trip (schema v2) with the planner reading
the recorded winner.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fast as fast_mod
from repro.core.artifact import SCHEMA_VERSION, GeoIndexSet
from repro.core.engine import EngineConfig, GeoEngine
from repro.core.plan import plan_for
from repro.core.resolve import onepass_stats
from repro.kernels import ops
from repro.serving.server import GeoServer

CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                   cap_block=1.0, cap_boundary=1.0, max_level=8)


@pytest.fixture(scope="module")
def engines(synth_small):
    census = synth_small.census
    fast = GeoEngine.build(census, "fast", CFG)
    cov = fast.covering            # share the host BFS across builds
    return {
        "fast": fast,
        "simple": GeoEngine.build(census, "simple", CFG, covering=cov),
        "hybrid": GeoEngine.build(census, "hybrid", CFG, covering=cov),
        "onepass": GeoEngine.build(census, "fast_onepass", CFG,
                                   covering=cov),
        # The EngineConfig spelling of the same plan.
        "onepass_cfg": GeoEngine.build(
            census, "fast", dataclasses.replace(CFG, fused="onepass"),
            covering=cov),
    }


def _ids(res):
    return tuple(np.asarray(a) for a in (res.state, res.county, res.block))


def _stats(res):
    return {k: int(v) for k, v in res.stats.as_dict().items()}


# ------------------------------------------------ engine-level bit-identity
def test_onepass_bitexact_vs_fast_exact(engines, points_small):
    """The acceptance bar: fast_onepass == fast_exact on ids AND the
    GeoStats counters (n_pip accounting included), not just accuracy."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    f = engines["fast"].assign(pts)
    o = engines["onepass"].assign(pts)
    for a, b in zip(_ids(f), _ids(o)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(_ids(o)[2], bid)
    assert _stats(f) == _stats(o)
    assert _stats(o)["overflow"] == 0 and _stats(o)["phase2_miss"] == 0


def test_onepass_cfg_spelling_is_the_same_plan(engines, points_small):
    """GeoEngine.build(census, "fast", EngineConfig(fused="onepass"))
    and strategy="fast_onepass" run the identical kernel path."""
    xy, *_ = points_small
    pts = jnp.asarray(xy)
    a = engines["onepass"].assign(pts)
    b = engines["onepass_cfg"].assign(pts)
    for x, y in zip(_ids(a), _ids(b)):
        np.testing.assert_array_equal(x, y)
    assert _stats(a) == _stats(b)


def test_onepass_agrees_with_simple_and_hybrid(engines, points_small):
    """Cross-driver agreement: the one-pass ids match the cascade and the
    hybrid drivers wherever those are exact (generous caps make them
    exact everywhere on the synthetic map)."""
    xy, bid, *_ = points_small
    pts = jnp.asarray(xy)
    o = np.asarray(engines["onepass"].assign(pts).block)
    np.testing.assert_array_equal(
        o, np.asarray(engines["simple"].assign(pts).block))
    np.testing.assert_array_equal(
        o, np.asarray(engines["hybrid"].assign(pts).block))
    np.testing.assert_array_equal(o, bid)


def test_onepass_padded_parity(engines, points_small):
    """assign_padded == assign on the valid prefix (ids and stats); pad
    rows come back -1."""
    xy, *_ = points_small
    pts = jnp.asarray(xy[:1000])
    padded = jnp.pad(pts, ((0, 24), (0, 0)))
    rp = engines["onepass"].assign_padded(padded, 1000)
    ru = engines["onepass"].assign(pts)
    for a, b in zip(_ids(rp), _ids(ru)):
        np.testing.assert_array_equal(a[:1000], b)
        assert (a[1000:] == -1).all()
    assert _stats(rp) == _stats(ru)


def test_onepass_rejects_off_extent(engines):
    """Points outside the quantization extent answer -1 at every level
    and never enter the boundary path (flags stay 0 in the raw op)."""
    x0, x1, y0, y1 = engines["fast"].census.extent
    far = jnp.asarray([[x1 + 1.0, y0], [x0 - 1.0, y1],
                       [x0, y1 + 2.0], [1e30, 1e30]], jnp.float32)
    res = engines["onepass"].assign(far)
    for a in _ids(res):
        assert (a == -1).all()
    idx = engines["onepass"].fast_index
    _, flags, nrest, nskip = ops.assign_cascade(
        far, idx.quant, idx.cell_lo, idx.cell_hi, idx.cell_val,
        idx.top_start, idx.cand, idx.block_bbox, idx.edge_pool,
        max_level=idx.max_level, gbits=idx.gbits,
        search_iters=idx.search_iters, backend="ref")
    assert (np.asarray(flags) == 0).all()
    assert (np.asarray(nrest) == 0).all()
    assert (np.asarray(nskip) == 0).all()


# ----------------------------------------------- kernel backend bit-identity
def test_interpret_matches_ref_bitexact(engines, points_small):
    """The Pallas kernel under interpret=True produces bit-identical
    (bid, flags, nrest, nskip) to the vectorized ref oracle — same fp32
    arithmetic, same candidate schedule, same DMA'd edge blocks."""
    xy, *_ = points_small
    idx = engines["onepass"].fast_index
    x0, _, y0, _ = engines["fast"].census.extent
    pts = np.concatenate([xy[:252].astype(np.float32),
                          [[x0 - 5.0, y0], [1e30, 1e30],
                           [x0 - 1.0, y0 - 1.0], [0.0, 1e30]]],
                         axis=0)
    outs = {}
    for backend in ("ref", "interpret"):
        outs[backend] = ops.assign_cascade(
            jnp.asarray(pts), idx.quant, idx.cell_lo, idx.cell_hi,
            idx.cell_val, idx.top_start, idx.cand, idx.block_bbox,
            idx.edge_pool, max_level=idx.max_level, gbits=idx.gbits,
            search_iters=idx.search_iters, backend=backend)
    for a, b in zip(outs["interpret"], outs["ref"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # The slice must exercise the boundary path for the parity to mean
    # anything.
    assert (np.asarray(outs["ref"][1]) & 1).sum() > 0


# ------------------------------------------------------ accounting parity
def test_onepass_stats_accounting():
    """``onepass_stats`` reproduces the two-phase n_pip formula from the
    kernel's raw counters: every boundary point pays the slot-0 test;
    slot-0 misses additionally pay each valid rest slot."""
    flags = jnp.asarray([0, 1, 3, 1, 0, 1], jnp.int32)   # bit0=boundary,
    nrest = jnp.asarray([9, 2, 4, 0, 9, 3], jnp.int32)   # bit1=slot0 hit
    nskip = jnp.asarray([9, 1, 0, 2, 9, 0], jnp.int32)
    st = onepass_stats(flags, nrest, nskip)
    assert int(st["n_boundary"]) == 4
    # slot-0 hits (row 2) pay 1 PIP; misses (rows 1, 3, 5) pay 1 + nrest.
    assert int(st["n_pip"]) == 4 + (2 + 0 + 3)
    assert int(st["overflow"]) == 0
    assert int(st["phase2_miss"]) == 0
    # Non-boundary rows (0, 4) never contribute, whatever their counters.
    assert int(st["bbox_skips"]) == 1 + 0 + 2


def test_onepass_exact_where_two_phase_overflows(engines, synth_small):
    """Feed more boundary-cell points than the two-phase compaction cap:
    fast_exact overflows (counted, degraded to the fallback candidate);
    the one-pass kernel has no compaction buffer, so it reports zero
    overflow and stays bit-identical to an uncapped fast_exact."""
    census = synth_small.census
    cov = engines["fast"].covering
    idx = engines["fast"].fast_index
    lo = np.asarray(cov.lo)
    codes = lo[np.asarray(cov.val) < 0][:512]
    ix, iy = fast_mod.demorton(jnp.asarray(codes.astype(np.int32)))
    q = np.asarray(idx.quant)
    pts = np.stack([q[0] + (np.asarray(ix) + 0.5) / q[2],
                    q[1] + (np.asarray(iy) + 0.5) / q[3]],
                   -1).astype(np.float32)
    pts = jnp.asarray(np.tile(pts, (2, 1)))          # ~1024 boundary pts
    small_cap = GeoEngine.build(
        census, "fast", dataclasses.replace(CFG, cap_boundary=0.01),
        covering=cov)
    capped = small_cap.assign(pts)
    assert _stats(capped)["overflow"] > 0
    one = engines["onepass"].assign(pts)
    full = engines["fast"].assign(pts)
    assert _stats(one)["overflow"] == 0
    np.testing.assert_array_equal(np.asarray(one.block),
                                  np.asarray(full.block))
    assert _stats(one)["n_boundary"] == pts.shape[0]


# ------------------------------------------- autotune manifest round trip
def test_tuning_roundtrip_and_planner(engines, synth_small, tmp_path):
    """record_tuning -> save -> load round-trips the autotune block
    (schema v2) and a reloaded artifact's auto plan follows the recorded
    winner for the matching device kind."""
    path = str(tmp_path / "tuned")
    iset = GeoIndexSet(census=synth_small.census,
                       covering=engines["fast"].covering, max_level=8)
    tuning = {"winner": "fast_onepass", "be": 128,
              "device_kind": jax.default_backend(),
              "pts_per_sec": 1.5e6, "roofline_fraction": 0.25,
              "recorded": "2026-08-08T00:00:00"}
    iset.record_tuning(tuning)
    iset.save(path)
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    assert manifest["schema_version"] == SCHEMA_VERSION == 2
    assert manifest["tuning"] == tuning

    i2 = GeoIndexSet.load(path)
    assert i2.tuning == tuning
    assert i2.pool_be() == 128
    eng = GeoEngine.from_index_set(i2, strategy="auto")
    assert eng.strategy == "fast_onepass"
    assert eng.plan.fused == "onepass"
    assert any("autotune" in r for r in eng.plan.reasons)
    # The tuned block size reaches the actual pool packing.
    assert eng.fast_index.edge_pool.be == 128


def test_planner_ignores_foreign_device_tuning():
    """A winner recorded on another device kind must not transfer."""
    caps = {"census": True, "covering": True, "fast": True,
            "fast_pool": True, "simple": False, "simple_pool": False,
            "sharded": []}
    tune = {"winner": "fast_onepass", "be": 256, "device_kind": "tpu"}
    here = plan_for(EngineConfig(), capabilities=caps, tuning=tune,
                    device_kind="cpu")
    assert here.strategy != "fast_onepass"
    there = plan_for(EngineConfig(), capabilities=caps, tuning=tune,
                     device_kind="tpu")
    assert there.strategy == "fast_onepass"
    assert there.fused == "onepass"


def test_load_accepts_v1_manifest(engines, synth_small, tmp_path):
    """A pre-tuning artifact (schema v1, no tuning block) still loads,
    with an empty tuning record and the default pool block size."""
    path = str(tmp_path / "v1")
    GeoIndexSet(census=synth_small.census,
                covering=engines["fast"].covering, max_level=8).save(path)
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["schema_version"] = 1
    del manifest["tuning"]
    json.dump(manifest, open(mpath, "w"))
    iset = GeoIndexSet.load(path)
    assert iset.tuning == {}
    assert iset.pool_be() == ops.DEF_BE


def test_load_rejects_unknown_schema(synth_small, tmp_path):
    path = str(tmp_path / "future")
    GeoIndexSet(census=synth_small.census).save(path)
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["schema_version"] = SCHEMA_VERSION + 1
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ValueError, match="schema_version"):
        GeoIndexSet.load(path)


# ------------------------------------------------------- serving surface
def test_server_surfaces_footprint_gauges(engines):
    """GeoServer exposes the built index's memory footprint (edge-pool
    bytes + chosen block size) as per-region gauges at construction."""
    srv = GeoServer(engines["onepass"])
    gauges = srv.metrics.snapshot()["gauges"]
    assert gauges["region0_pool_be"] == ops.DEF_BE
    assert gauges["region0_edge_pool_bytes"] > 0
    assert gauges["region0_edge_pool_blocks"] > 0
    assert gauges["region0_index_bytes"] > 0
