"""Concurrency battery for the AsyncGeoServer front-end (DESIGN.md §14):
MicroBatcher put/drain/requeue races, HotCellCache eviction under
contention, 8-thread bit-identity with the synchronous server (cache on
and off, single- and multi-region), async backpressure (shed + block),
retry/failure recovery, the deadline-flush loop, and lifecycle
(drain/close/context manager).

Every threaded test carries ``@pytest.mark.timeout`` (conftest's
thread-based deadline) so a deadlock fails in seconds instead of
hanging the suite; the sustained-load soak is ``@pytest.mark.load``
and runs only under ``--run-load``.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, GeoEngine
from repro.core.synth import build_synth_census
from repro.serving import (AsyncGeoServer, CellTable, FrontendConfig,
                           GeoServer, HotCellCache, MicroBatcher,
                           QueueFull, ServeConfig)

EXACT_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8)
FUSED_CFG = EngineConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                         cap_block=1.0, cap_boundary=1.0, max_level=8,
                         fused=True)
BUCKETS = (64, 256, 1024)
# Mixed request sizes: singletons, coalescing, and top-bucket splits.
STREAM = (1, 7, 300, 555, 1024, 113)


@pytest.fixture(scope="module")
def engine(synth_small):
    return GeoEngine.build(synth_small.census, "fast", FUSED_CFG)


@pytest.fixture(scope="module")
def two_regions_exact():
    """Two regional engines with FULL caps: bit-identity across batch
    compositions needs overflow-free engines (an overflowed candidate
    list is the one batching-dependent code path)."""
    scA = build_synth_census(seed=3, n_states=2, counties_per_state=2,
                             blocks_per_county=4,
                             extent=(-120.0, -100.0, 30.0, 45.0))
    scB = build_synth_census(seed=4, n_states=2, counties_per_state=2,
                             blocks_per_county=4,
                             extent=(-100.0, -80.0, 30.0, 45.0))
    return (scA, GeoEngine.build(scA.census, "fast", EXACT_CFG),
            scB, GeoEngine.build(scB.census, "fast", EXACT_CFG))


def _region_stats(server):
    return [s.as_dict() if s is not None else None for s in server.stats]


# -- MicroBatcher under contention (satellite 1) -----------------------------

@pytest.mark.timeout(60)
def test_batcher_stress_no_ticket_lost_or_duplicated():
    """N producers race put(wait=True) against a flusher that drains and
    sometimes requeues (simulated failed flush).  Every ticket's rows
    must be served exactly once, contiguously, and a ticket's slices
    must serve in request order even across a requeue (FIFO survives
    contention)."""
    b = MicroBatcher(buckets=BUCKETS, max_queue_points=512,
                     policy="block")
    n_producers, per_producer = 8, 40
    total = n_producers * per_producer
    sizes = {}                       # ticket -> request length
    served = []                      # (ticket, req_off, length) in order
    served_lock = threading.Lock()
    done = threading.Event()
    errors = []

    def producer(pid):
        rng = np.random.default_rng(100 + pid)
        try:
            for rix in range(per_producer):
                n = int(rng.integers(1, 150))
                t = (pid, rix)
                sizes[t] = n         # keyed writes from distinct threads
                pts = np.full((n, 2), pid, np.float32)
                while not b.put(t, pts, wait=True, timeout=5.0):
                    if done.is_set():
                        raise RuntimeError("flusher died while blocked")
        except Exception as e:       # pragma: no cover - failure path
            errors.append(e)
            done.set()

    def flusher():
        rng = np.random.default_rng(7)
        requeues_left = 25
        try:
            while not done.is_set():
                if not b.wait_for_work(timeout=0.05):
                    continue
                for mb in b.drain():
                    if requeues_left > 0 and rng.uniform() < 0.3:
                        requeues_left -= 1
                        b.requeue([(t, mb.points[bo:bo + ln], ro)
                                   for (t, ro, bo, ln) in mb.parts])
                        continue
                    with served_lock:
                        served.extend((t, ro, ln)
                                      for (t, ro, _, ln) in mb.parts)
                with served_lock:
                    n_tickets = len({t for t, _, _ in served})
                if n_tickets == total and not len(b):
                    done.set()
        except Exception as e:       # pragma: no cover - failure path
            errors.append(e)
            done.set()

    threads = [threading.Thread(target=producer, args=(p,))
               for p in range(n_producers)]
    threads.append(threading.Thread(target=flusher))
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors, errors
    assert done.is_set() and not any(t.is_alive() for t in threads)

    # Exactly-once, gap-free coverage of every request.
    coverage = {}
    order_ok = True
    last_off = {}
    for t, ro, ln in served:
        coverage.setdefault(t, []).append((ro, ln))
        # FIFO through requeue: a ticket's slices serve in request
        # order (offsets non-decreasing in the global serve sequence).
        order_ok &= ro >= last_off.get(t, 0)
        last_off[t] = ro
    assert order_ok
    assert len(coverage) == total
    for t, slices in coverage.items():
        slices.sort()
        pos = 0
        for ro, ln in slices:
            assert ro == pos, f"gap/overlap in {t}: {slices}"
            pos += ln
        assert pos == sizes[t], f"short serve of {t}"
    assert b.queued_points == 0


@pytest.mark.timeout(30)
def test_batcher_oldest_age_monotone_under_puts():
    """The deadline clock never moves backwards while the queue stays
    non-empty, whatever other producers do."""
    b = MicroBatcher(buckets=BUCKETS)
    b.put("anchor", np.zeros((2, 2), np.float32))
    stop = threading.Event()

    def churn():
        i = 0
        while not stop.is_set():
            b.put(("c", i), np.zeros((3, 2), np.float32))
            i += 1
            time.sleep(0.0005)

    t = threading.Thread(target=churn)
    t.start()
    try:
        last = 0.0
        for _ in range(200):
            age = b.oldest_age_s()
            assert age >= last
            last = age
    finally:
        stop.set()
        t.join(5)
    assert last > 0.0
    b.drain()
    assert b.oldest_age_s() == 0.0


# -- HotCellCache under contention (satellite 2) -----------------------------

@pytest.mark.timeout(60)
def test_cache_eviction_under_contention():
    """8 threads hammer learn/lookup on a capacity-16 cache: entries
    never exceed capacity, every hit returns the exact interior value,
    eviction happens, and no counter update is lost."""
    n_codes = 256
    table = CellTable(lo=np.arange(n_codes, dtype=np.int32),
                      hi=np.arange(n_codes, dtype=np.int32),
                      val=(np.arange(n_codes, dtype=np.int32) * 3 + 1),
                      quant=np.zeros(4, np.float32), max_level=8)
    cache = HotCellCache(table, capacity=16)
    truth = table.interior_value(np.arange(n_codes, dtype=np.int32))
    probes = [0] * 8                 # per-thread unique-probe counts
    errors = []

    def worker(wid):
        rng = np.random.default_rng(wid)
        try:
            for _ in range(60):
                codes = rng.integers(0, n_codes, 32).astype(np.int32)
                cache.learn(codes)
                assert len(cache) <= 16
                bid, hit = cache.lookup(codes)
                probes[wid] += len(np.unique(codes))
                # A hit is exact or it is corruption.
                np.testing.assert_array_equal(bid[hit], truth[codes][hit])
                assert np.all(bid[~hit] == -1)
        except Exception as e:       # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors, errors
    assert len(cache) <= 16
    assert cache.evictions > 0
    assert cache.insertions - cache.evictions == len(cache)
    # Lost read-modify-write updates would break this exact total.
    assert cache.hits + cache.misses == sum(probes)
    snap = cache.snapshot()
    assert snap["entries"] == len(cache)
    assert 0.0 <= snap["hit_rate"] <= 1.0


# -- bit-identity under concurrency (satellite 3, acceptance criterion) ------

def _compare_streams(sync_server, async_server, xy, request_sizes):
    """Drive the identical request stream through both servers (sequential
    prewarm first so the cache hit/miss sequence is deterministic), then
    the measured phase concurrently through the async pipeline; assert
    per-request ids and merged per-region GeoStats are identical."""
    # Prewarm: one full sequential pass each.  Both servers coalesce the
    # single request into the same micro-batch sequence, so the caches
    # learn identically; afterwards the measured phase's hit/miss
    # pattern is a pure function of each point.
    sync_server.submit(xy)
    async_server.submit(xy)

    reqs, off = [], 0
    for n in request_sizes:
        reqs.append(xy[off:off + n])
        off += n
    sync_res = [sync_server.submit(r) for r in reqs]
    futures = [async_server.submit_async(r) for r in reqs]
    assert async_server.drain(timeout=60)
    async_res = [f.result(timeout=5) for f in futures]

    for i, (s, a) in enumerate(zip(sync_res, async_res)):
        for field in ("state", "county", "block", "region"):
            np.testing.assert_array_equal(
                getattr(a, field), getattr(s, field),
                err_msg=f"request {i} field {field}")
    assert _region_stats(async_server) == _region_stats(sync_server)


@pytest.mark.timeout(120)
@pytest.mark.parametrize("cache", [False, True])
def test_async_bit_identical_single_region(engine, points_small, cache):
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=cache)
    sync_server = GeoServer(engine, cfg)
    with AsyncGeoServer(engine, cfg,
                        frontend=FrontendConfig(n_submitters=8,
                                                n_replicas=3)) as srv:
        _compare_streams(sync_server, srv, xy, STREAM)
        if cache:
            assert srv.cache_snapshot()["hits"] > 0
    # And both match the engine's direct answer (transitively the whole
    # concurrent pipeline is bit-identical to engine.assign).
    direct = engine.assign(jnp.asarray(xy[:64]))
    np.testing.assert_array_equal(
        sync_server.submit(xy[:64]).block, np.asarray(direct.block))


@pytest.mark.timeout(120)
@pytest.mark.parametrize("cache", [False, True])
def test_async_bit_identical_multi_region(two_regions_exact, cache):
    scA, engA, scB, engB = two_regions_exact
    xyA, *_ = scA.sample_points(np.random.default_rng(21), 900)
    xyB, *_ = scB.sample_points(np.random.default_rng(22), 900)
    inter = np.empty((1800, 2), np.float32)
    inter[0::2], inter[1::2] = xyA, xyB
    cfg = ServeConfig(buckets=BUCKETS, cache=cache)
    sync_server = GeoServer([engA, engB], cfg)
    with AsyncGeoServer([engA, engB], cfg,
                        frontend=FrontendConfig(n_submitters=8,
                                                n_replicas=2)) as srv:
        _compare_streams(sync_server, srv, inter, (13, 301, 555, 700, 231))


@pytest.mark.timeout(120)
def test_async_concurrent_submitters_bit_identical(engine, points_small):
    """The hardest interleaving: 8 client threads submitting racing
    requests (arrival order nondeterministic).  Per-request results must
    still equal the engine's direct per-request answer — the cache can
    reorder hits/misses across clients but never change a value."""
    xy, *_ = points_small
    rng = np.random.default_rng(5)
    reqs = []
    for _ in range(48):
        ix = rng.integers(0, len(xy), int(rng.integers(1, 400)))
        reqs.append(xy[ix])
    direct = [np.asarray(engine.assign(jnp.asarray(r)).block)
              for r in reqs]
    with AsyncGeoServer(engine, ServeConfig(buckets=BUCKETS, cache=True),
                        frontend=FrontendConfig(n_submitters=8,
                                                n_replicas=3)) as srv:
        futures = [None] * len(reqs)
        barrier = threading.Barrier(8)

        def client(cid):
            barrier.wait()
            for i in range(cid, len(reqs), 8):
                futures[i] = srv.submit_async(reqs[i])

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert srv.drain(timeout=60)
        for i, fut in enumerate(futures):
            np.testing.assert_array_equal(
                fut.result(timeout=5).block, direct[i],
                err_msg=f"request {i}")
        snap = srv.snapshot()
        assert snap["counters"]["requests"] == len(reqs)
        assert snap["counters"]["points_served"] \
            == sum(len(r) for r in reqs)


# -- async backpressure ------------------------------------------------------

@pytest.mark.timeout(30)
def test_async_shed_fails_future_with_queue_full(engine, points_small):
    """Under "shed", an overflowing request fails its future with
    QueueFull instead of blocking anyone; serving continues."""
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=False, policy="shed",
                      max_queue_points=64)
    # One submitter serializes puts; a huge flush trigger + deadline
    # parks the flusher so the overflow is deterministic.
    fe = FrontendConfig(n_submitters=1, flush_points=1 << 20,
                        max_delay_ms=10_000.0)
    with AsyncGeoServer(engine, cfg, frontend=fe) as srv:
        f1 = srv.submit_async(xy[:40])
        f2 = srv.submit_async(xy[40:120])          # 40 + 80 > 64: shed
        with pytest.raises(QueueFull):
            f2.result(timeout=5)
        srv.flush()
        assert len(f1.result(timeout=5).block) == 40
        snap = srv.snapshot()
        assert snap["counters"]["shed_requests"] == 1
        assert snap["counters"]["shed_points"] == 80


@pytest.mark.timeout(30)
def test_async_block_waits_for_room_and_completes(engine, points_small):
    """Under "block", the overflowing submitter sleeps until the flusher
    frees room — both requests complete, nothing is shed."""
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=False, policy="block",
                      max_queue_points=64, max_delay_ms=2.0)
    with AsyncGeoServer(engine, cfg,
                        frontend=FrontendConfig(n_submitters=2)) as srv:
        f1 = srv.submit_async(xy[:60])
        f2 = srv.submit_async(xy[60:160])          # blocks, then proceeds
        r1, r2 = f1.result(timeout=10), f2.result(timeout=10)
        direct = np.asarray(engine.assign(jnp.asarray(xy[:160])).block)
        np.testing.assert_array_equal(
            np.concatenate([r1.block, r2.block]), direct)
        assert srv.snapshot()["counters"].get("shed_requests", 0) == 0


# -- failure recovery / retry budget -----------------------------------------

class _FlakyAssign:
    """Thread-safe assign_padded wrapper failing the first ``n_fail``
    calls (replica threads race through it)."""

    def __init__(self, engine, n_fail):
        self._orig = engine.assign_padded
        self._lock = threading.Lock()
        self.n_fail = n_fail
        self.calls = 0

    def __call__(self, points, n_valid):
        with self._lock:
            self.calls += 1
            fail = self.calls <= self.n_fail
        if fail:
            raise RuntimeError("device lost")
        return self._orig(points, n_valid)


@pytest.mark.timeout(30)
def test_async_requeue_retries_failed_batch(engine, points_small,
                                            monkeypatch):
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=False, max_delay_ms=2.0)
    monkeypatch.setattr(engine, "assign_padded", _FlakyAssign(engine, 1))
    with AsyncGeoServer(engine, cfg) as srv:
        fut = srv.submit_async(xy[:100])
        res = fut.result(timeout=10)               # survives one failure
        snap = srv.snapshot()
    monkeypatch.undo()
    np.testing.assert_array_equal(
        res.block, np.asarray(engine.assign(jnp.asarray(xy[:100])).block))
    assert snap["counters"]["failed_flushes"] == 1
    assert snap["counters"].get("failed_requests", 0) == 0


@pytest.mark.timeout(30)
def test_async_retry_budget_exhaustion_fails_future(engine, points_small,
                                                    monkeypatch):
    """A permanently poisoned batch fails the future with the engine's
    exception after max_retries — no crash-loop — and the server keeps
    serving afterwards."""
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=False, max_delay_ms=2.0)
    flaky = _FlakyAssign(engine, 10 ** 9)
    monkeypatch.setattr(engine, "assign_padded", flaky)
    with AsyncGeoServer(engine, cfg,
                        frontend=FrontendConfig(max_retries=1)) as srv:
        fut = srv.submit_async(xy[:50])
        with pytest.raises(RuntimeError, match="device lost"):
            fut.result(timeout=10)
        snap = srv.snapshot()
        assert snap["counters"]["failed_requests"] == 1
        # attempt 1 + retry 1 = exactly max_retries + 1 serve attempts
        assert snap["counters"]["failed_flushes"] == 2
        assert srv.batcher.queued_points == 0      # nothing crash-loops
        monkeypatch.undo()
        ok = srv.submit(xy[:10], timeout=10)       # server still healthy
        np.testing.assert_array_equal(
            ok.block, np.asarray(engine.assign(jnp.asarray(xy[:10])).block))


# -- deadline loop / lifecycle -----------------------------------------------

@pytest.mark.timeout(30)
def test_async_deadline_loop_serves_trickle(engine, points_small):
    """A lone small request is served by the background deadline flusher
    with no flush()/drain() call from anyone."""
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=False, max_delay_ms=2.0)
    with AsyncGeoServer(engine, cfg) as srv:
        res = srv.submit_async(xy[:5]).result(timeout=10)
        assert len(res.block) == 5
        assert srv.snapshot()["counters"]["deadline_flushes"] >= 1


@pytest.mark.timeout(30)
def test_async_lifecycle_drain_close_empty(engine):
    cfg = ServeConfig(buckets=BUCKETS, cache=False)
    srv = AsyncGeoServer(engine, cfg)
    assert srv.drain(timeout=1)                    # idle server: True
    res = srv.submit(np.empty((0, 2), np.float32), timeout=5)
    assert res.block.shape == (0,)
    with pytest.raises(NotImplementedError):
        srv.enqueue(np.zeros((3, 2), np.float32))
    srv.close()
    srv.close()                                    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit_async(np.zeros((3, 2), np.float32))


@pytest.mark.timeout(60)
def test_async_close_serves_queued_work(engine, points_small):
    """close() drains in-flight work before stopping: every accepted
    future resolves."""
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=False, max_delay_ms=50.0)
    srv = AsyncGeoServer(engine, cfg,
                         frontend=FrontendConfig(n_submitters=4,
                                                 n_replicas=2))
    futures = [srv.submit_async(xy[i * 37:(i + 1) * 37])
               for i in range(20)]
    srv.close()
    for fut in futures:
        assert len(fut.result(timeout=5).block) == 37


# -- sustained load (opt-in: --run-load) -------------------------------------

@pytest.mark.load
@pytest.mark.timeout(120)
def test_sustained_load_soak(engine, points_small):
    """~2s of closed-loop 8-client traffic: every future resolves, ids
    match direct assign, points_in == points_served + shed."""
    xy, *_ = points_small
    cfg = ServeConfig(buckets=BUCKETS, cache=True, policy="shed",
                      max_queue_points=1 << 15, max_delay_ms=2.0)
    with AsyncGeoServer(engine, cfg,
                        frontend=FrontendConfig(n_submitters=8,
                                                n_replicas=3)) as srv:
        srv.warm()
        stop = time.perf_counter() + 2.0
        results, errors = [], []
        lock = threading.Lock()

        def client(cid):
            rng = np.random.default_rng(cid)
            while time.perf_counter() < stop:
                ix = rng.integers(0, len(xy), int(rng.integers(1, 256)))
                try:
                    res = srv.submit(xy[ix], timeout=30)
                    with lock:
                        results.append((ix, np.asarray(res.block)))
                except QueueFull:
                    pass
                except Exception as e:  # pragma: no cover - failure path
                    errors.append(e)
                    return

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors, errors
        assert srv.drain(timeout=60)
        assert len(results) > 50                   # actually sustained
        direct = np.asarray(engine.assign(jnp.asarray(xy)).block)
        for ix, got in results[::17]:              # spot-check identity
            np.testing.assert_array_equal(got, direct[ix])
        c = srv.snapshot()["counters"]
        assert c["points_in"] == c["points_served"] \
            + c.get("shed_points", 0)
