"""GeoLint analyzer battery (DESIGN.md §17).

Two halves:

* **seeded violations** — one fixture module per rule, written as inline
  source strings, asserting each rule fires exactly at the seeded line
  and that the annotation/suppression grammar silences it;
* **real-tree silence** — ``run_all`` over the actual repo returns zero
  findings (the acceptance bar the verify ratchet enforces), and the
  annotations in the tree match the DESIGN.md §14 lock table.

Plus unit + integration coverage for the runtime lock-order detector
(repro.analysis.lockcheck): cycle detection, unguarded-write capture on
the real serving classes, clean uninstall, and a subprocess rerun of a
real concurrency test under ``REPRO_LOCKCHECK=1``.
"""
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.analysis import (RULE_BOUNDARY, RULE_LOCKS, RULE_PURITY,
                            RULE_UNREACHABLE, RULE_UNUSED_IMPORT,
                            RULE_WALLCLOCK, SourceModule, check_boundary,
                            check_locks, check_purity, check_unreachable,
                            check_unused_imports, check_wallclock,
                            collect_guards, counts_by_rule, run_all)
from repro.analysis import lockcheck

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def mod(src: str, path: str = "fixture.py") -> SourceModule:
    return SourceModule(path, textwrap.dedent(src))


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# lock-discipline


LOCK_FIXTURE = '''
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0  # guarded-by: _lock
        self.m = 0  # guarded-by: _lock

    def bad(self):
        self.n += 1          # line 11: unguarded write

    def good(self):
        with self._lock:
            self.n += 1

    def helper(self):  # requires-lock: _lock
        self.m += 1

    def container(self):
        with self._lock:
            pass
        self.m = {}          # line 23: lock released again
'''


def test_lock_rule_fires_only_on_unguarded_writes():
    findings = check_locks([mod(LOCK_FIXTURE)])
    assert rules_of(findings) == [RULE_LOCKS, RULE_LOCKS]
    assert sorted(f.line for f in findings) == [11, 23]
    assert "self.n" in findings[0].message


def test_lock_rule_init_writes_exempt():
    quiet = '''
    import threading
    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0  # guarded-by: _lock
            self.n = 1          # still __init__: construction publishes
    '''
    assert check_locks([mod(quiet)]) == []


def test_lock_rule_closure_breaks_with_containment():
    fixture = '''
    import threading
    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0  # guarded-by: _lock
        def spawn(self):
            with self._lock:
                def later():
                    self.n += 1   # runs after the with exits
                return later
    '''
    findings = check_locks([mod(fixture)])
    assert rules_of(findings) == [RULE_LOCKS]


def test_lock_rule_shared_field_checked_cross_object():
    fixture = '''
    import threading
    import dataclasses

    @dataclasses.dataclass
    class Region:
        lock: threading.Lock
        stats: object = None  # guarded-by: lock

    def merge_bad(region, s):
        region.stats = s

    def merge_good(region, s):
        with region.lock:
            region.stats = s
    '''
    findings = check_locks([mod(fixture)])
    assert rules_of(findings) == [RULE_LOCKS]
    assert "region.stats" in findings[0].message


def test_lock_rule_suppression_needs_reason():
    base = '''
    import threading
    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0  # guarded-by: _lock
        def f(self):
            self.n += 1  {comment}
    '''
    with_reason = base.format(
        comment="# geolint: ignore[lock-discipline] -- benign: test rig")
    bare = base.format(comment="# geolint: ignore[lock-discipline]")
    assert check_locks([mod(with_reason)]) == []
    assert rules_of(check_locks([mod(bare)])) == [RULE_LOCKS]


# ---------------------------------------------------------------------------
# wallclock


def test_wallclock_fires_and_annotation_silences():
    bad = '''
    import time
    def latency():
        t0 = time.time()
        return time.time() - t0
    '''
    ok = '''
    import time
    def stamp():
        return time.time()  # wallclock-ok: event time
    def measure():
        return time.monotonic(), time.perf_counter()
    '''
    assert rules_of(check_wallclock([mod(bad)])) == \
        [RULE_WALLCLOCK, RULE_WALLCLOCK]
    assert check_wallclock([mod(ok)]) == []


def test_wallclock_sees_through_from_import():
    aliased = '''
    from time import time as now
    def f():
        return now()
    '''
    assert rules_of(check_wallclock([mod(aliased)])) == [RULE_WALLCLOCK]


# ---------------------------------------------------------------------------
# compat-boundary


def test_boundary_flags_private_and_gated_symbols():
    fixture = '''
    import jax
    from jax._src import mesh as mesh_lib

    def f(fn, mesh):
        jax.set_mesh(mesh)
        return jax.shard_map(f, check_rep=False)
    '''
    findings = check_boundary([mod(fixture)])
    msgs = " | ".join(f.message for f in findings)
    assert all(r == RULE_BOUNDARY for r in rules_of(findings))
    assert "jax._src" in msgs
    assert "jax.set_mesh" in msgs
    assert "check_rep" in msgs


def test_boundary_allows_compat_py():
    fixture = '''
    from jax._src import mesh as mesh_lib
    import jax
    jax.set_mesh(None)
    '''
    assert check_boundary([mod(fixture, path="src/repro/compat.py")]) == []


# ---------------------------------------------------------------------------
# trace-purity


def test_purity_flags_host_calls_in_jitted_functions():
    fixture = '''
    import time
    import functools
    import numpy as np
    import jax

    @functools.partial(jax.jit, static_argnames=("n",))
    def traced(x, n):
        helper(x)
        return np.sum(x)

    def helper(x):
        return time.time()

    def untraced(x):
        return np.sum(x), time.time()
    '''
    findings = check_purity([mod(fixture, path="src/fix.py")])
    assert sorted(rules_of(findings)) == [RULE_PURITY, RULE_PURITY]
    msgs = " | ".join(f.message for f in findings)
    assert "numpy.sum" in msgs            # direct np in the jit root
    assert "time.time" in msgs            # through the call-graph edge
    assert not any("untraced" in f.message for f in findings)


def test_purity_allows_static_numpy_and_flags_closure_mutation():
    fixture = '''
    import numpy as np
    import jax

    @jax.jit
    def ok(x):
        return x.astype(np.float32) * np.prod((2, 3))

    def make():
        calls = 0
        @jax.jit
        def counting(x):
            nonlocal calls
            calls += 1
            return x
        return counting
    '''
    findings = check_purity([mod(fixture, path="src/fix.py")])
    assert rules_of(findings) == [RULE_PURITY]
    assert "nonlocal" in findings[0].message


def test_purity_follows_pallas_call_kernels():
    fixture = '''
    import numpy as np
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = np.tanh(x_ref[...])

    def launch(x):
        return pl.pallas_call(kernel, out_shape=x)(x)
    '''
    findings = check_purity([mod(fixture, path="src/fix.py")])
    assert rules_of(findings) == [RULE_PURITY]
    assert "numpy.tanh" in findings[0].message


# ---------------------------------------------------------------------------
# dead code


def test_unused_import_rule_and_all_reexport():
    dead = '''
    import os
    import json

    def f():
        return os.sep
    '''
    reexport = '''
    from collections import OrderedDict

    __all__ = ["OrderedDict"]
    '''
    findings = check_unused_imports([mod(dead)])
    assert rules_of(findings) == [RULE_UNUSED_IMPORT]
    assert "json" in findings[0].message
    assert check_unused_imports([mod(reexport)]) == []


def test_unreachable_rule():
    fixture = '''
    def f(x):
        if x:
            return 1
        return 2
        x += 1
    '''
    findings = check_unreachable([mod(fixture)])
    assert rules_of(findings) == [RULE_UNREACHABLE]
    assert findings[0].line == 6


# ---------------------------------------------------------------------------
# the real tree


def test_real_tree_is_clean():
    findings = run_all(
        [os.path.join(REPO, "src", "repro")],
        [os.path.join(REPO, d)
         for d in ("benchmarks", "examples", "scripts", "tests")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_real_tree_guards_match_design_lock_table():
    """The # guarded-by: annotations ARE the §14 table — every class it
    names must carry guards, with the documented owning lock."""
    import glob
    guards = {}
    for path in glob.glob(os.path.join(REPO, "src", "repro", "**", "*.py"),
                          recursive=True):
        for g in collect_guards(SourceModule.load(path)):
            guards.setdefault(g.cls, set()).add((g.field, g.lock))
    assert ("_q", "_cond") in guards["MicroBatcher"]
    assert ("_map", "_lock") in guards["HotCellCache"]
    assert ("counters", "_lock") in guards["ServerMetrics"]
    assert ("_samples", "_lock") in guards["LatencyWindow"]
    assert ("_remaining", "_lock") in guards["_Ticket"]
    assert ("stats", "lock") in guards["_Region"]
    assert ("panes", "_lock") in guards["WindowedAggregator"]


def test_check_static_script_passes_on_tree():
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "check_static.py")],
        capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "check_static.py"),
         "--strict"],
        capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr


def test_counts_by_rule_keys_are_stable():
    counts = counts_by_rule([])
    assert set(counts) == {RULE_LOCKS, RULE_WALLCLOCK, RULE_BOUNDARY,
                           RULE_PURITY, RULE_UNUSED_IMPORT,
                           RULE_UNREACHABLE}


# ---------------------------------------------------------------------------
# runtime lock-order detector


@pytest.fixture
def instrumented():
    lockcheck.install()
    lockcheck.registry.reset()
    yield lockcheck.registry
    lockcheck.uninstall()


def test_lockcheck_cycle_detection(instrumented):
    a = lockcheck.wrap_lock(threading.Lock(), "A")
    b = lockcheck.wrap_lock(threading.Lock(), "B")
    with a:
        with b:
            pass
    assert instrumented.find_cycle() is None
    with b:
        with a:
            pass
    cycle = instrumented.find_cycle()
    assert cycle is not None and cycle[0] == cycle[-1]
    assert {"A", "B"} <= set(cycle)


def test_lockcheck_rlock_reentrance_is_not_a_cycle(instrumented):
    r = lockcheck.wrap_lock(threading.RLock(), "R")
    with r:
        with r:
            pass
    assert instrumented.find_cycle() is None


def test_lockcheck_catches_unguarded_write(instrumented):
    from repro.analytics.window import WindowedAggregator
    w = WindowedAggregator(16)
    assert instrumented.violations == []   # construction is exempt
    w.observed = 7
    assert len(instrumented.violations) == 1
    assert "WindowedAggregator.observed" in instrumented.violations[0]
    with w._lock:
        w.observed = 8                     # held: clean
    assert len(instrumented.violations) == 1


def test_lockcheck_real_batcher_cycle_is_clean(instrumented):
    from repro.serving.batcher import MicroBatcher
    from repro.serving.server import _Ticket
    b = MicroBatcher()
    t = _Ticket(4, 0.0)
    b.put(t, np.zeros((4, 2), np.float32))
    batch = b.drain()
    assert batch and instrumented.violations == []
    assert instrumented.find_cycle() is None


def test_lockcheck_uninstall_restores_classes():
    from repro.serving.batcher import MicroBatcher
    lockcheck.install()
    assert isinstance(MicroBatcher()._cond, lockcheck._InstrumentedLock)
    lockcheck.uninstall()
    assert isinstance(MicroBatcher()._cond, threading.Condition)


@pytest.mark.timeout(180)
def test_lockcheck_mode_passes_real_concurrency_test():
    """Integration: a real threaded serving test rerun under
    REPRO_LOCKCHECK=1 (the verify gate reruns the full frontend +
    analytics batteries the same way)."""
    env = dict(os.environ, REPRO_LOCKCHECK="1",
               PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x",
         "tests/test_analytics.py::test_window_rotation_out_of_order",
         "tests/test_analytics.py::test_k_anonymity_suppression"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=150)
    assert res.returncode == 0, res.stdout + res.stderr
