"""Model-math correctness: blockwise attention vs naive softmax, GQA
grouping, SWA masks, MLA decode-vs-train agreement, chunked SSM/mLSTM vs
recurrent references, MoE dispatch properties.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.configs.base import ModelConfig, RunConfig
from repro.models import ssm, xlstm
from repro.models.attention import blockwise_attn, decode_attn
from repro.models.module import init_params

hypothesis.settings.register_profile(
    "models", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("models")


def naive_attn(q, k, v, causal=True, window=None):
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k) / np.sqrt(d)
    sc = sc.astype(jnp.float32)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    sc = jnp.where(ok[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return o.reshape(b, s, h, d)


@pytest.mark.parametrize("s,h,kh,window", [(32, 4, 4, None), (33, 4, 2, None),
                                           (64, 8, 1, None), (48, 4, 4, 16)])
def test_blockwise_attn_matches_naive(s, h, kh, window):
    rng = np.random.default_rng(0)
    b, d = 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    want = naive_attn(q, k, v, causal=True, window=window)
    got = blockwise_attn(q, k, v, causal=True, window=window,
                         chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@hypothesis.given(chunk=st.sampled_from([8, 16, 32, 64]),
                  seed=st.integers(0, 10_000))
def test_blockwise_attn_chunk_invariance(chunk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 8)), jnp.float32)
    a = blockwise_attn(q, k, v, causal=True, chunk_q=64, chunk_kv=64)
    b = blockwise_attn(q, k, v, causal=True, chunk_q=chunk, chunk_kv=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_decode_attn_matches_last_row_of_blockwise():
    rng = np.random.default_rng(1)
    b, t, h, kh, d = 2, 24, 4, 2, 8
    q_full = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    full = blockwise_attn(q_full, k, v, causal=True, chunk_q=8, chunk_kv=8)
    dec = decode_attn(q_full[:, -1:], k, v, t)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-3)


# ----------------------------------------------------- ssm / xlstm oracles
def test_mamba2_chunked_equals_recurrent():
    cfg = ModelConfig(name="t", family="ssm_hybrid", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab=10,
                      ssm_state=16, ssm_head_dim=8)
    params = init_params(ssm.mamba2_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 24, 32)) * 0.5
    y = ssm.mamba2(params, cfg, x, chunk=8)
    st_ = ssm.mamba2_init_state(cfg, 2, 32)
    ys = []
    for t in range(24):
        yt, st_ = ssm.mamba2_step(params, cfg, x[:, t:t + 1], st_)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(ys, 1)),
                               atol=5e-4)


def test_mlstm_chunked_equals_recurrent():
    cfg = ModelConfig(name="t", family="xlstm", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab=10)
    params = init_params(xlstm.mlstm_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 24, 32)) * 0.5
    y = xlstm.mlstm(params, cfg, x, chunk=8)
    st_ = xlstm.mlstm_init_state(cfg, 2)
    ys = []
    for t in range(24):
        yt, st_ = xlstm.mlstm_step(params, cfg, x[:, t:t + 1], st_)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(ys, 1)),
                               atol=5e-4)


# ------------------------------------------------------------ moe dispatch
@hypothesis.given(seed=st.integers(0, 2**31 - 1),
                  n=st.integers(4, 200), nb=st.integers(1, 8),
                  cap=st.integers(1, 32))
def test_dispatch_roundtrip_properties(seed, n, nb, cap):
    """plan_routes/slot_tables invariants: kept items occupy unique slots;
    drops are exactly the over-capacity tail; combine preserves payload."""
    from repro.distributed.dispatch import gather_from_buckets, \
        plan_routes, scatter_to_buckets, slot_tables
    rng = np.random.default_rng(seed)
    buckets = jnp.asarray(rng.integers(0, nb + 1, n), jnp.int32)  # nb = drop
    plan = plan_routes(buckets, nb, cap)
    keep = np.asarray(plan.keep)
    flat = np.asarray(plan.flat_ix)
    # kept slots are unique and in range
    kept_slots = flat[keep]
    assert len(set(kept_slots.tolist())) == keep.sum()
    assert (kept_slots < nb * cap).all()
    # per-bucket counts respect capacity and drop accounting is exact
    b_np = np.asarray(buckets)
    expect_drop = sum(max(0, (b_np == i).sum() - cap) for i in range(nb))
    assert int(plan.n_dropped) == expect_drop
    # roundtrip: scatter payload then gather with weight 1 reproduces kept
    payload = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
    tabs = slot_tables(plan, nb, cap)
    buf = scatter_to_buckets(plan, payload, nb, cap, item_for_slot=tabs[0])
    out = gather_from_buckets(tabs, buf, n)
    out = np.asarray(out)
    # kept items come back exactly; dropped items are zero
    kept_items = np.zeros(n, bool)
    kept_items[np.asarray(plan.order)[keep]] = True
    np.testing.assert_allclose(out[kept_items],
                               np.asarray(payload)[kept_items], atol=1e-6)
    assert (out[~kept_items] == 0).all()


def test_moe_sharded_matches_local_subprocess():
    from subproc import assert_subprocess_ok
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models.moe import moe_spec, moe_ffn
from repro.models.module import init_params
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_test_mesh, use_mesh
for ne, mdl in ((8, 4), (2, 4)):   # EP and virtual-expert paths
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=0, vocab=10,
                      n_experts=ne, top_k=2, d_ff_expert=16,
                      capacity_factor=8.0)
    params = init_params(moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 8, 32), jnp.bfloat16)
    y_ref, aux_ref = moe_ffn(params, cfg, x)
    mesh = make_test_mesh((2, mdl))
    with use_mesh(mesh):
        y, aux = jax.jit(lambda p, x: moe_ffn(p, cfg, x, mesh=mesh))(params, x)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32))))
    assert err < 0.2, (ne, mdl, err)
    assert abs(float(aux["lb_loss"]) - float(aux_ref["lb_loss"])) < 1e-2
print("MOE_SHARDED_OK")
"""
    assert_subprocess_ok(code, "MOE_SHARDED_OK")
