"""Shared fixtures: a small synthetic census + points with ground truth.

NOTE: device count must stay 1 here (the multi-pod dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 in its own process).
Sharding tests spawn subprocesses with their own XLA_FLAGS.
"""
import numpy as np
import pytest

from repro.core.synth import build_synth_census


@pytest.fixture(scope="session")
def synth_small():
    return build_synth_census(seed=0, n_states=8, counties_per_state=4,
                              blocks_per_county=16)


@pytest.fixture(scope="session")
def synth_mid():
    return build_synth_census(seed=1, n_states=16, counties_per_state=8,
                              blocks_per_county=24)


@pytest.fixture(scope="session")
def points_small(synth_small):
    rng = np.random.default_rng(42)
    return synth_small.sample_points(rng, 4096)


@pytest.fixture(scope="session")
def points_mid(synth_mid):
    rng = np.random.default_rng(43)
    return synth_mid.sample_points(rng, 8192)
