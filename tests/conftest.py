"""Shared fixtures: a small synthetic census + points with ground truth.

Also provides two markers the concurrency battery relies on:

* ``@pytest.mark.load`` — sustained-load / soak tests, excluded from the
  default (tier-1) run; opt in with ``--run-load``.
* ``@pytest.mark.timeout(seconds)`` — per-test wall-clock deadline so a
  deadlocked threaded test fails fast instead of hanging the whole
  suite.  Implemented in-tree (the pytest-timeout plugin is not in the
  image): the test body runs on a daemon worker thread and the hook
  fails the test if it does not finish in time.  Only apply it to tests
  whose fixtures/teardown tolerate the test thread being abandoned —
  the serving tests do (daemon threads, in-process state only).

``REPRO_LOCKCHECK=1`` turns on the runtime lock-order detector
(repro.analysis.lockcheck, DESIGN.md §17): the serving/analytics locks
are wrapped once per session, and after every test the hook asserts
(a) no write to a ``# guarded-by:`` field was observed without its lock
held and (b) the accumulated acquisition-order graph is acyclic.

NOTE: device count must stay 1 here (the multi-pod dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 in its own process).
Sharding tests spawn subprocesses with their own XLA_FLAGS.
"""
import os
import threading

import numpy as np
import pytest

from repro.core.synth import build_synth_census

LOCKCHECK = os.environ.get("REPRO_LOCKCHECK") == "1"

if LOCKCHECK:
    from repro.analysis import lockcheck

    @pytest.fixture(autouse=True)
    def _lockcheck_guard():
        """Per-test lockcheck verdict: violations recorded during this
        test (plus any cycle in the session-wide acquisition graph)
        fail it.  Install is idempotent — first test pays it."""
        lockcheck.install()
        seen = len(lockcheck.registry.violations)
        yield
        fresh = lockcheck.registry.violations[seen:]
        cycle = lockcheck.registry.find_cycle()
        if fresh or cycle:
            lines = list(fresh)
            if cycle:
                lines.append(
                    f"lock acquisition-order cycle: {' -> '.join(cycle)}")
            pytest.fail("lockcheck: " + "; ".join(lines), pytrace=False)


def pytest_addoption(parser):
    parser.addoption("--run-load", action="store_true", default=False,
                     help="run @pytest.mark.load sustained-load tests")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "load: sustained-load test, skipped unless --run-load")
    config.addinivalue_line(
        "markers", "timeout(seconds): fail the test if its body runs "
                   "longer than this (thread-based, no pytest-timeout)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-load"):
        return
    skip = pytest.mark.skip(reason="load test: needs --run-load")
    for item in items:
        if "load" in item.keywords:
            item.add_marker(skip)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if marker is None:
        yield
        return
    seconds = float(marker.args[0]) if marker.args else 60.0
    outcome = []
    orig = item.runtest

    def run():
        try:
            orig()
            outcome.append(None)
        except BaseException as e:      # noqa: BLE001 — re-raised below
            outcome.append(e)

    # Replace runtest with a thread-joined wrapper; the surrounding
    # pytest machinery (setup/teardown, reporting) stays on the main
    # thread.  A daemon thread left behind on timeout cannot block
    # interpreter exit.

    def runtest_with_deadline():
        t = threading.Thread(target=run, daemon=True,
                             name=f"timeout:{item.name}")
        t.start()
        t.join(seconds)
        if t.is_alive():
            pytest.fail(f"test exceeded {seconds:g}s timeout "
                        f"(likely deadlock)", pytrace=False)
        if outcome and outcome[0] is not None:
            raise outcome[0]

    item.runtest = runtest_with_deadline
    try:
        yield
    finally:
        item.runtest = orig


@pytest.fixture(scope="session")
def synth_small():
    return build_synth_census(seed=0, n_states=8, counties_per_state=4,
                              blocks_per_county=16)


@pytest.fixture(scope="session")
def synth_mid():
    return build_synth_census(seed=1, n_states=16, counties_per_state=8,
                              blocks_per_county=24)


@pytest.fixture(scope="session")
def points_small(synth_small):
    rng = np.random.default_rng(42)
    return synth_small.sample_points(rng, 4096)


@pytest.fixture(scope="session")
def points_mid(synth_mid):
    rng = np.random.default_rng(43)
    return synth_mid.sample_points(rng, 8192)
