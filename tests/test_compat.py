"""The jax version-compat layer (repro/compat.py, DESIGN.md §12):
ambient-mesh tracking, shard_act edge cases as direct unit tests (these
previously had coverage only through full-model smokes), and a
multi-device regression test that the activation constraint is actually
applied inside ``use_mesh(...)`` scopes — on jax 0.4.x it used to no-op
silently because the bare ``Mesh`` was never recorded anywhere
``shard_act`` could see.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from subproc import assert_subprocess_ok

from repro import compat
from repro.launch.mesh import make_mesh
from repro.models.layers import BATCH, act_spec, shard_act


class StubMesh:
    """act_spec only needs axis_names + a name->size mapping."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


# ----------------------------------------------------- act_spec unit tests
def test_act_spec_axis_absent_from_mesh_is_dropped():
    # "pod" is not on the single-pod mesh: BATCH collapses to "data".
    spec = act_spec((8, 16), (BATCH, None), StubMesh(data=2, model=4))
    assert spec == PartitionSpec("data", None)


def test_act_spec_all_axes_absent_is_replicated():
    spec = act_spec((8, 16), (("pod",), "ring"), StubMesh(data=2, model=4))
    assert spec == PartitionSpec(None, None)


def test_act_spec_non_divisible_dim_is_replicated():
    # 6 % 4 != 0 -> replicate that entry; the divisible one still shards.
    spec = act_spec((6, 8), ("data", "model"), StubMesh(data=4, model=4))
    assert spec == PartitionSpec(None, "model")


def test_act_spec_multi_axis_extent():
    # ("pod","data") both present: extent 2*2=4 divides 8 -> tuple entry.
    spec = act_spec((8, 5), (BATCH, "model"), StubMesh(pod=2, data=2, model=4))
    assert spec == PartitionSpec(("pod", "data"), None)


def test_act_spec_fewer_parts_than_dims_pads_replicated():
    spec = act_spec((4, 4, 4), ("data",), StubMesh(data=2))
    assert spec == PartitionSpec("data")


# ------------------------------------------------- shard_act + ambient mesh
def test_shard_act_no_mesh_is_identity():
    x = jnp.ones((8, 16))
    assert compat.get_abstract_mesh() is None
    assert shard_act(x, BATCH, None) is x


def test_use_mesh_records_and_restores_ambient_mesh():
    mesh = make_mesh((1,), ("data",))
    assert compat.get_abstract_mesh() is None
    with compat.use_mesh(mesh):
        got = compat.get_abstract_mesh()
        assert got is not None and got.axis_names == ("data",)
        with compat.use_mesh(mesh):        # nests
            assert compat.get_abstract_mesh() is not None
    assert compat.get_abstract_mesh() is None


def test_shard_act_applies_constraint_under_single_device_mesh():
    mesh = make_mesh((1,), ("data",))
    x = jnp.ones((8, 16))
    with compat.use_mesh(mesh):
        y = jax.jit(lambda a: shard_act(a, BATCH, None))(x)
    want = NamedSharding(mesh, PartitionSpec("data", None))
    assert y.sharding.is_equivalent_to(want, y.ndim), y.sharding
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_with_sharding_constraint_no_mesh_noop():
    x = jnp.ones((4,))
    assert compat.with_sharding_constraint(x, PartitionSpec("data")) is x


def test_param_shardings_resolves_ambient_concrete_mesh():
    """``param_shardings(mesh=None)`` resolves the concrete mesh of the
    enclosing ``use_mesh`` scope, and is a loud error outside one."""
    import pytest

    from repro.models.module import P
    from repro.sharding.rules import param_shardings

    specs = {"w": P((4, 8), ("embed", None))}
    mesh = make_mesh((1,), ("data",))
    with compat.use_mesh(mesh):
        assert compat.concrete_mesh() is mesh
        sh = param_shardings(specs)
    assert sh["w"].mesh is mesh
    assert compat.concrete_mesh() is None
    with pytest.raises(ValueError, match="no ambient mesh"):
        param_shardings(specs)


# ------------------------------------------- multi-device regression tests
def test_shard_act_actually_shards_in_use_mesh_scope():
    """The satellite regression: on a fake (2,4) multi-device mesh the
    constraint must place the batch on "data" (4-row shards), not no-op."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec
from repro.compat import get_abstract_mesh, use_mesh
from repro.launch.mesh import make_test_mesh
from repro.models.layers import BATCH, shard_act

mesh = make_test_mesh((2, 4))
x = jnp.ones((8, 16))

with use_mesh(mesh):
    y = jax.jit(lambda a: shard_act(a, BATCH, None))(x)
# 4-row shards over the 2-way "data" axis — the constraint was applied
# (shard_shape, not is_equivalent_to: CPU jit outputs carry an explicit
# memory_kind that fails strict equivalence on some jax versions).
assert y.sharding.shard_shape(y.shape) == (4, 16), ("use_mesh", y.sharding)

# Raw `with Mesh(...):` scopes (never went through use_mesh) fall back to
# the resource-env mesh.
with mesh:
    z = jax.jit(lambda a: shard_act(a, BATCH, None, None))(
        jnp.ones((8, 4, 16)))
assert z.sharding.shard_shape(z.shape) == (4, 4, 16), ("mesh-cm", z.sharding)

# Non-divisible batch (7 rows on the 2-way data axis) replicates instead
# of crashing.
with use_mesh(mesh):
    w = jax.jit(lambda a: shard_act(a, BATCH, None))(jnp.ones((7, 16)))
assert w.sharding.shard_shape(w.shape) == (7, 16), w.sharding

# Outside every scope the ambient mesh is gone.
assert get_abstract_mesh() is None
print("AMBIENT_MESH_OK")
"""
    assert_subprocess_ok(code, "AMBIENT_MESH_OK")


def test_compat_shard_map_resolves_ambient_mesh_and_vma_kwarg():
    """compat.shard_map runs with the new-jax kwarg surface (mesh=None ->
    ambient mesh, check_vma) on whatever jax is installed."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as PS
from repro.compat import shard_map, use_mesh
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4))
x = jnp.ones((8, 16))

def body(a):
    # x is batch-sharded over "data" and replicated over "model": psum
    # over "data" alone gives the global sum.
    return jax.lax.psum(jnp.sum(a), "data")

with use_mesh(mesh):
    total = shard_map(body, in_specs=PS("data", None), out_specs=PS(),
                      check_vma=True)(x)
assert float(total) == 128.0, float(total)

try:
    shard_map(body, in_specs=PS("data", None), out_specs=PS())
    raised = False
except ValueError:
    raised = True
import jax as _j
if not hasattr(_j, "shard_map"):   # old jax: no ambient mesh -> loud error
    assert raised
print("COMPAT_SHARD_MAP_OK")
"""
    assert_subprocess_ok(code, "COMPAT_SHARD_MAP_OK")
