"""Full train step under a (2,4) mesh on 8 fake devices: loss matches the
single-device step, params stay finite, shardings are as declared.
Runs in a subprocess (device count locks at jax init)."""
from subproc import assert_subprocess_ok


def test_sharded_train_step_matches_local():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced_config
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.models.model import build_model
from repro.models.module import init_params
from repro.optim import adamw
from repro.runtime.steps import make_train_step
from repro.sharding.rules import input_shardings, param_shardings

run = RunConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32,
                ssm_chunk=16, learning_rate=1e-3, warmup_steps=1,
                total_steps=10)
for arch in ("qwen1.5-0.5b", "mixtral-8x7b"):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.key(0))
    opt = adamw.init(params)
    src = SyntheticLM(cfg=cfg, batch=8, seq=32)
    batch = src.batch_at(0)
    # local reference
    _, _, m_ref = jax.jit(make_train_step(model, run))(params, opt, batch)
    mesh = make_test_mesh((2, 4))
    with use_mesh(mesh):
        p_sh = param_shardings(model.specs, mesh)
        params_s = jax.device_put(params, p_sh)
        opt_s = adamw.init(params_s)
        step = jax.jit(make_train_step(model, run, mesh))
        p2, o2, m = step(params_s, opt_s, batch)
    dl = abs(float(m["loss"]) - float(m_ref["loss"]))
    assert dl < 5e-2, (arch, float(m["loss"]), float(m_ref["loss"]))
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(p2))
    # param shardings preserved through the update
    for got, want in zip(jax.tree.leaves(p2), jax.tree.leaves(p_sh)):
        assert got.sharding.is_equivalent_to(want, got.ndim), (arch, got.sharding, want)
    print(arch, "OK dloss", dl)
print("SHARDED_TRAIN_OK")
"""
    assert_subprocess_ok(code, "SHARDED_TRAIN_OK")
