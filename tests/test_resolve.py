"""Unit tests for the shared resolution core (core/resolve.py): overflow
accounting, sentinel candidates, PIP-schedule equivalence, and parity with
the fp64 host oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.geometry import point_in_polygon_host
from repro.core.resolve import (ResolveStats, first_k_candidates,
                                resolve_candidates)
from repro.kernels import ops


def star_polygon(rng, n_verts, cx=0.0, cy=0.0, r0=0.5, r1=1.5):
    th = np.sort(rng.uniform(0, 2 * np.pi, n_verts))
    th += np.arange(n_verts) * 1e-9
    r = rng.uniform(r0, r1, n_verts)
    return np.stack([cx + r * np.cos(th), cy + r * np.sin(th)], -1)


@pytest.fixture(scope="module")
def poly_world():
    """Four star polygons on a 2x2 grid + points + the [P, E, 4] table."""
    rng = np.random.default_rng(0)
    centers = [(-2.0, -2.0), (2.0, -2.0), (-2.0, 2.0), (2.0, 2.0)]
    rings = [star_polygon(rng, 24, cx, cy) for cx, cy in centers]
    e = max(len(r) for r in rings)
    edges = np.zeros((len(rings), e, 4), np.float32)
    for p, ring in enumerate(rings):
        nxt = np.roll(ring, -1, axis=0)
        edges[p, :len(ring)] = np.concatenate([ring, nxt], -1)
        edges[p, len(ring):] = np.concatenate([ring[:1], ring[:1]], -1)
    pts = rng.uniform(-4.0, 4.0, (512, 2)).astype(np.float32)
    return rings, jnp.asarray(edges), pts


def oracle_first_match(rings, pts, cand_ids):
    """First candidate (slot order) containing each point, per fp64 host
    oracle; -1 if none."""
    out = np.full(len(pts), -1, np.int32)
    for i, (x, y) in enumerate(pts):
        for pid in cand_ids[i]:
            if pid < 0:
                continue
            if point_in_polygon_host(np.array([x]), np.array([y]),
                                     rings[pid])[0]:
                out[i] = pid
                break
    return out


def all_cands(n, n_poly):
    return jnp.tile(jnp.arange(n_poly, dtype=jnp.int32)[None, :], (n, 1))


def test_parity_with_host_oracle(poly_world):
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.ones((n,), bool)
    expect = oracle_first_match(rings, pts, np.asarray(cand))
    for two_phase in (False, True):
        assign, stats = resolve_candidates(
            jnp.asarray(pts), cand, edges, need, cap=n, backend="ref",
            two_phase=two_phase, cap2=n)
        np.testing.assert_array_equal(np.asarray(assign), expect)
        assert int(stats.overflow) == 0
        assert int(stats.n_need) == n


def test_two_phase_matches_sequential(poly_world):
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.asarray(np.arange(n) % 3 != 0)     # a non-trivial subset
    seq, _ = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                cap=n, backend="ref", two_phase=False)
    two, _ = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                cap=n, backend="ref", two_phase=True,
                                cap2=n)
    np.testing.assert_array_equal(np.asarray(seq), np.asarray(two))


def test_overflow_accounting_exact(poly_world):
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.ones((n,), bool)
    cap = 256
    assign, stats = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                       cap=cap, backend="ref")
    assert int(stats.n_need) == n
    assert int(stats.overflow) == n - cap
    # Overflowed rows (beyond the first `cap` needed points) keep prior.
    np.testing.assert_array_equal(np.asarray(assign)[cap:], -1)


def test_sentinel_candidates_never_match(poly_world):
    rings, edges, pts = poly_world
    n = len(pts)
    cand = jnp.full((n, 4), -1, jnp.int32)
    need = jnp.ones((n,), bool)
    prior = jnp.arange(n, dtype=jnp.int32)
    assign, stats = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                       cap=n, backend="ref", prior=prior,
                                       fallback="prior")
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(prior))
    assert int(stats.n_pip) == 0


def test_fallback_first_uses_slot0(poly_world):
    """Points outside every candidate get the slot-0 candidate under
    fallback="first" (the centre-owner policy of the cell index)."""
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.ones((n,), bool)
    expect = oracle_first_match(rings, pts, np.asarray(cand))
    assign, _ = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                   cap=n, backend="ref", fallback="first")
    a = np.asarray(assign)
    np.testing.assert_array_equal(a[expect >= 0], expect[expect >= 0])
    np.testing.assert_array_equal(a[expect < 0], 0)   # slot-0 candidate


def test_candidate_callable_after_compaction(poly_world):
    """A callable candidate table sees only compacted rows and must agree
    with the precomputed-array form."""
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.asarray(np.arange(n) % 2 == 0)
    a1, _ = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                               cap=n, backend="ref")
    seen_rows = []

    def cand_fn(idx, sub_pts):
        seen_rows.append(sub_pts.shape[0])
        return cand[idx]

    a2, _ = resolve_candidates(jnp.asarray(pts), cand_fn, edges, need,
                               cap=256, backend="ref")
    np.testing.assert_array_equal(np.asarray(a1)[np.asarray(need)],
                                  np.asarray(a2)[np.asarray(need)])
    assert seen_rows == [256]      # evaluated on the compacted buffer only


def test_first_k_candidates_slots():
    mask = jnp.asarray(np.array([[0, 1, 0, 1, 1],
                                 [0, 0, 0, 0, 0],
                                 [1, 0, 0, 0, 1]], np.int8))
    out = np.asarray(first_k_candidates(mask, 2))
    np.testing.assert_array_equal(out, [[1, 3], [-1, -1], [0, 4]])


def test_resolve_stats_is_pytree():
    import jax
    st = ResolveStats(n_need=jnp.int32(3), n_pip=jnp.int32(5),
                      overflow=jnp.int32(0), phase2_miss=jnp.int32(0))
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == 4


# ------------------------------------------------------- phase-2 capacity
def test_phase2_miss_counted_not_silent(poly_world):
    """Slot-0 misses beyond cap2 degrade to the fallback AND are counted
    in the dedicated phase2_miss stat (ROADMAP: no silent degradation)."""
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.ones((n,), bool)
    # Generous cap2: every slot-0 miss gets a phase-2 slot.
    _, full = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                 cap=n, backend="ref", two_phase=True,
                                 cap2=n)
    assert int(full.phase2_miss) == 0
    # How many points actually miss slot 0?
    in0 = np.asarray(ops.pip_gathered(
        jnp.asarray(pts), edges[np.asarray(cand)[:, 0]], backend="ref"))
    n_miss = int((~in0).sum())
    assert n_miss > 0                     # the fixture guarantees misses
    cap2 = 8
    a_tight, tight = resolve_candidates(jnp.asarray(pts), cand, edges,
                                        need, cap=n, backend="ref",
                                        two_phase=True, cap2=cap2)
    assert int(tight.phase2_miss) == n_miss - cap2
    # Missed points still answered via the fallback, not dropped.
    assert int(tight.overflow) == 0
    assert (np.asarray(a_tight) >= -1).all()


def test_phase2_miss_zero_for_sequential(poly_world):
    rings, edges, pts = poly_world
    n = len(pts)
    cand = all_cands(n, len(rings))
    _, stats = resolve_candidates(jnp.asarray(pts), cand, edges,
                                  jnp.ones((n,), bool), cap=n,
                                  backend="ref", two_phase=False)
    assert int(stats.phase2_miss) == 0


# ------------------------------------------------------- fused gather-PIP
@pytest.mark.parametrize("two_phase", [False, True])
def test_fused_edge_pool_matches_legacy(poly_world, two_phase):
    """resolve_candidates(edge_pool=...) routes PIP through the fused
    gather-PIP kernel and must reproduce the legacy gather flow exactly,
    on both schedules."""
    rings, edges, pts = poly_world
    pool = ops.build_edge_pool(np.asarray(edges), be=128)
    n = len(pts)
    cand = all_cands(n, len(rings))
    need = jnp.asarray(np.arange(n) % 3 != 0)
    legacy, ls = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                    cap=n, backend="ref",
                                    two_phase=two_phase, cap2=n)
    fused, fs = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                   cap=n, backend="ref",
                                   two_phase=two_phase, cap2=n,
                                   edge_pool=pool)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(fused))
    assert int(ls.n_pip) == int(fs.n_pip)


@pytest.mark.parametrize("two_phase", [False, True])
@pytest.mark.parametrize("cap2", [256, 8])
def test_fused_sort_by_candidate_bit_identical_under_compaction(
        poly_world, two_phase, cap2):
    """The fused path runs each kernel call in candidate-id-sorted order
    for block-DMA reuse (PR 2 open item); the permutation is unwound
    inside the call, so with a real capacity compaction in play — and
    even with a tiny cap2 that overflows the phase-2 compaction — the
    assignments AND stats stay bit-identical to the legacy unsorted
    gather flow."""
    rings, edges, pts = poly_world
    pool = ops.build_edge_pool(np.asarray(edges), be=128)
    n = len(pts)
    rng = np.random.default_rng(5)
    # Shuffled candidate rows -> the sort actually permutes the buffer.
    cand = jnp.asarray(rng.permuted(
        np.tile(np.arange(len(rings), dtype=np.int32), (n, 1)), axis=1))
    need = jnp.asarray(rng.random(n) < 0.7)
    cap = 256
    assert cap < int(np.asarray(need).sum())     # compaction overflows
    legacy, ls = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                    cap=cap, backend="ref",
                                    two_phase=two_phase, cap2=cap2)
    fused, fs = resolve_candidates(jnp.asarray(pts), cand, edges, need,
                                   cap=cap, backend="ref",
                                   two_phase=two_phase, cap2=cap2,
                                   edge_pool=pool)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(fused))
    for field in ("n_need", "n_pip", "overflow", "phase2_miss"):
        assert int(getattr(ls, field)) == int(getattr(fs, field)), field


def test_fused_edge_pool_interpret_backend(poly_world):
    """The fused path under the Pallas interpret backend is bit-exact with
    the ref oracle end-to-end through resolve_candidates (small buffer:
    the per-point interpret grid is unrolled at trace time)."""
    rings, edges, pts = poly_world
    pool = ops.build_edge_pool(np.asarray(edges), be=128)
    n = 64
    cand = all_cands(n, len(rings))
    need = jnp.ones((n,), bool)
    sub = jnp.asarray(pts[:n])
    a, _ = resolve_candidates(sub, cand, edges, need, cap=n,
                              backend="ref", edge_pool=pool)
    b, _ = resolve_candidates(sub, cand, edges, need, cap=n,
                              backend="interpret", edge_pool=pool)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
