"""End-to-end correctness of the simple and fast mapping approaches against
synthetic ground truth, plus the paper's headline structural claims.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cells import build_cell_covering
from repro.core.fast import FastConfig, FastIndex, assign_fast
from repro.core.simple import SimpleConfig, SimpleIndex, assign_simple


@pytest.fixture(scope="module")
def simple_index(synth_small):
    return SimpleIndex.from_census(synth_small.census)


@pytest.fixture(scope="module")
def covering(synth_small):
    return build_cell_covering(synth_small.census, max_level=8, max_cand=8)


@pytest.fixture(scope="module")
def fast_index(covering, synth_small):
    return FastIndex.from_covering(covering, synth_small.census, gbits=4)


def test_simple_exact_vs_ground_truth(simple_index, points_small):
    xy, bid, cid, sid = points_small
    cfg = SimpleConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                       cap_block=1.0)
    s, c, b, stats = assign_simple(simple_index, jnp.asarray(xy), cfg)
    np.testing.assert_array_equal(np.asarray(s), sid)
    np.testing.assert_array_equal(np.asarray(c), cid)
    np.testing.assert_array_equal(np.asarray(b), bid)
    for lvl in ("state", "county", "block"):
        assert int(stats[lvl]["overflow"]) == 0


def test_simple_capacity_overflow_is_reported(simple_index, points_small):
    xy, *_ = points_small
    cfg = SimpleConfig(backend="ref", cap_state=0.01, cap_county=0.01,
                       cap_block=0.01)
    *_, stats = assign_simple(simple_index, jnp.asarray(xy), cfg)
    # With absurdly small capacity some level must overflow (and say so).
    total = sum(int(stats[lvl]["overflow"]) for lvl in stats)
    assert total > 0


def test_simple_pip_fraction_close_to_paper(simple_index, points_mid,
                                            synth_mid):
    """Paper §III: ~20 % of points need a PIP test at a level (~0.2/pt)."""
    xy, *_ = points_mid
    idx = SimpleIndex.from_census(synth_mid.census)
    cfg = SimpleConfig(backend="ref", cap_state=1.0, cap_county=1.0,
                       cap_block=1.0)
    *_, stats = assign_simple(idx, jnp.asarray(xy), cfg)
    for lvl in ("state", "county", "block"):
        frac = int(stats[lvl]["n_multi"]) / len(xy)
        assert 0.05 < frac < 0.40, (lvl, frac)


def test_covering_is_partition(covering):
    covering.validate_partition()


def test_fast_exact_vs_ground_truth(fast_index, points_small):
    xy, bid, cid, sid = points_small
    cfg = FastConfig(mode="exact", cap_boundary=1.0, backend="ref")
    s, c, b, stats = assign_fast(fast_index, jnp.asarray(xy), cfg)
    np.testing.assert_array_equal(np.asarray(b), bid)
    np.testing.assert_array_equal(np.asarray(c), cid)
    np.testing.assert_array_equal(np.asarray(s), sid)
    assert int(stats["overflow"]) == 0


def test_fast_true_hit_filtering_beats_simple(fast_index, simple_index,
                                              points_small):
    """The paper's §IV claim: interior cells resolve most points with zero
    PIP tests, so the fast approach does fewer PIP evals than simple."""
    xy, *_ = points_small
    _, _, _, fstats = assign_fast(fast_index, jnp.asarray(xy),
                                  FastConfig(mode="exact", cap_boundary=1.0,
                                             backend="ref"))
    _, _, _, sstats = assign_simple(simple_index, jnp.asarray(xy),
                                    SimpleConfig(backend="ref", cap_state=1.0,
                                                 cap_county=1.0,
                                                 cap_block=1.0))
    fast_pip = int(fstats["n_pip"])
    simple_pip = sum(int(sstats[lvl]["n_pip"]) for lvl in sstats)
    assert fast_pip < simple_pip


def test_fast_approx_error_bounded(fast_index, covering, synth_small,
                                   points_small):
    """Approximate mode: wrong assignments only for boundary-cell points,
    and the assigned block is within one leaf-cell diagonal of the point."""
    xy, bid, *_ = points_small
    s, c, b, _ = assign_fast(fast_index, jnp.asarray(xy),
                             FastConfig(mode="approx", backend="ref"))
    b = np.asarray(b)
    wrong = b != bid
    # Error rate is bounded by the boundary-cell hit rate.
    _, _, _, st = assign_fast(fast_index, jnp.asarray(xy),
                              FastConfig(mode="exact", cap_boundary=1.0,
                                         backend="ref"))
    assert wrong.mean() <= int(st["n_boundary"]) / len(xy) + 1e-9
    # Distance from a wrongly-assigned point to its assigned block's bbox is
    # within the leaf cell diagonal (the paper's precision guarantee).
    x0, x1, y0, y1 = synth_small.census.extent
    n = 1 << covering.max_level
    diag = np.hypot((x1 - x0) / n, (y1 - y0) / n)
    bb = synth_small.census.blocks.bbox
    for i in np.nonzero(wrong)[0]:
        box = bb[b[i]]
        dx = max(box[0] - xy[i, 0], 0, xy[i, 0] - box[1])
        dy = max(box[2] - xy[i, 1], 0, xy[i, 1] - box[3])
        assert np.hypot(dx, dy) <= diag + 1e-6


def test_fast_gbits_variants_agree(covering, synth_small, points_small):
    """F1/F2/F4 analogue: top-grid depth changes perf, never results."""
    xy, *_ = points_small
    outs = []
    for gbits in (0, 2, 5):
        idx = FastIndex.from_covering(covering, synth_small.census,
                                      gbits=gbits)
        _, _, b, _ = assign_fast(idx, jnp.asarray(xy),
                                 FastConfig(mode="exact", cap_boundary=1.0,
                                            backend="ref"))
        outs.append(np.asarray(b))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_hierarchy_consistency(simple_index, points_small, synth_small):
    """block -> county -> state derived parents must match direct assigns."""
    xy, *_ = points_small
    s, c, b, _ = assign_simple(simple_index, jnp.asarray(xy),
                               SimpleConfig(backend="ref", cap_state=1.0,
                                            cap_county=1.0, cap_block=1.0))
    blocks = synth_small.census.blocks
    counties = synth_small.census.counties
    np.testing.assert_array_equal(blocks.parent[np.asarray(b)],
                                  np.asarray(c))
    np.testing.assert_array_equal(counties.parent[np.asarray(c)],
                                  np.asarray(s))
