"""One real optimizer step for every architecture family: gradients must
flow (finite, params change) through Mamba2 chunked scans, mLSTM/sLSTM,
enc-dec cross-attention, VLM gated cross-attention, MLA and MoE dispatch —
not just the dense path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.models.module import init_params
from repro.optim import adamw
from repro.runtime.steps import make_train_step

RUN = RunConfig(remat="none", attn_chunk_q=16, attn_chunk_kv=16,
                ssm_chunk=8, learning_rate=1e-3, warmup_steps=1,
                total_steps=10)

FAMILY_REPS = ("yi-9b",                 # dense GQA
               "seamless-m4t-medium",   # enc-dec
               "llama-3.2-vision-90b",  # vlm cross-attn
               "zamba2-1.2b",           # mamba2 hybrid
               "xlstm-1.3b",            # mLSTM + sLSTM
               "deepseek-v2-236b")      # MLA + MoE


@pytest.mark.parametrize("name", FAMILY_REPS)
def test_one_train_step_grads_flow(name):
    cfg = get_reduced_config(name)
    model = build_model(cfg)
    params = init_params(model.specs, jax.random.key(0))
    opt = adamw.init(params)
    src = SyntheticLM(cfg=cfg, batch=2, seq=16)
    step = jax.jit(make_train_step(model, RUN))
    batch = src.batch_at(0)
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m1["grad_norm"]) > 0
    # Every parameter leaf must receive a finite update...
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert bool(jnp.all(jnp.isfinite(b))), name
    # ...and the model must actually learn the repeated batch a little.
    assert float(m2["loss"]) < float(m1["loss"]) + 1e-3, (name,
                                                          float(m1["loss"]),
                                                          float(m2["loss"]))
    # No dead subtrees: the overwhelming majority of leaves move.
    moved = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))]
    assert np.mean(moved) > 0.9, (name, np.mean(moved))
