"""Shared harness for tests that must run in a fresh interpreter (jax
locks the fake-device count at first init, so multi-device cases cannot
run in the main pytest process).

``assert_subprocess_ok`` replaces the old pattern of asserting on
``CompletedProcess.stdout`` directly, which buried the child's real
traceback inside a giant repr (or dropped it entirely) when the child
died: on failure it raises with labelled tails of BOTH streams, so the
first line of pytest's short summary shows the child's actual error.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, *, extra_env: dict | None = None,
           timeout: float = 600.0) -> subprocess.CompletedProcess:
    """Run ``code`` with a fresh interpreter from the repo root with
    PYTHONPATH=src (the child picks its own XLA_FLAGS before importing
    jax — that must happen before any jax import, hence in the child)."""
    env = {**os.environ, "PYTHONPATH": "src", **(extra_env or {})}
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, cwd=REPO_ROOT, env=env,
                          timeout=timeout)


def assert_subprocess_ok(code: str, sentinel: str, **kwargs) -> str:
    """Run ``code`` and require ``sentinel`` on its stdout.

    Failure surfaces the child's exit status, stdout tail and stderr tail
    (where python writes the traceback) instead of a bare repr.
    Returns the child's stdout for further assertions.
    """
    out = run_py(code, **kwargs)
    if sentinel not in out.stdout:
        raise AssertionError(
            f"subprocess never printed sentinel {sentinel!r} "
            f"(exit status {out.returncode})\n"
            f"--- child stdout (tail) ---\n{out.stdout[-2000:]}\n"
            f"--- child stderr (tail) ---\n{out.stderr[-4000:]}")
    return out.stdout
