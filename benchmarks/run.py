"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig6,...] [--fast]

Prints ``name,us_per_call,derived`` CSV rows.  Sections:
  fig4   simple approach: single-device rate vs number of points
  fig5   simple approach: device scaling (structural proxy; see note)
  fig6   fast approach: single-device rate vs number of points (F-variants)
  fig7   fast approach: device scaling + sharded index
  table1 index memory for exact/approx/fanout/sharded variants
  claim  ~0.2 PIP evaluations per point (paper §III)
  lm     train/serve step times for reduced LM archs
  roofline  (separate: python -m benchmarks.roofline results/dryrun.json)
"""
import argparse
import subprocess
import sys

from benchmarks import common
from benchmarks.common import emit, sample_points, timeit


# ------------------------------------------------------------------ fig4
def fig4(quick=False):
    """Paper Fig 4: simple-approach rate vs N_pt (single core: 45K/s peak)."""
    import jax.numpy as jnp
    from repro.core.simple import SimpleConfig, SimpleIndex, assign_simple
    idx = SimpleIndex.from_census(common.get_census().census)
    cfg = SimpleConfig(cap_state=0.5, cap_county=0.5, cap_block=0.5)
    sizes = [10_000, 100_000] if quick else [1_000, 10_000, 100_000,
                                             1_000_000]
    for n in sizes:
        xy, *_ = sample_points(n)
        dt, _ = timeit(lambda p: assign_simple(idx, p, cfg)[2],
                       jnp.asarray(xy))
        emit(f"fig4_simple_n{n}", dt * 1e6,
             f"{n/dt:.0f} pts/s (paper single-core peak ~45K/s)")


# ------------------------------------------------------------------ fig5
def _scaling_subprocess(n_dev: int, mode: str, n_pts: int) -> float:
    """Run a sharded assign in a fresh process with n_dev fake devices."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
import sys, time, pickle
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks import common
from repro.launch.mesh import make_test_mesh, use_mesh
sc = common.get_census()
xy, *_ = sc.sample_points(np.random.default_rng(7), {n_pts})
pts = jnp.asarray(xy)
if "{mode}" == "simple":
    from repro.core.simple import SimpleConfig, SimpleIndex, assign_simple
    idx = SimpleIndex.from_census(sc.census)
    cfg = SimpleConfig(cap_state=0.5, cap_county=0.5, cap_block=0.5)
    mesh = make_test_mesh(({n_dev}, 1))
    with use_mesh(mesh):
        f = jax.jit(lambda p: assign_simple(idx, p, cfg)[2],
                    in_shardings=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec("data", None)))
        f(pts).block_until_ready()
        t0 = time.perf_counter(); f(pts).block_until_ready()
        print("TIME", time.perf_counter() - t0)
else:
    from repro.core.distributed import shard_covering, assign_fast_distributed
    from repro.core.fast import FastConfig
    cov = common.get_covering(9)
    n_model = min({n_dev}, 2)
    mesh = make_test_mesh((max({n_dev}//n_model, 1), n_model))
    sidx = shard_covering(cov, sc.census, n_shards=n_model)
    cfg = FastConfig(mode="exact", cap_boundary=0.5)
    with use_mesh(mesh):
        f = jax.jit(lambda p: assign_fast_distributed(sidx, p, mesh, cfg)[2])
        f(pts).block_until_ready()
        t0 = time.perf_counter(); f(pts).block_until_ready()
        print("TIME", time.perf_counter() - t0)
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True)
    for line in out.stdout.splitlines():
        if line.startswith("TIME"):
            return float(line.split()[1])
    raise RuntimeError(out.stderr[-1500:])


def fig5(quick=False):
    """Paper Fig 5: simple-approach scaling with processing units.

    NOTE: this container has ONE physical core; fake host devices validate
    the sharded program structure (a real pod gives the paper's linear
    scaling; the dry-run roofline covers the 256/512-chip projection)."""
    n = 100_000
    for nd in ([1, 4] if quick else [1, 2, 4, 8]):
        dt = _scaling_subprocess(nd, "simple", n)
        emit(f"fig5_simple_dev{nd}", dt * 1e6,
             f"{n/dt:.0f} pts/s on {nd} simulated devices (1 phys core)")


# ------------------------------------------------------------------ fig6
def fig6(quick=False):
    """Paper Fig 6: fast-approach rate vs N_pt, exact + approx, with the
    top-grid depth sweep standing in for the paper's F1/F2/F4 fanouts."""
    import jax.numpy as jnp
    from repro.core.fast import FastConfig, FastIndex, assign_fast
    cov = common.get_covering(9)
    census = common.get_census().census
    sizes = [100_000] if quick else [10_000, 100_000, 1_000_000]
    for gbits in (0, 4, 6):
        idx = FastIndex.from_covering(cov, census, gbits=gbits)
        for n in sizes:
            xy, *_ = sample_points(n)
            for mode in ("exact", "approx"):
                cfg = FastConfig(mode=mode, cap_boundary=0.5)
                dt, _ = timeit(lambda p: assign_fast(idx, p, cfg)[2],
                               jnp.asarray(xy))
                emit(f"fig6_fast_{mode}_G{gbits}_n{n}", dt * 1e6,
                     f"{n/dt:.0f} pts/s, search_iters={idx.search_iters} "
                     f"(paper: few M pts/s/core)")


# ------------------------------------------------------------------ fig7
def fig7(quick=False):
    """Paper Fig 7: fast-approach thread scaling -> device scaling with the
    Morton-sharded index (beyond-paper distribution)."""
    n = 100_000
    for nd in ([2, 4] if quick else [2, 4, 8]):
        dt = _scaling_subprocess(nd, "fast", n)
        emit(f"fig7_fast_dev{nd}", dt * 1e6,
             f"{n/dt:.0f} pts/s on {nd} simulated devices (1 phys core)")


# ---------------------------------------------------------------- table1
def table1(quick=False):
    """Paper Table I: index memory.  Exact at L9 with G0/G4/G6 top grids,
    approx-precision variants via deeper leaves, plus per-shard bytes."""
    from repro.core.distributed import shard_covering
    from repro.core.fast import FastIndex
    census = common.get_census().census
    for lvl in ([9] if quick else [8, 9, 10]):
        cov = common.get_covering(lvl)
        for gbits in (0, 4, 6):
            idx = FastIndex.from_covering(cov, census, gbits=gbits)
            emit(f"table1_L{lvl}_G{gbits}", 0.0,
                 f"{idx.nbytes()/1e6:.2f} MB | cells={len(cov.lo)} "
                 f"interior={cov.n_interior} boundary={cov.n_boundary}")
        sidx = shard_covering(cov, census, n_shards=16)
        emit(f"table1_L{lvl}_sharded16", 0.0,
             f"{sidx.index_bytes_per_shard()/1e6:.2f} MB/shard x16")


# ----------------------------------------------------------------- claim
def claim(quick=False):
    """Paper §III: ~20 % of points need a PIP test (~0.2 evals/pt)."""
    import jax.numpy as jnp
    from repro.core.fast import FastConfig, FastIndex, assign_fast
    from repro.core.simple import SimpleConfig, SimpleIndex, assign_simple
    census = common.get_census().census
    xy, *_ = sample_points(100_000)
    idx = SimpleIndex.from_census(census)
    *_, stats = assign_simple(idx, jnp.asarray(xy),
                              SimpleConfig(cap_state=1.0, cap_county=1.0,
                                           cap_block=1.0))
    for lvl in ("state", "county", "block"):
        frac = int(stats[lvl]["n_multi"]) / len(xy)
        emit(f"claim_multibbox_{lvl}", 0.0,
             f"{frac:.3f} of points in >1 bbox (paper ~0.2)")
    total = sum(int(stats[k]["n_pip"]) for k in stats) / len(xy)
    emit("claim_simple_pip_per_pt", 0.0,
         f"{total:.3f} candidate PIP tests/pt")
    fidx = FastIndex.from_covering(common.get_covering(9), census, gbits=4)
    *_, fstats = assign_fast(fidx, jnp.asarray(xy),
                             FastConfig(mode="exact", cap_boundary=1.0))
    emit("claim_fast_pip_per_pt", 0.0,
         f"{int(fstats['n_pip'])/len(xy):.3f} (true-hit filtering, "
         f"boundary frac {int(fstats['n_boundary'])/len(xy):.3f})")


# -------------------------------------------------------------------- lm
def lm(quick=False):
    """Train/serve step times for reduced LM archs (CPU smoke scale)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced_config
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import build_model
    from repro.models.module import init_params
    from repro.optim import adamw
    from repro.runtime.steps import make_serve_step, make_train_step
    run = RunConfig(remat="none", attn_chunk_q=64, attn_chunk_kv=64,
                    ssm_chunk=32)
    for name in (("qwen1.5-0.5b",) if quick
                 else ("qwen1.5-0.5b", "mixtral-8x7b", "zamba2-1.2b")):
        cfg = get_reduced_config(name)
        model = build_model(cfg)
        params = init_params(model.specs, jax.random.key(0))
        opt = adamw.init(params)
        src = SyntheticLM(cfg=cfg, batch=4, seq=128)
        step = jax.jit(make_train_step(model, run))
        batch = src.batch_at(0)
        dt, _ = timeit(lambda: step(params, opt, batch)[2]["loss"])
        emit(f"lm_train_{name}", dt * 1e6,
             f"{4*128/dt:.0f} tok/s (reduced cfg, CPU)")
        serve = jax.jit(make_serve_step(model, run))
        cache = model.init_cache(4, 256)
        tok = jnp.ones((4, 1), jnp.int32)
        dt, _ = timeit(lambda: serve(params, tok, cache)[0])
        emit(f"lm_decode_{name}", dt * 1e6, f"{4/dt:.0f} tok/s decode")


SECTIONS = {"fig4": fig4, "fig5": fig5, "fig6": fig6, "fig7": fig7,
            "table1": table1, "claim": claim, "lm": lm}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes for CI")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SECTIONS)
    print("name,us_per_call,derived")
    for n in names:
        SECTIONS[n](quick=args.fast)


if __name__ == "__main__":
    main()
