"""GeoAnalytics benchmark: fused vs unfused per-block aggregation, and
windowed streaming throughput (DESIGN.md §16).

    PYTHONPATH=src python -m benchmarks.analytics_perf            # full
    PYTHONPATH=src python -m benchmarks.analytics_perf --smoke    # verify

Three measurements per run:

* **agg stage** (the headline ``agg_per_sec_*`` pair): per-block
  occupancy aggregation consuming *device-resident* assign outputs —
  the stage fusion actually changes.  *Fused* consumes the jitted
  assign+park program's buffer directly (zero-copy on the CPU backend,
  segment kernel on TPU): no host materialization, no validity
  filtering.  *Unfused* is the naive chain the subsystem replaces:
  ``np.asarray`` the id vector, mask the invalid rows, compact,
  ``np.bincount``.  Both totals are asserted bit-identical before
  either throughput is recorded; the fused ≥ unfused margin is
  structural (fewer passes over the ids), not noise — and on an
  accelerator the unfused side additionally pays a real device→host
  transfer that the CPU backend gets for free.

* **pipeline** (context row, no ratchet): the same two paths end to
  end including the engine assign, which dominates both — recorded so
  the stage numbers can be read against the full-pipeline cost.

* **window**: events/sec through a sliding 4-pane ``WindowedAggregator``
  with the distinct sketch + k-anonymity on, plus snapshot latency.

Appends an ``analytics_*`` row (``"bench": "analytics"``) to
``results/BENCH_geo.json``; ``scripts/check_bench.py`` soft-ratchets
``agg_per_sec_fused`` against trailing history like points/sec.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.analytics import AnalyticsConfig, BlockAggregator, \
    WindowedAggregator
from repro.core.engine import GeoEngine

OUT_PATH = common.BENCH_GEO_PATH


def bench_agg_stage(agg, engine, batches, repeats: int = 5):
    """(fused_per_sec, unfused_per_sec, equal) over the aggregation
    stage alone: both sides consume pre-computed device-resident assign
    outputs (parked ids for fused, raw ids for unfused).  Interleaved
    repeats, medians, so drift hits both paths alike."""
    parked = [agg.fused_ids(b) for b in batches]
    raw = [engine.assign(b).block for b in batches]
    jax.block_until_ready(parked)
    jax.block_until_ready(raw)
    n_total = sum(len(b) for b in batches)

    def fused_stage():
        total = np.zeros(agg.n_blocks, np.int64)
        for ids in parked:
            total += agg.reduce_counts(ids)
        return total

    def unfused_stage():
        total = np.zeros(agg.n_blocks, np.int64)
        for ids in raw:
            total += agg.counts(np.asarray(ids))
        return total

    equal = bool(np.array_equal(fused_stage(), unfused_stage()))
    inner = max(1, (1 << 21) // n_total)   # ~2M points per timed rep
    ts_f, ts_u = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            fused_stage()
        ts_f.append((time.perf_counter() - t0) / inner)
        t0 = time.perf_counter()
        for _ in range(inner):
            unfused_stage()
        ts_u.append((time.perf_counter() - t0) / inner)
    return (n_total / float(np.median(ts_f)),
            n_total / float(np.median(ts_u)), equal)


def bench_pipeline(agg, engine, batches, repeats: int = 3):
    """(fused_per_sec, unfused_per_sec) end to end — assign included.
    Context only: the assign dominates both sides."""
    n_total = sum(len(b) for b in batches)
    agg.fused_counts(batches[0])           # warm both programs
    np.asarray(engine.assign(batches[0]).block)
    ts_f, ts_u = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        total = np.zeros(agg.n_blocks, np.int64)
        for b in batches:
            total += agg.fused_counts(b)
        ts_f.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        total = np.zeros(agg.n_blocks, np.int64)
        for b in batches:
            total += agg.counts(np.asarray(engine.assign(b).block))
        ts_u.append(time.perf_counter() - t0)
    return (n_total / float(np.median(ts_f)),
            n_total / float(np.median(ts_u)))


def bench_window(bids, n_blocks, batch: int, repeats: int = 3):
    """(events_per_sec, snapshot_ms): stream host ids through a sliding
    4-pane windowed aggregator with the sketch + suppression on, one
    batch per simulated second."""
    rng = np.random.default_rng(5)
    sources = rng.integers(0, 1 << 20, size=len(bids))
    cfg = AnalyticsConfig(window_s=16.0, slide_s=4.0, k_anon=2,
                          sketch_bits=1024, allowed_lateness_s=4.0)
    ts = []
    for _ in range(repeats):
        agg = WindowedAggregator(n_blocks, cfg)
        t0 = time.perf_counter()
        for i in range(0, len(bids), batch):
            agg.observe(float(i // batch), bids[i:i + batch],
                        sources[i:i + batch])
        ts.append(time.perf_counter() - t0)
    events_per_sec = len(bids) / float(np.median(ts))
    snaps = []
    for _ in range(5):
        t0 = time.perf_counter()
        agg.snapshot()
        snaps.append(time.perf_counter() - t0)
    return events_per_sec, float(np.median(snaps)) * 1e3


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="verify-sized run")
    ap.add_argument("--strategy", default="fast")
    args = ap.parse_args()
    batch = 8192 if args.smoke else 32768
    n_batches = 8 if args.smoke else 32
    n_points = batch * n_batches

    census = common.get_census().census
    cov = common.get_covering(9)
    engine = GeoEngine.build(census, args.strategy, covering=cov)
    n_blocks = census.blocks.n_poly
    agg = BlockAggregator.from_engine(engine)
    xy, *_ = common.sample_points(n_points, seed=17)
    batches = [jnp.asarray(xy[i:i + batch])
               for i in range(0, n_points, batch)]
    print(f"{n_points} points / {n_batches} x {batch} batches / "
          f"{n_blocks} blocks" + (" [smoke]" if args.smoke else ""))

    fps, ups, equal = bench_agg_stage(agg, engine, batches)
    print(f"agg stage fused   : {fps / 1e6:7.1f}M agg/s")
    print(f"agg stage unfused : {ups / 1e6:7.1f}M agg/s  "
          f"(fused speedup {fps / ups:.2f}x, bit-identical={equal})")
    if not equal:
        raise SystemExit("FAILED: fused/unfused per-block counts differ")
    pfps, pups = bench_pipeline(agg, engine, batches)
    print(f"pipeline fused    : {pfps / 1e6:7.2f}M pts/s  "
          f"unfused {pups / 1e6:.2f}M pts/s (assign-dominated)")

    bid_host = np.asarray(engine.assign(jnp.asarray(xy)).block)
    eps, snap_ms = bench_window(bid_host, n_blocks, batch=4096)
    print(f"window feed       : {eps / 1e6:7.2f}M events/s  "
          f"snapshot {snap_ms:.2f}ms")

    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "bench": "analytics", "smoke": bool(args.smoke),
           "backend": jax.default_backend(),
           "strategy": args.strategy,
           "n_points": n_points, "batch": batch, "n_blocks": n_blocks,
           "agg_per_sec_fused": fps, "agg_per_sec_unfused": ups,
           "fused_speedup": fps / ups, "counts_equal": equal,
           "pipeline_per_sec_fused": pfps,
           "pipeline_per_sec_unfused": pups,
           "window_events_per_sec": eps, "snapshot_ms": snap_ms}
    n_runs = common.append_bench_run(run, OUT_PATH)
    print(f"wrote {os.path.normpath(OUT_PATH)} ({n_runs} runs)")


if __name__ == "__main__":
    main()
