"""Tracing overhead guard: the observability budget, enforced.

    PYTHONPATH=src python -m benchmarks.trace_overhead            # full
    PYTHONPATH=src python -m benchmarks.trace_overhead --smoke    # verify

DESIGN.md §15 promises that tracing is cheap enough to leave on: the
serve path with **no tracer** and with a **1%-sampled tracer** must both
stay within ``--tol`` (default 3%, env ``TRACE_OVERHEAD_TOL``) of the
untraced baseline's points/sec.  This harness measures all three modes —

  * ``untraced``   — no tracer attached (the baseline);
  * ``tracer_off`` — tracer attached, sample_rate=0 (pays only the
    per-request sampling gate + the ticket stamps);
  * ``sampled_1pct`` — sample_rate=0.01 (the recommended production
    setting: 1 in 100 requests records a full span timeline);

interleaved across repeats with the mode order ROTATED each round (so
both slow drift and position effects — a pass inheriting its
predecessor's deferred work — hit all modes alike).  The verdict is a
**paired** comparison: each traced mode's slowdown is measured against
the SAME round's untraced pass and the median over rounds is gated —
common-mode machine drift cancels within a round, which a best-of or
mean comparison cannot do on a shared CI box (best-of throughput is
still reported per mode as the clean-machine estimate).  A failing
median escalates to up to 3x the configured rounds before the verdict:
noise is zero-mean so more rounds converge the median — extra data can
only exonerate an unlucky mode, never hide a genuinely slow one.  The per-stage histograms in
``ServerMetrics`` are always on and therefore part of *every* mode,
including the baseline: the budget guards what tracing *adds*.

Appends one ``trace_overhead`` row to ``results/BENCH_geo.json`` and
exits non-zero when a traced mode falls outside the budget — wired into
``scripts/verify.sh`` so an accidentally hot span path fails CI, not a
production SLO.
"""
import argparse
import os
import sys
import time

import jax
import numpy as np

from benchmarks import common
from repro.core.engine import EngineConfig, GeoEngine
from repro.obs import Tracer
from repro.serving import GeoServer, ServeConfig

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_geo.json")

MODES = ("untraced", "tracer_off", "sampled_1pct")


def build_server(engine, cov, buckets, mode):
    tracer = {"untraced": None,
              "tracer_off": Tracer(sample_rate=0.0),
              "sampled_1pct": Tracer(sample_rate=0.01)}[mode]
    # Cache off: the cache would absorb most requests after the first
    # pass and the residual device time would swamp the tracer's
    # microseconds — overhead is measured on the full serve path.
    server = GeoServer(engine, ServeConfig(buckets=buckets, cache=False),
                       covering=cov, tracer=tracer)
    server.warm()
    return server


def run_pass(server, requests) -> float:
    """One full pass over the stream; returns points/sec."""
    n = sum(len(r) for r in requests)
    t0 = time.perf_counter()
    for req in requests:
        server.submit(req)
    return n / (time.perf_counter() - t0)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="verify-sized: smaller stream, fewer repeats")
    ap.add_argument("--seed", type=int, default=23)
    ap.add_argument("--repeats", type=int, default=None,
                    help="interleaved repeats per mode (default 5 smoke, "
                         "7 full)")
    ap.add_argument("--tol", type=float, default=float(
                        os.environ.get("TRACE_OVERHEAD_TOL", 0.03)),
                    help="max tolerated fractional slowdown vs untraced")
    args = ap.parse_args()
    repeats = args.repeats or (5 if args.smoke else 7)
    n_requests = 128 if args.smoke else 512
    size = 64            # small requests: per-request overhead maximized
    buckets = (256, 1024)

    census = common.get_census().census
    cov = common.get_covering(9)
    rng = np.random.default_rng(args.seed)
    xy, *_ = common.sample_points(n_requests * size, seed=args.seed + 1)
    requests = [xy[rng.integers(0, len(xy), size)].astype(np.float32)
                for _ in range(n_requests)]

    engine = GeoEngine.build(census, "fast", EngineConfig(mode="exact"),
                             covering=cov)
    servers = {m: build_server(engine, cov, buckets, m) for m in MODES}
    for m in MODES:                        # warm pass (JIT + page-in)
        run_pass(servers[m], requests[:16])

    rates = {m: [] for m in MODES}

    def run_round(r):
        for i in range(len(MODES)):        # rotate: position bias cancels
            m = MODES[(r + i) % len(MODES)]
            rates[m].append(run_pass(servers[m], requests))

    def paired_median(m):
        # Median paired slowdown: round r's traced pass vs round r's
        # untraced pass.
        n = len(rates[m])
        paired = sorted(1.0 - rates[m][r] / rates["untraced"][r]
                        for r in range(n))
        return paired[n // 2] if n % 2 else \
            0.5 * (paired[n // 2 - 1] + paired[n // 2])

    traced = ("tracer_off", "sampled_1pct")
    rounds = 0
    for _ in range(repeats):
        run_round(rounds)
        rounds += 1
    # Escalate on failure: pass-to-pass noise on a shared box is
    # zero-mean, so the median converges with more rounds, while a
    # real regression stays put — extra rounds can only exonerate a
    # mode that was unlucky, never hide a mode that is slow.
    while rounds < 3 * repeats and \
            any(paired_median(m) > args.tol for m in traced):
        run_round(rounds)
        rounds += 1

    best = {m: max(rates[m]) for m in MODES}
    base = best["untraced"]
    verdicts = {}
    ok = True
    print(f"untraced        : {base / 1e6:7.3f}M pts/s  (baseline, "
          f"{rounds} rounds)")
    for m in traced:
        slowdown = paired_median(m)
        passed = slowdown <= args.tol
        ok &= passed
        verdicts[m] = {"pts_per_sec": best[m], "slowdown": slowdown,
                       "pass": passed}
        print(f"{m:16s}: {best[m] / 1e6:7.3f}M pts/s  "
              f"paired median overhead {slowdown * 100:+.2f}% "
              f"(budget {args.tol * 100:.0f}%) "
              f"-> {'PASS' if passed else 'FAIL'}")

    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "bench": "trace_overhead", "smoke": bool(args.smoke),
           "seed": args.seed, "repeats": repeats, "rounds": rounds,
           "n_requests": n_requests, "request_size": size,
           "tol": args.tol, "backend": jax.default_backend(),
           "untraced_pts_per_sec": base,
           "tracer_off_pts_per_sec": best["tracer_off"],
           "sampled_pts_per_sec": best["sampled_1pct"],
           "tracer_off_slowdown": verdicts["tracer_off"]["slowdown"],
           "sampled_slowdown": verdicts["sampled_1pct"]["slowdown"],
           "pass": bool(ok)}
    n_runs = common.append_bench_run(run, OUT_PATH)
    print(f"wrote {os.path.normpath(OUT_PATH)} ({n_runs} runs)")
    if not ok:
        print("trace overhead budget exceeded", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
