"""Roofline analysis from the dry-run's compiled artifacts.

    PYTHONPATH=src python -m benchmarks.roofline results/dryrun.json

Per (arch x shape x mesh) cell, derive the three roofline terms (seconds):

  compute    = HLO_FLOPs_per_device / 197e12          (TPU v5e bf16 peak)
  memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
  collective = collective_result_bytes_per_device / 50e9   (per-link ICI)

Convention: collective bytes are the *result shapes* of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops in the
partitioned HLO — a per-device proxy for link traffic that is consistent
across baselines (ring factors ~2(N-1)/N are absorbed into the constant).

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (prefill/decode) with N = active
params (MoE counts shared + top-k routed only).  The "roofline fraction" is
useful-compute-time / bottleneck-term — the score we hillclimb in §Perf.

**Geo mode** (``--geo [--smoke]``): instead of reading a dry-run file,
measure the geo kernels live — achieved vs peak bandwidth/FLOPs per
kernel from XLA cost analysis over the bench census (DESIGN.md §13).
Each run appends a ``kind: "roofline_geo"`` row to
``results/BENCH_geo.json`` so the bandwidth trajectory accumulates next
to the points/sec history:

    PYTHONPATH=src python -m benchmarks.roofline --geo --smoke
"""
import argparse
import json
import sys

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

# Nominal CPU anchors for the geo rows when the bench runs off-TPU.
# Order-of-magnitude single-socket figures: the point of the geo rows is
# the *trajectory* of achieved bandwidth on a fixed device kind (and the
# memory- vs compute-bound verdict), not cross-device comparisons.
CPU_PEAK_FLOPS = 1.0e12   # FLOP/s, vectorized f32
CPU_MEM_BW = 80e9         # B/s

sys.path.insert(0, "src")


def device_peaks(device_kind: str) -> tuple:
    """(peak FLOP/s, peak B/s) for a jax backend kind."""
    if device_kind == "tpu":
        return PEAK_FLOPS, HBM_BW
    return CPU_PEAK_FLOPS, CPU_MEM_BW


def active_params(arch: str, total: int) -> int:
    """Active params per token: subtract un-routed expert weights."""
    from repro.configs import get_config
    cfg = get_config(arch)
    if not cfg.n_experts:
        return total
    expert_p = 3 * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    routed_total = cfg.n_experts * expert_p * n_moe_layers
    routed_active = cfg.top_k * expert_p * n_moe_layers
    return total - routed_total + routed_active


def analyze(rec: dict) -> dict:
    arch, shape, kind = rec["arch"], rec["shape"], rec["kind"]
    n_dev = rec["n_devices"]
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = rec["bytes_accessed_per_device"] / HBM_BW
    coll_bytes = sum(rec["collective_bytes_per_device"].values())
    coll = coll_bytes / ICI_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)

    n_act = active_params(arch, rec["params"])
    b, s = rec["shape_batch_seq"] if "shape_batch_seq" in rec else (None,
                                                                   None)
    from repro.configs.base import ALL_SHAPES
    sh = {x.name: x for x in ALL_SHAPES}[shape]
    if kind == "train":
        tokens = sh.global_batch * sh.seq_len
        model_flops = 6 * n_act * tokens
    elif kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        model_flops = 2 * n_act * tokens
    else:
        tokens = sh.global_batch          # one new token per sequence
        model_flops = 2 * n_act * tokens
    mf_dev = model_flops / n_dev
    useful = mf_dev / PEAK_FLOPS
    bottleneck_t = max(terms.values())
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh_name"],
        "kind": kind,
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant,
        "model_flops_per_dev": mf_dev,
        "hlo_flops_per_dev": rec["flops_per_device"],
        "useful_flop_ratio": (mf_dev / rec["flops_per_device"]
                              if rec["flops_per_device"] else 0.0),
        "roofline_fraction": useful / bottleneck_t if bottleneck_t else 0.0,
        "temp_gib": rec["memory"]["temp_size"] / 2**30,
        "args_gib": rec["memory"]["argument_size"] / 2**30,
    }


def improvement_hint(a: dict) -> str:
    if a["dominant"] == "compute":
        if a["useful_flop_ratio"] < 0.5:
            return ("cut non-model FLOPs (remat recompute, causal-masked "
                    "waste, replicated head compute)")
        return "compute-bound near useful flops; raise MXU util via tiling"
    if a["dominant"] == "memory":
        return ("cut HBM traffic: fuse/bf16 intermediates, larger attention "
                "chunks, avoid logit materialization")
    return "reduce collective volume: reshard weights, overlap, or cast " \
           "all-gathers to bf16"


def compiled_cost(compiled) -> dict:
    """(flops, bytes accessed) from a jax compiled artifact's cost
    analysis — tolerant of the dict-vs-singleton-list return shape that
    varies across jax versions, and of missing keys (TPU interpret)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca = ca or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def geo_roofline(name: str, fn, args_: tuple, n_points: int,
                 repeats: int = 3) -> dict:
    """One achieved-vs-peak row for a geo kernel: compile ``fn`` once,
    take FLOPs + bytes from the compiled cost analysis, wall time from a
    median of ``repeats`` runs, and divide by the device-kind peaks."""
    import jax

    from benchmarks import common

    f = jax.jit(fn)
    cost = compiled_cost(f.lower(*args_).compile())
    dt, _ = common.timeit(f, *args_, repeats=repeats)
    device_kind = jax.default_backend()
    peak_flops, peak_bw = device_peaks(device_kind)
    achieved_bw = cost["bytes_accessed"] / dt
    achieved_flops = cost["flops"] / dt
    bw_frac = achieved_bw / peak_bw
    flop_frac = achieved_flops / peak_flops
    return {
        "kernel": name, "n_points": int(n_points),
        "device_kind": device_kind,
        "wall_ms": dt * 1e3, "pts_per_sec": n_points / dt,
        "flops": cost["flops"], "bytes_accessed": cost["bytes_accessed"],
        "bytes_per_point": cost["bytes_accessed"] / max(n_points, 1),
        "achieved_bw": achieved_bw, "achieved_flops": achieved_flops,
        "bw_fraction": bw_frac, "flop_fraction": flop_frac,
        # Distance to the nearest roof — the score the tile sweep
        # (geo_perf --autotune) hillclimbs.
        "roofline_fraction": max(bw_frac, flop_frac),
        "dominant": "memory" if bw_frac >= flop_frac else "compute",
    }


def geo_main(smoke: bool) -> None:
    """Live achieved-bandwidth rows for the geo strategies (see module
    docstring); appends one roofline_geo run to results/BENCH_geo.json."""
    import time

    import jax
    import jax.numpy as jnp

    from benchmarks import common
    from repro.core.engine import EngineConfig, GeoEngine

    n = int(min(1 << 18, 20_000 if smoke else 1 << 18))
    census = common.get_census().census
    cov = common.get_covering(9)
    xy, _, *_ = common.sample_points(n)
    pts = jnp.asarray(xy, jnp.float32)
    specs = {
        "fast_exact": ("fast", EngineConfig(mode="exact", fused=True)),
        "fast_onepass": ("fast_onepass", EngineConfig()),
    }
    kernels = {}
    print(f"geo roofline: n={n} points, device={jax.default_backend()}"
          + (" [smoke]" if smoke else ""))
    for name, (strategy, cfg) in specs.items():
        eng = GeoEngine.build(census, strategy, cfg, covering=cov)
        row = geo_roofline(name, lambda p, e=eng: e.assign(p).block,
                           (pts,), n, repeats=3 if smoke else 5)
        kernels[name] = row
        print(f"{name:14s}: {row['wall_ms']:7.1f}ms "
              f"({row['pts_per_sec']/1e6:5.2f}M pts/s) | "
              f"{row['achieved_bw']/1e9:6.2f} GB/s "
              f"({row['bw_fraction']*100:5.2f}% of peak) | "
              f"{row['bytes_per_point']:6.0f} B/pt | {row['dominant']}")
    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "kind": "roofline_geo", "smoke": bool(smoke),
           "n_points": n, "device_kind": jax.default_backend(),
           "kernels": kernels}
    n_runs = common.append_bench_run(run)
    print(f"wrote {common.BENCH_GEO_PATH} ({n_runs} runs)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="results/dryrun.json")
    ap.add_argument("--mesh", default=None,
                    choices=(None, "single_pod", "multi_pod"))
    ap.add_argument("--json-out", default="results/roofline.json")
    ap.add_argument("--geo", action="store_true",
                    help="live geo-kernel achieved-bandwidth rows "
                         "instead of dry-run analysis")
    ap.add_argument("--smoke", action="store_true",
                    help="with --geo: verify-sized batch")
    args = ap.parse_args()
    if args.geo:
        geo_main(args.smoke)
        return
    recs = json.load(open(args.path))
    rows = []
    for r in recs:
        if not r.get("ok"):
            continue
        if args.mesh and r["mesh_name"] != args.mesh:
            continue
        rows.append(analyze(r))
    rows.sort(key=lambda a: (a["mesh"], a["arch"], a["shape"]))

    hdr = (f"| arch | shape | mesh | compute(s) | memory(s) | coll(s) | "
           f"dominant | useful/HLO | roofline frac | temp GiB |")
    print(hdr)
    print("|" + "---|" * 10)
    for a in rows:
        print(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
              f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
              f"| {a['collective_s']:.3e} | **{a['dominant']}** "
              f"| {a['useful_flop_ratio']:.2f} "
              f"| {a['roofline_fraction']:.3f} | {a['temp_gib']:.1f} |")
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {args.json_out}")
    # Hillclimb-candidate summary (single-pod train/prefill cells).
    sp = [a for a in rows if a["mesh"] == "single_pod"]
    if sp:
        worst = min(sp, key=lambda a: a["roofline_fraction"])
        coll = max(sp, key=lambda a: a["collective_s"]
                   / max(max(a["compute_s"], a["memory_s"]), 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} x "
              f"{worst['shape']} ({worst['roofline_fraction']:.3f}, "
              f"{worst['dominant']}-bound) -> {improvement_hint(worst)}")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']} "
              f"(coll/max(other)="
              f"{coll['collective_s']/max(max(coll['compute_s'], coll['memory_s']), 1e-12):.2f})"
              f" -> {improvement_hint(coll)}")


if __name__ == "__main__":
    main()
