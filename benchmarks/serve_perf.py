"""GeoServer throughput/latency harness: a mixed-size, hot-spotted
request stream served through the full serving stack (bucket-ladder
micro-batching + hot-cell cache + metrics), per strategy.

    PYTHONPATH=src python -m benchmarks.serve_perf            # full run
    PYTHONPATH=src python -m benchmarks.serve_perf --smoke    # verify-sized

The stream models serving traffic rather than batch analytics: request
sizes are log-uniform in [1, 4096] (mobile check-ins to bulk uploads) and
a ``--hot`` fraction of requests re-query a small pool of hot locations
(the mContain hot-spot pattern the cache exists for).  Rows record
points/sec, p50/p99 request latency, cache hit rate, batch-fill ratio,
accuracy vs ground truth, and the GeoStats counters (phase2_miss,
overflow, boundary count) so serving-path degradation shows in the bench
history just like the batch path's.

Appends ``serve_*`` rows to ``results/BENCH_geo.json`` alongside the
geo_perf rows (run objects carry ``"bench": "serve"``).
"""
import argparse
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.core.engine import EngineConfig, GeoEngine
from repro.serving import GeoServer, ServeConfig

N_POINTS = int(os.environ.get("BENCH_SERVE_N", 500_000))
SMOKE_N = int(os.environ.get("BENCH_SERVE_SMOKE_N", 20_000))
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_geo.json")

SPECS = {
    "serve_simple": ("simple", EngineConfig()),
    "serve_hybrid": ("hybrid", EngineConfig()),
    "serve_fast_exact_fused": ("fast", EngineConfig(mode="exact",
                                                    fused=True)),
    # Planner-chosen engine behind the same serving stack; its row
    # records the GeoPlan so serve history ties latency to the plan.
    "serve_auto": ("auto", EngineConfig()),
}


def build_stream(n_total: int, hot_frac: float, seed: int = 11):
    """(requests, truths): lists of ([n, 2] f32 points, [n] i32 block
    ids).  Request sizes are log-uniform; ``hot_frac`` of requests draw
    their points from a 256-point hot pool (with replacement).
    ``hot_frac`` is clamped to [0, 0.9]: only non-hot requests consume
    fresh points, so the loop needs a non-hot fraction to terminate.

    ``seed`` drives BOTH the stream-shape rng and (offset, so the two
    streams stay decorrelated) the point sample — one flag pins the
    whole run for apples-to-apples bench comparisons."""
    hot_frac = min(max(hot_frac, 0.0), 0.9)
    rng = np.random.default_rng(seed)
    xy, bid, *_ = common.sample_points(n_total, seed=seed + 2)
    hot_n = min(256, n_total)
    hot_ix = rng.choice(n_total, hot_n, replace=False)
    requests, truths, used = [], [], 0
    while used < n_total:
        size = min(int(np.exp(rng.uniform(0, np.log(4096)))),
                   n_total - used)
        if rng.uniform() < hot_frac:
            ix = hot_ix[rng.integers(0, hot_n, size)]
        else:
            ix = np.arange(used, used + size)
            used += size
        requests.append(xy[ix].astype(np.float32))
        truths.append(bid[ix])
    return requests, truths


def bench_serving(census, cov, requests, truths, buckets,
                  trace_sample=None, trace_out=None):
    """Per-strategy serve run.  ``trace_sample`` attaches a fresh Tracer
    per strategy (the span stream must attribute to one engine) and
    exports ``<trace_out>_<name>.chrome.json`` beside the row."""
    results = {}
    for name, (strategy, ecfg) in SPECS.items():
        tracer = None
        if trace_sample is not None:
            from repro.obs import Tracer
            tracer = Tracer(sample_rate=trace_sample)
        engine = GeoEngine.build(census, strategy, ecfg, covering=cov)
        server = GeoServer(engine, ServeConfig(buckets=buckets),
                           covering=cov, tracer=tracer)
        warm = server.warm()
        t0 = time.perf_counter()
        served = [server.submit(req).block for req in requests]
        wall = time.perf_counter() - t0

        n = sum(len(r) for r in requests)
        acc = float(np.mean(np.concatenate(served)
                            == np.concatenate(truths)))
        snap = server.snapshot()
        lat, c, d = snap["latency_ms"], snap["counters"], snap["derived"]
        results[name] = {
            "pts_per_sec": n / wall, "wall_ms": wall * 1e3,
            "n_requests": len(requests), "accuracy": acc,
            "plan": engine.explain(),
            "p50_ms": lat["p50"], "p99_ms": lat["p99"],
            "cache_hit_rate": d["cache_hit_rate"],
            "batch_fill_ratio": d["batch_fill_ratio"],
            "n_boundary": c.get("geo_n_boundary", 0),
            "n_pip": c.get("geo_n_pip", 0),
            "overflow": c.get("geo_overflow", 0),
            "phase2_miss": c.get("geo_phase2_miss", 0),
            "warm_s": sum(warm.values()),
            **common.stage_breakdown(snap),
        }
        if tracer is not None and trace_out is not None:
            os.makedirs(os.path.dirname(os.path.abspath(trace_out)),
                        exist_ok=True)
            n_ev = tracer.export_chrome(f"{trace_out}_{name}.chrome.json")
            print(f"  trace: {n_ev} chrome events -> "
                  f"{trace_out}_{name}.chrome.json")
        print(f"{name:24s}: {n / wall / 1e6:5.2f}M pts/s "
              f"p50 {lat['p50']:6.2f}ms p99 {lat['p99']:7.2f}ms "
              f"hit {d['cache_hit_rate']:.2f} "
              f"fill {d['batch_fill_ratio']:.2f} acc {acc:.4f} "
              f"p2miss {c.get('geo_phase2_miss', 0)}")
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="verify-sized run: small stream, small buckets")
    ap.add_argument("--hot", type=float, default=0.3,
                    help="fraction of requests hitting the hot pool")
    ap.add_argument("--seed", type=int, default=11,
                    help="rng seed for the request stream + point sample")
    ap.add_argument("--trace", action="store_true",
                    help="attach a per-strategy Tracer; exports Chrome "
                         "traces beside the BENCH row")
    ap.add_argument("--trace-sample", type=float, default=0.05,
                    help="head-sampling rate for --trace")
    ap.add_argument("--trace-out", default=os.path.join(
                        os.path.dirname(OUT_PATH), "trace_serve"),
                    help="output prefix for --trace exports")
    args = ap.parse_args()
    n_total = SMOKE_N if args.smoke else N_POINTS
    buckets = (256, 1024, 4096) if args.smoke else (256, 1024, 4096, 16384)

    census = common.get_census().census
    cov = common.get_covering(9)
    requests, truths = build_stream(n_total, args.hot, seed=args.seed)
    print(f"{len(requests)} requests / "
          f"{sum(len(r) for r in requests)} points, hot={args.hot}"
          + (" [smoke]" if args.smoke else ""))

    results = bench_serving(
        census, cov, requests, truths, buckets,
        trace_sample=args.trace_sample if args.trace else None,
        trace_out=args.trace_out if args.trace else None)

    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "bench": "serve",
           "n_points": int(sum(len(r) for r in requests)),
           "n_requests": len(requests), "hot_frac": args.hot,
           "seed": args.seed, "trace": bool(args.trace),
           "smoke": bool(args.smoke), "backend": jax.default_backend(),
           "strategies": results}
    n_runs = common.append_bench_run(run, OUT_PATH)
    print(f"wrote {os.path.normpath(OUT_PATH)} ({n_runs} runs)")


if __name__ == "__main__":
    main()
