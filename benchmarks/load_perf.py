"""Sustained-load SLO harness for the concurrent serving front-end.

    PYTHONPATH=src python -m benchmarks.load_perf            # full search
    PYTHONPATH=src python -m benchmarks.load_perf --smoke    # verify-sized

Where serve_perf measures the serving stack one request at a time, this
harness asks the production question: **what offered load can the
AsyncGeoServer sustain while still meeting a p99 latency SLO?**  It is
the ratcheting throughput-under-SLO metric the ROADMAP's async-serving
item calls for.

Two generator modes, both with hot-spot key skew (``--hot`` fraction of
requests re-query a small hot pool — the mContain pattern):

  * **open loop** (the SLO measurement): request arrivals follow a
    Poisson process (``--arrival poisson``) or a bursty on/off process
    (``--arrival bursty``: Poisson bursts of ``BURST`` back-to-back
    arrivals) at a target QPS, submitted via ``submit_async`` without
    waiting — so a slow server cannot slow the generator down, and
    latency is measured from the *scheduled* arrival (no coordinated
    omission).  Overload sheds (``policy="shed"``) rather than queueing
    without bound; the shed rate is part of the SLO verdict.
  * **closed loop** (context row): ``--clients`` workers in a
    submit-wait loop — the classic saturation throughput, reported
    alongside so the open-loop number has a ceiling to compare against.

``find_qps_at_slo`` binary-searches the highest QPS whose trial meets
``p99 <= --slo-ms`` and ``shed_rate <= --max-shed``, then appends one
``serve_slo`` row (qps_at_slo, p50/p99, shed rate, cache hit rate,
replica count, arrival mode) to ``results/BENCH_geo.json``;
``scripts/check_bench.py`` ratchets on ``qps_at_slo``.

All RNGs seed from ``--seed`` so the request stream is reproducible;
wall-clock jitter is what the soft ratchet's trailing median absorbs.
"""
import argparse
import os
import threading
import time

import jax
import numpy as np

from benchmarks import common
from repro.core.engine import EngineConfig, GeoEngine
from repro.serving import (AsyncGeoServer, FrontendConfig, QueueFull,
                           ServeConfig)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_geo.json")
BURST = 8                        # arrivals per burst in --arrival bursty


def build_requests(n_requests: int, size: int, hot_frac: float,
                   seed: int):
    """``n_requests`` request arrays of ``size`` points each; a
    ``hot_frac`` fraction draw from a 256-point hot pool (cacheable
    traffic), the rest from the full sample (cold tail)."""
    rng = np.random.default_rng(seed)
    pool_n = max(n_requests * size // 4, 4096)
    xy, *_ = common.sample_points(pool_n, seed=seed + 1)
    hot = xy[rng.choice(pool_n, min(256, pool_n), replace=False)]
    reqs = []
    for _ in range(n_requests):
        if rng.uniform() < hot_frac:
            reqs.append(hot[rng.integers(0, len(hot), size)]
                        .astype(np.float32))
        else:
            reqs.append(xy[rng.integers(0, pool_n, size)]
                        .astype(np.float32))
    return reqs


def arrival_offsets(qps: float, duration_s: float, rng,
                    arrival: str) -> np.ndarray:
    """Sorted arrival times in [0, duration_s) at mean rate ``qps``."""
    n_max = int(qps * duration_s * 3) + 32
    if arrival == "poisson":
        t = np.cumsum(rng.exponential(1.0 / qps, size=n_max))
    elif arrival == "bursty":
        # Bursts arrive Poisson at qps/BURST; each contributes BURST
        # back-to-back arrivals (0.1 ms apart) — the worst case for the
        # batcher's coalescing and the deadline clock.
        starts = np.cumsum(rng.exponential(BURST / qps,
                                           size=n_max // BURST + 1))
        t = (starts[:, None] + np.arange(BURST) * 1e-4).ravel()
    else:
        raise ValueError(f"unknown arrival process {arrival!r}")
    return t[t < duration_s]


def open_loop_trial(server: AsyncGeoServer, requests, qps: float,
                    duration_s: float, rng, arrival: str) -> dict:
    """Offer ``qps`` for ``duration_s``; returns latency percentiles
    (measured from the scheduled arrival), achieved/offered QPS, and the
    shed rate."""
    offsets = arrival_offsets(qps, duration_s, rng, arrival)
    lat, shed, lock = [], [0], threading.Lock()

    def on_done(sched_abs, fut):
        done = time.perf_counter()
        with lock:
            if isinstance(fut.exception(), QueueFull):
                shed[0] += 1
            else:
                lat.append(done - sched_abs)

    t0 = time.perf_counter()
    for i, off in enumerate(offsets):
        now = time.perf_counter() - t0
        if off > now:
            time.sleep(off - now)
        sched_abs = t0 + off
        try:
            fut = server.submit_async(requests[i % len(requests)])
        except QueueFull:                   # shed can surface either way
            with lock:
                shed[0] += 1
            continue
        fut.add_done_callback(
            lambda f, s=sched_abs: on_done(s, f))
    server.drain(timeout=30.0)
    wall = time.perf_counter() - t0
    n = len(offsets)
    with lock:
        samples = np.asarray(lat) * 1e3
        n_shed = shed[0]
    if len(samples) == 0:
        samples = np.asarray([float("inf")])
    return {"offered_qps": n / duration_s,
            "achieved_qps": len(samples) / wall,
            "p50_ms": float(np.percentile(samples, 50)),
            "p99_ms": float(np.percentile(samples, 99)),
            "shed_rate": n_shed / n if n else 0.0,
            "n_requests": n}


def closed_loop_trial(server: AsyncGeoServer, requests, n_clients: int,
                      duration_s: float) -> dict:
    """``n_clients`` submit-wait workers for ``duration_s`` — saturation
    throughput and its latency, the open-loop search's ceiling."""
    stop = time.perf_counter() + duration_s
    counts = [0] * n_clients
    lats: list[list] = [[] for _ in range(n_clients)]

    def client(ix):
        k = ix
        while time.perf_counter() < stop:
            t0 = time.perf_counter()
            try:
                server.submit(requests[k % len(requests)], timeout=30)
            except QueueFull:
                continue
            finally:
                k += n_clients
            lats[ix].append(time.perf_counter() - t0)
            counts[ix] += 1

    threads = [threading.Thread(target=client, args=(ix,), daemon=True)
               for ix in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(duration_s + 30)
    wall = time.perf_counter() - t0
    samples = np.asarray([l for ls in lats for l in ls]) * 1e3
    if len(samples) == 0:
        samples = np.asarray([float("inf")])
    return {"qps": sum(counts) / wall,
            "p50_ms": float(np.percentile(samples, 50)),
            "p99_ms": float(np.percentile(samples, 99)),
            "n_requests": int(sum(counts))}


def find_qps_at_slo(server: AsyncGeoServer, requests, slo_ms: float,
                    max_shed: float, lo: float, hi: float, iters: int,
                    trial_s: float, rng, arrival: str):
    """Binary-search (geometric midpoint) the max sustained QPS whose
    open-loop trial meets the SLO; returns (qps_at_slo, trial metrics at
    that QPS).  ``lo`` must pass — if even ``lo`` misses the SLO, the
    row records qps_at_slo=0 with the failing trial (an honest floor,
    and the ratchet will scream)."""
    best_qps, best = 0.0, None
    m = open_loop_trial(server, requests, lo, trial_s, rng, arrival)
    if m["p99_ms"] <= slo_ms and m["shed_rate"] <= max_shed:
        best_qps, best = lo, m
    else:
        return 0.0, m
    for _ in range(iters):
        mid = (lo * hi) ** 0.5
        m = open_loop_trial(server, requests, mid, trial_s, rng, arrival)
        ok = m["p99_ms"] <= slo_ms and m["shed_rate"] <= max_shed
        print(f"  trial {mid:8.1f} qps: p99 {m['p99_ms']:7.2f}ms "
              f"shed {m['shed_rate']:.3f} -> {'PASS' if ok else 'FAIL'}")
        if ok:
            lo = mid
            if mid > best_qps:
                best_qps, best = mid, m
        else:
            hi = mid
    return best_qps, best


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="verify-sized: short trials, few search iters")
    ap.add_argument("--seed", type=int, default=17,
                    help="seeds every RNG (stream content + arrivals)")
    ap.add_argument("--arrival", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--hot", type=float, default=0.5,
                    help="fraction of requests hitting the hot pool")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="p99 latency SLO (default: 100 smoke, 50 full)")
    ap.add_argument("--max-shed", type=float, default=0.01,
                    help="max tolerated shed rate under SLO")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4,
                    help="closed-loop worker count")
    ap.add_argument("--request-size", type=int, default=32)
    ap.add_argument("--trace", action="store_true",
                    help="attach a Tracer and export Chrome-trace + span "
                         "dumps beside the BENCH row (DESIGN.md §15)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="head-sampling rate for --trace (default 1.0 — "
                         "the smoke validates complete timelines; use "
                         "~0.01 under real load)")
    ap.add_argument("--trace-out", default=os.path.join(
                        os.path.dirname(OUT_PATH), "trace_load"),
                    help="output prefix: writes <prefix>.chrome.json and "
                         "<prefix>.spans.json")
    args = ap.parse_args()

    slo_ms = args.slo_ms if args.slo_ms is not None \
        else (100.0 if args.smoke else 50.0)
    trial_s = 0.6 if args.smoke else 3.0
    iters = 3 if args.smoke else 7
    n_requests = 64 if args.smoke else 512
    lo, hi = (20.0, 2000.0) if args.smoke else (50.0, 20000.0)

    census = common.get_census().census
    cov = common.get_covering(9)
    rng = np.random.default_rng(args.seed)
    requests = build_requests(n_requests, args.request_size, args.hot,
                              args.seed)

    engine = GeoEngine.build(census, "fast", EngineConfig(mode="exact"),
                             covering=cov)
    scfg = ServeConfig(buckets=(256, 1024, 4096), policy="shed",
                       max_queue_points=1 << 15, max_delay_ms=2.0)
    fcfg = FrontendConfig(n_replicas=args.replicas, n_submitters=4)
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(sample_rate=args.trace_sample)
    with AsyncGeoServer(engine, scfg, covering=cov,
                        frontend=fcfg, tracer=tracer) as server:
        server.warm()
        # Prime the hot-cell cache so the searched steady state is the
        # warmed one (cold-cache trials would understate sustained QPS).
        for req in requests[:16]:
            server.submit(req, timeout=30)

        closed = closed_loop_trial(server, requests, args.clients,
                                   trial_s)
        print(f"closed loop ({args.clients} clients): "
              f"{closed['qps']:8.1f} qps p99 {closed['p99_ms']:.2f}ms")

        qps_at_slo, at = find_qps_at_slo(
            server, requests, slo_ms, args.max_shed, lo, hi, iters,
            trial_s, rng, args.arrival)
        snap = server.snapshot()

    hit_rate = snap["derived"]["cache_hit_rate"]
    breakdown = common.stage_breakdown(snap)
    print(f"qps_at_slo (p99<={slo_ms}ms, shed<={args.max_shed}): "
          f"{qps_at_slo:8.1f} qps "
          f"(p50 {at['p50_ms']:.2f}ms p99 {at['p99_ms']:.2f}ms "
          f"shed {at['shed_rate']:.3f} hit {hit_rate:.2f})")
    def _ms(v):
        return "n/a" if v is None else f"{v:.3f}"
    print(f"stage p99 (ms): queue_wait "
          f"{_ms(breakdown['queue_wait_p99_ms'])} "
          f"host {_ms(breakdown['host_p99_ms'])} "
          f"device {_ms(breakdown['device_p99_ms'])}")
    if tracer is not None:
        os.makedirs(os.path.dirname(os.path.abspath(args.trace_out)),
                    exist_ok=True)
        chrome_path = args.trace_out + ".chrome.json"
        n_ev = tracer.export_chrome(chrome_path)
        n_sp = tracer.export_spans(args.trace_out + ".spans.json")
        st = tracer.stats()
        print(f"trace: {n_sp} spans ({n_ev} chrome events, "
              f"{st['sampled']}/{st['started']} requests sampled, "
              f"{st['dropped']} dropped) -> {os.path.normpath(chrome_path)}")

    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "bench": "load",
           "kind": "serve_slo", "smoke": bool(args.smoke),
           "seed": args.seed, "arrival": args.arrival,
           "hot_frac": args.hot, "request_size": args.request_size,
           "replicas": args.replicas, "slo_ms": slo_ms,
           "max_shed": args.max_shed, "trial_s": trial_s,
           "backend": jax.default_backend(),
           "qps_at_slo": qps_at_slo,
           "points_per_sec_at_slo": qps_at_slo * args.request_size,
           "p50_ms": at["p50_ms"], "p99_ms": at["p99_ms"],
           "shed_rate": at["shed_rate"], "cache_hit_rate": hit_rate,
           "closed_loop_qps": closed["qps"],
           "closed_loop_p99_ms": closed["p99_ms"],
           "n_clients": args.clients, "trace": bool(args.trace),
           **breakdown}
    n_runs = common.append_bench_run(run, OUT_PATH)
    print(f"wrote {os.path.normpath(OUT_PATH)} ({n_runs} runs)")


if __name__ == "__main__":
    main()
