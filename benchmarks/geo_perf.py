"""Geo-engine perf hillclimb harness: stage-level wall-clock breakdown of
the fast approach on CPU (the paper-representative cell of §Perf).

    PYTHONPATH=src python -m benchmarks.geo_perf
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.fast import FastConfig, FastIndex, assign_fast, \
    leaf_codes, locate_cells


def t(fn, *a, r=5):
    out = fn(*a)
    jax.block_until_ready(out)
    ts = []
    for _ in range(r):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main():
    census = common.get_census().census
    cov = common.get_covering(9)
    n = 1_000_000
    xy, bid, *_ = common.sample_points(n)
    pts = jnp.asarray(xy)
    print(f"n={n} points, {len(cov.lo)} cells")

    for gbits in (0, 4, 6):
        idx = FastIndex.from_covering(cov, census, gbits=gbits)
        dt_codes = t(jax.jit(lambda p: leaf_codes(idx, p)), pts)
        codes = leaf_codes(idx, pts)
        dt_locate = t(jax.jit(lambda c: locate_cells(idx, c)), codes)
        for mode in ("approx", "exact"):
            cfg = FastConfig(mode=mode, cap_boundary=0.25)
            f = jax.jit(lambda p: assign_fast(idx, p, cfg)[2])
            dt_full = t(f, pts)
            acc = float(np.mean(np.asarray(f(pts)) == bid))
            print(f"G{gbits} {mode:6s}: full {dt_full*1e3:7.1f}ms "
                  f"({n/dt_full/1e6:5.2f}M pts/s) | codes "
                  f"{dt_codes*1e3:5.1f}ms locate {dt_locate*1e3:6.1f}ms "
                  f"(iters={idx.search_iters}) | acc {acc:.4f}")


if __name__ == "__main__":
    main()
