"""Geo-engine perf hillclimb harness: points/sec for every GeoEngine
strategy plus the fast path's stage-level breakdown, on CPU (the
paper-representative cell of §Perf).

    PYTHONPATH=src python -m benchmarks.geo_perf            # full run
    PYTHONPATH=src python -m benchmarks.geo_perf --smoke    # verify-sized

``--smoke`` caps the batch at BENCH_GEO_SMOKE_N (default 20k) and skips
the gbits stage sweep so scripts/verify.sh can afford to append a row on
every run — the bench trajectory accumulates with the test history.

Emits ``results/BENCH_geo.json`` — machine-readable points/sec + accuracy
per strategy — so the bench trajectory accumulates across PRs.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.engine import EngineConfig, GeoEngine
from repro.core.fast import FastIndex, leaf_codes, locate_cells

N_POINTS = int(os.environ.get("BENCH_GEO_N", 1_000_000))
SMOKE_N = int(os.environ.get("BENCH_GEO_SMOKE_N", 20_000))
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_geo.json")


def t(fn, *a, r=5):
    out = fn(*a)
    jax.block_until_ready(out)
    ts = []
    for _ in range(r):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_strategies(census, cov, pts, bid, repeats=5):
    """points/sec + accuracy for simple / fast-exact (legacy + fused) /
    fast-approx / hybrid, all through the GeoEngine facade."""
    n = pts.shape[0]
    results = {}
    specs = {
        "simple": ("simple", EngineConfig()),
        "fast_exact": ("fast", EngineConfig(mode="exact")),
        "fast_exact_fused": ("fast", EngineConfig(mode="exact",
                                                  fused=True)),
        "fast_approx": ("fast", EngineConfig(mode="approx")),
        "hybrid": ("hybrid", EngineConfig()),
        # The planner's pick for this device/map/batch — its row records
        # the chosen GeoPlan, so the bench history shows when the auto
        # heuristics and the measured winner disagree.
        "auto": ("auto", EngineConfig()),
    }
    for name, (strategy, cfg) in specs.items():
        eng = GeoEngine.build(census, strategy, cfg, covering=cov)
        # One jitted callable serves both timing and the row's stats
        # (one compile per strategy); t() blocks on the whole pytree, so
        # the timed quantity includes the stats scalars — the serving
        # path computes them anyway, and they are reductions over work
        # already done.
        f = jax.jit(lambda p, e=eng: e.assign(p))
        dt = t(f, pts, r=repeats)
        res = f(pts)
        acc = float(np.mean(np.asarray(res.block) == bid))
        # GeoStats counters ride in every row (as_dict: n_need / n_pip /
        # overflow / phase2_miss / boundary count) so the bench history
        # catches silent degradation — a capacity squeeze or a phase-2
        # miss creep shows up even when points/sec holds steady.
        stats = res.stats.as_dict()
        # Every row records the engine's plan (strategy/mode/fused +
        # reasons; the planner's own choice for the "auto" row) so bench
        # history ties numbers to the execution plan that produced them.
        results[name] = {"pts_per_sec": n / dt, "wall_ms": dt * 1e3,
                         "accuracy": acc, "plan": eng.explain(), **stats}
        tag = f" -> {eng.strategy}" if strategy == "auto" else ""
        print(f"{name:16s}: {dt*1e3:7.1f}ms ({n/dt/1e6:5.2f}M pts/s) "
              f"acc {acc:.4f} | boundary {stats['n_boundary']} "
              f"pip {stats['n_pip']} overflow {stats['overflow']} "
              f"p2miss {stats['phase2_miss']}{tag}")
    return results


def bench_fast_stages(census, cov, pts, bid):
    """The original gbits sweep: stage-level breakdown of the fast path."""
    n = pts.shape[0]
    for gbits in (0, 4, 6):
        idx = FastIndex.from_covering(cov, census, gbits=gbits)
        dt_codes = t(jax.jit(lambda p: leaf_codes(idx, p)), pts)
        codes = leaf_codes(idx, pts)
        dt_locate = t(jax.jit(lambda c: locate_cells(idx, c)), codes)
        for mode in ("approx", "exact"):
            eng = GeoEngine(
                "fast", EngineConfig(mode=mode, cap_boundary=0.25),
                fast_index=idx)
            f = jax.jit(lambda p, e=eng: e.assign(p).block)
            dt_full = t(f, pts)
            acc = float(np.mean(np.asarray(f(pts)) == bid))
            print(f"G{gbits} {mode:6s}: full {dt_full*1e3:7.1f}ms "
                  f"({n/dt_full/1e6:5.2f}M pts/s) | codes "
                  f"{dt_codes*1e3:5.1f}ms locate {dt_locate*1e3:6.1f}ms "
                  f"(iters={idx.search_iters}) | acc {acc:.4f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="verify-sized run: small batch, no stage sweep")
    args = ap.parse_args()
    n_points = min(N_POINTS, SMOKE_N) if args.smoke else N_POINTS

    census = common.get_census().census
    cov = common.get_covering(9)
    xy, bid, *_ = common.sample_points(n_points)
    pts = jnp.asarray(xy)
    print(f"n={n_points} points, {len(cov.lo)} cells"
          + (" [smoke]" if args.smoke else ""))

    results = bench_strategies(census, cov, pts, bid,
                               repeats=3 if args.smoke else 5)
    if not args.smoke:
        bench_fast_stages(census, cov, pts, bid)

    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "n_points": n_points, "n_cells": int(len(cov.lo)),
           "smoke": bool(args.smoke),
           "backend": jax.default_backend(), "strategies": results}
    n_runs = common.append_bench_run(run, OUT_PATH)
    print(f"wrote {os.path.normpath(OUT_PATH)} ({n_runs} runs)")


if __name__ == "__main__":
    main()
