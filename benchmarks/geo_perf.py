"""Geo-engine perf hillclimb harness: points/sec for every GeoEngine
strategy plus the fast path's stage-level breakdown, on CPU (the
paper-representative cell of §Perf).

    PYTHONPATH=src python -m benchmarks.geo_perf            # full run
    PYTHONPATH=src python -m benchmarks.geo_perf --smoke    # verify-sized

``--smoke`` caps the batch at BENCH_GEO_SMOKE_N (default 20k) and skips
the gbits stage sweep so scripts/verify.sh can afford to append a row on
every run — the bench trajectory accumulates with the test history.

Emits ``results/BENCH_geo.json`` — machine-readable points/sec + accuracy
per strategy — so the bench trajectory accumulates across PRs.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.roofline import geo_roofline
from repro.core.artifact import GeoIndexSet
from repro.core.engine import EngineConfig, GeoEngine
from repro.core.fast import FastIndex, leaf_codes, locate_cells

N_POINTS = int(os.environ.get("BENCH_GEO_N", 1_000_000))
SMOKE_N = int(os.environ.get("BENCH_GEO_SMOKE_N", 20_000))
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_geo.json")
TUNED_INDEX_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "results", "tuned_index")
# Edge-pool block sizes the one-pass sweep tries (the kernel's DMA
# granularity: bigger blocks amortize DMA issue, smaller ones waste less
# on short polygons).  Smoke keeps two candidates so verify stays cheap.
BE_SWEEP = (128, 256, 512)
BE_SWEEP_SMOKE = (128, 256)


def t(fn, *a, r=5):
    out = fn(*a)
    jax.block_until_ready(out)
    ts = []
    for _ in range(r):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bench_row(eng, pts, bid, repeats):
    """One bench row for a built engine.  One jitted callable serves
    both timing and the row's stats (one compile per strategy); t()
    blocks on the whole pytree, so the timed quantity includes the stats
    scalars — the serving path computes them anyway, and they are
    reductions over work already done.

    GeoStats counters ride in every row (as_dict: n_need / n_pip /
    overflow / phase2_miss / boundary count) so the bench history catches
    silent degradation — a capacity squeeze or a phase-2 miss creep shows
    up even when points/sec holds steady.  Every row also records the
    engine's plan (strategy/mode/fused + reasons) so bench history ties
    numbers to the execution plan that produced them."""
    n = pts.shape[0]
    f = jax.jit(lambda p, e=eng: e.assign(p))
    dt = t(f, pts, r=repeats)
    res = f(pts)
    acc = float(np.mean(np.asarray(res.block) == bid))
    stats = res.stats.as_dict()
    return {"pts_per_sec": n / dt, "wall_ms": dt * 1e3,
            "accuracy": acc, "plan": eng.explain(), **stats}


def _print_row(name, row, tag=""):
    print(f"{name:16s}: {row['wall_ms']:7.1f}ms "
          f"({row['pts_per_sec']/1e6:5.2f}M pts/s) "
          f"acc {row['accuracy']:.4f} | boundary {row['n_boundary']} "
          f"pip {row['n_pip']} overflow {row['overflow']} "
          f"p2miss {row['phase2_miss']}{tag}")


def bench_strategies(census, cov, pts, bid, repeats=5, tuned_iset=None,
                     roof=None):
    """points/sec + accuracy for simple / fast-exact (legacy + fused) /
    fast-onepass / fast-approx / hybrid, all through the GeoEngine
    facade.  ``tuned_iset`` (from ``autotune_onepass``) supplies the
    fast_onepass row's artifact so it runs at the tuned edge-pool block
    size, with the tuning record and roofline fraction in the row."""
    results = {}
    specs = {
        "simple": ("simple", EngineConfig()),
        "fast_exact": ("fast", EngineConfig(mode="exact")),
        "fast_exact_fused": ("fast", EngineConfig(mode="exact",
                                                  fused=True)),
        "fast_approx": ("fast", EngineConfig(mode="approx")),
        "hybrid": ("hybrid", EngineConfig()),
        # The planner's pick for this device/map/batch — its row records
        # the chosen GeoPlan, so the bench history shows when the auto
        # heuristics and the measured winner disagree.
        "auto": ("auto", EngineConfig()),
    }
    for name, (strategy, cfg) in specs.items():
        eng = GeoEngine.build(census, strategy, cfg, covering=cov)
        row = results[name] = _bench_row(eng, pts, bid, repeats)
        _print_row(name, row,
                   f" -> {eng.strategy}" if strategy == "auto" else "")
    if tuned_iset is not None:
        eng = GeoEngine.from_index_set(tuned_iset, "fast_onepass")
        row = _bench_row(eng, pts, bid, repeats)
        row["tuning"] = dict(tuned_iset.tuning)
        if roof is not None:
            row["roofline_fraction"] = roof["roofline_fraction"]
            row["achieved_bw"] = roof["achieved_bw"]
        results["fast_onepass"] = row
        _print_row("fast_onepass", row,
                   f" be={tuned_iset.pool_be()}")
    return results


def autotune_onepass(census, cov, pts, bid, smoke, repeats=3):
    """Roofline-driven tile sweep for the one-pass cascade: try each
    edge-pool block size, race the winner against the strongest
    two-kernel baseline (fast_exact fused), and persist the measurement
    into a ``GeoIndexSet`` manifest (``results/tuned_index``) — the
    record ``core/plan.py`` reads so ``strategy="auto"`` picks the
    measured winner instead of hard-coded thresholds.

    Returns (tuned GeoIndexSet, roofline row for the tuned kernel)."""
    n = pts.shape[0]
    iset = GeoIndexSet(census=census, covering=cov)
    sweep = BE_SWEEP_SMOKE if smoke else BE_SWEEP
    best = None
    for be in sweep:
        iset.record_tuning({"be": be})   # drops pools -> repack at be
        eng = GeoEngine.from_index_set(iset, "fast_onepass")
        dt = t(jax.jit(lambda p, e=eng: e.assign(p)), pts, r=repeats)
        rate = n / dt
        print(f"autotune be={be:4d}: {dt*1e3:7.1f}ms "
              f"({rate/1e6:5.2f}M pts/s)")
        if best is None or rate > best[1]:
            best = (be, rate)
    be, rate = best
    iset.record_tuning({"be": be})
    eng_fx = GeoEngine.from_index_set(
        iset, "fast", EngineConfig(mode="exact", fused=True))
    dt_fx = t(jax.jit(lambda p, e=eng_fx: e.assign(p)), pts, r=repeats)
    rate_fx = n / dt_fx
    winner = "fast_onepass" if rate >= rate_fx else "fast_exact"
    eng_best = GeoEngine.from_index_set(iset, "fast_onepass")
    roof = geo_roofline("fast_onepass",
                        lambda p, e=eng_best: e.assign(p).block, (pts,),
                        n, repeats=repeats)
    iset.record_tuning({
        "winner": winner, "be": int(be),
        "device_kind": jax.default_backend(),
        "pts_per_sec": float(rate),
        "baseline_pts_per_sec": float(rate_fx),
        "roofline_fraction": float(roof["roofline_fraction"]),
        "recorded": time.strftime("%Y-%m-%dT%H:%M:%S"),
    })
    iset.save(TUNED_INDEX_PATH)
    print(f"autotune winner: {winner} (onepass be={be}: "
          f"{rate/1e6:.2f}M pts/s vs fast_exact_fused "
          f"{rate_fx/1e6:.2f}M pts/s; roofline "
          f"{roof['roofline_fraction']:.3f}) -> {TUNED_INDEX_PATH}")
    return iset, roof


def bench_fast_stages(census, cov, pts, bid):
    """The original gbits sweep: stage-level breakdown of the fast path."""
    n = pts.shape[0]
    for gbits in (0, 4, 6):
        idx = FastIndex.from_covering(cov, census, gbits=gbits)
        dt_codes = t(jax.jit(lambda p: leaf_codes(idx, p)), pts)
        codes = leaf_codes(idx, pts)
        dt_locate = t(jax.jit(lambda c: locate_cells(idx, c)), codes)
        for mode in ("approx", "exact"):
            eng = GeoEngine(
                "fast", EngineConfig(mode=mode, cap_boundary=0.25),
                fast_index=idx)
            f = jax.jit(lambda p, e=eng: e.assign(p).block)
            dt_full = t(f, pts)
            acc = float(np.mean(np.asarray(f(pts)) == bid))
            print(f"G{gbits} {mode:6s}: full {dt_full*1e3:7.1f}ms "
                  f"({n/dt_full/1e6:5.2f}M pts/s) | codes "
                  f"{dt_codes*1e3:5.1f}ms locate {dt_locate*1e3:6.1f}ms "
                  f"(iters={idx.search_iters}) | acc {acc:.4f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="verify-sized run: small batch, no stage sweep")
    args = ap.parse_args()
    n_points = min(N_POINTS, SMOKE_N) if args.smoke else N_POINTS

    census = common.get_census().census
    cov = common.get_covering(9)
    xy, bid, *_ = common.sample_points(n_points)
    pts = jnp.asarray(xy)
    print(f"n={n_points} points, {len(cov.lo)} cells"
          + (" [smoke]" if args.smoke else ""))

    tuned_iset, roof = autotune_onepass(census, cov, pts, bid,
                                        smoke=args.smoke,
                                        repeats=3 if args.smoke else 5)
    results = bench_strategies(census, cov, pts, bid,
                               repeats=3 if args.smoke else 5,
                               tuned_iset=tuned_iset, roof=roof)
    if not args.smoke:
        bench_fast_stages(census, cov, pts, bid)

    run = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "n_points": n_points, "n_cells": int(len(cov.lo)),
           "smoke": bool(args.smoke),
           "backend": jax.default_backend(), "strategies": results}
    n_runs = common.append_bench_run(run, OUT_PATH)
    print(f"wrote {os.path.normpath(OUT_PATH)} ({n_runs} runs)")


if __name__ == "__main__":
    main()
