"""Shared benchmark helpers: cached synthetic census + covering, timing,
and the BENCH_geo.json run-trajectory appender."""
from __future__ import annotations

import json
import os
import pickle
import time

import numpy as np

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "cache")
BENCH_GEO_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_geo.json")


def append_bench_run(run: dict, out_path: str = BENCH_GEO_PATH) -> int:
    """Append one run object to the bench trajectory file (shared by
    geo_perf and serve_perf so successive rows stay comparable); returns
    the new run count.  A corrupt/absent file restarts the trajectory."""
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    runs = []
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                runs = json.load(f).get("runs", [])
        except (json.JSONDecodeError, AttributeError):
            runs = []
    runs.append(run)
    with open(out_path, "w") as f:
        json.dump({"runs": runs}, f, indent=2)
    return len(runs)

# Benchmark-scale map: 16 states / 128 counties / 3,072 block groups.
SCALE = dict(seed=0, n_states=16, counties_per_state=8, blocks_per_county=24)


def get_census():
    from repro.core.synth import build_synth_census
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, "census.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    sc = build_synth_census(**SCALE)
    with open(path, "wb") as f:
        pickle.dump(sc, f)
    return sc


def get_covering(max_level: int = 9):
    from repro.core.cells import build_cell_covering
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"covering_L{max_level}.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    cov = build_cell_covering(get_census().census, max_level=max_level)
    with open(path, "wb") as f:
        pickle.dump(cov, f)
    return cov


def sample_points(n: int, seed: int = 7):
    return get_census().sample_points(np.random.default_rng(seed), n)


def timeit(fn, *args, repeats: int = 3):
    """Median wall time of fn(*args) after one warm-up (compile) call."""
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def stage_breakdown(snap: dict) -> dict:
    """Per-stage p50/p99 latency columns (ms) from a ServerMetrics
    snapshot's ``stages`` block — the attributed-latency columns the
    serve_slo/serve_* bench rows carry (DESIGN.md §15).  Missing stages
    (e.g. a cache-less run never observed cache stages) report None so
    rows stay schema-stable."""
    stages = snap.get("stages", {})
    out = {}
    for stage, col in (("queue_wait", "queue_wait"),
                       ("host_prepare", "host"),
                       ("device_assign", "device")):
        s = stages.get(stage) or {}
        out[f"{col}_p50_ms"] = s.get("p50")
        out[f"{col}_p99_ms"] = s.get("p99")
    return out
