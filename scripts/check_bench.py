#!/usr/bin/env python
"""Soft perf ratchet over the bench trajectory (results/BENCH_geo.json).

Compares the latest run's ``fast_exact`` / ``fast_onepass`` points/sec
against the trailing median of earlier runs at the same batch size, and
the latest ``serve_slo`` row's sustained ``qps_at_slo`` (load_perf's
throughput-under-SLO metric) against the trailing median at the same
load shape, and WARNS on a >30 % regression.  The attributed-latency
columns ratchet too: a ``queue_wait_p99_ms`` that *grew* >30 % over
the trailing median at the same load shape warns even when the
end-to-end SLO still passes (DESIGN.md §15).  The analytics rows
(DESIGN.md §16) ratchet on ``agg_per_sec_fused`` — the fused
assign→aggregate stage throughput — at the same (smoke, batch,
n_blocks) shape, plus a hard check that the row's fused/unfused counts
were bit-identical.  Deliberately non-fatal by default: the bench rows
come from shared CI machines whose load jitters, so a hard gate here
would flake — the warning plus the accumulated trajectory is the
review signal (``--strict`` upgrades warnings to exit 1 for local perf
work).  Every row family skips cleanly (prints, exits 0 even under
``--strict``) when it has no rows or no trailing history at the latest
row's shape — a fresh clone or a first-ever bench run must never fail
the ratchet.

    PYTHONPATH=src python scripts/check_bench.py [--strict]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_geo.json")
STRATEGIES = ("fast_exact", "fast_onepass")
WINDOW = 8          # trailing runs the median is taken over
THRESHOLD = 0.30    # warn when latest < (1 - THRESHOLD) * median


def strategy_rate(run: dict, strategy: str):
    """pts_per_sec for one strategy row of a geo_perf run, else None
    (roofline_geo / serve_perf runs share the file and have no
    ``strategies`` block)."""
    row = (run.get("strategies") or {}).get(strategy)
    if not row:
        return None
    rate = float(row.get("pts_per_sec") or 0.0)
    return rate if rate > 0 else None


def check_strategy(runs: list, strategy: str) -> tuple[str, bool]:
    """(human-readable verdict line, regressed?) for one strategy."""
    rows = [(r.get("n_points"), strategy_rate(r, strategy)) for r in runs]
    rows = [(n, v) for n, v in rows if v is not None]
    if not rows:
        return f"{strategy}: no bench rows yet", False
    n_latest, latest = rows[-1]
    prior = [v for n, v in rows[:-1] if n == n_latest][-WINDOW:]
    if not prior:
        return (f"{strategy}: first row at n={n_latest} "
                f"({latest/1e6:.2f}M pts/s) — no history to compare"),\
            False
    med = statistics.median(prior)
    ratio = latest / med
    line = (f"{strategy}: {latest/1e6:.2f}M pts/s vs trailing median "
            f"{med/1e6:.2f}M ({len(prior)} runs at n={n_latest}, "
            f"ratio {ratio:.2f})")
    if ratio < 1.0 - THRESHOLD:
        return (f"WARNING: {line} — >{THRESHOLD:.0%} regression", True)
    return line, False


def slo_shape(run: dict) -> tuple:
    """The load-shape key serve_slo rows are comparable under: smoke
    flag, replica count, arrival process, request size, the SLO
    itself (a row at a looser SLO is not a regression baseline), and
    whether the run traced — verify's 100%-sampled trace smoke pays a
    real span-recording cost and must not ratchet against untraced
    history (or vice versa)."""
    return (run.get("smoke"), run.get("replicas"), run.get("arrival"),
            run.get("request_size"), run.get("slo_ms"),
            bool(run.get("trace")))


def check_serve_slo(runs: list) -> tuple[str, bool]:
    """(verdict line, regressed?) for load_perf's serve_slo rows:
    ratchet on sustained qps_at_slo at the same load shape."""
    rows = [(slo_shape(r), float(r.get("qps_at_slo") or 0.0))
            for r in runs
            if r.get("bench") == "load" and r.get("kind") == "serve_slo"]
    if not rows:
        return "serve_slo: no load_perf rows yet", False
    shape, latest = rows[-1]
    if latest <= 0:
        return ("WARNING: serve_slo: latest run met the SLO at NO "
                "tested QPS (qps_at_slo=0)", True)
    prior = [q for s, q in rows[:-1] if s == shape and q > 0][-WINDOW:]
    if not prior:
        return (f"serve_slo: first row at shape {shape} "
                f"({latest:.0f} qps) — no history to compare"), False
    med = statistics.median(prior)
    ratio = latest / med
    line = (f"serve_slo: {latest:.0f} qps_at_slo vs trailing median "
            f"{med:.0f} ({len(prior)} runs at shape {shape}, "
            f"ratio {ratio:.2f})")
    if ratio < 1.0 - THRESHOLD:
        return (f"WARNING: {line} — >{THRESHOLD:.0%} regression", True)
    return line, False


def check_queue_wait(runs: list) -> tuple[str, bool]:
    """(verdict line, regressed?) for the attributed-latency columns
    (DESIGN.md §15): warn when the latest serve_slo row's queue_wait
    p99 grew >THRESHOLD over the trailing median at the same load
    shape — the stage that grows when the flusher or replica pool falls
    behind, caught before the end-to-end SLO breaks."""
    rows = [(slo_shape(r), r.get("queue_wait_p99_ms"))
            for r in runs
            if r.get("bench") == "load" and r.get("kind") == "serve_slo"]
    rows = [(s, float(q)) for s, q in rows if q is not None]
    if not rows:
        return "queue_wait: no attributed serve_slo rows yet", False
    shape, latest = rows[-1]
    prior = [q for s, q in rows[:-1] if s == shape and q > 0][-WINDOW:]
    if not prior:
        return (f"queue_wait: first attributed row at shape {shape} "
                f"(p99 {latest:.3f}ms) — no history to compare"), False
    med = statistics.median(prior)
    ratio = latest / med
    line = (f"queue_wait: p99 {latest:.3f}ms vs trailing median "
            f"{med:.3f}ms ({len(prior)} runs at shape {shape}, "
            f"ratio {ratio:.2f})")
    if ratio > 1.0 + THRESHOLD:
        return (f"WARNING: {line} — queue_wait p99 grew "
                f">{THRESHOLD:.0%}", True)
    return line, False


def check_analytics(runs: list) -> tuple[str, bool]:
    """(verdict line, regressed?) for analytics_perf rows: ratchet on
    the fused assign→aggregate stage throughput at the same
    (smoke, batch, n_blocks) shape, and flag any row whose fused and
    unfused per-block counts were not bit-identical (the bench asserts
    this itself, but a hand-edited or merged history should not pass
    silently)."""
    rows = [r for r in runs if r.get("bench") == "analytics"]
    if not rows:
        return "analytics: no bench rows yet", False
    latest = rows[-1]
    if not latest.get("counts_equal", True):
        return ("WARNING: analytics: latest row's fused/unfused counts "
                "were NOT bit-identical", True)
    shape = (latest.get("smoke"), latest.get("batch"),
             latest.get("n_blocks"))
    rate = float(latest.get("agg_per_sec_fused") or 0.0)
    if rate <= 0:
        return "analytics: latest row has no agg_per_sec_fused", False
    prior = [float(r.get("agg_per_sec_fused") or 0.0) for r in rows[:-1]
             if (r.get("smoke"), r.get("batch"),
                 r.get("n_blocks")) == shape
             and float(r.get("agg_per_sec_fused") or 0.0) > 0][-WINDOW:]
    if not prior:
        return (f"analytics: first row at shape {shape} "
                f"({rate/1e6:.1f}M agg/s fused) — no history to "
                f"compare"), False
    med = statistics.median(prior)
    ratio = rate / med
    line = (f"analytics: {rate/1e6:.1f}M agg/s fused vs trailing median "
            f"{med/1e6:.1f}M ({len(prior)} runs at shape {shape}, "
            f"ratio {ratio:.2f})")
    if ratio < 1.0 - THRESHOLD:
        return (f"WARNING: {line} — >{THRESHOLD:.0%} regression", True)
    return line, False


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default=DEFAULT_PATH)
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on a regression warning")
    args = ap.parse_args()
    if not os.path.exists(args.path):
        print(f"check_bench: no {args.path} — nothing to check")
        return 0
    try:
        with open(args.path) as f:
            runs = json.load(f).get("runs", [])
    except (json.JSONDecodeError, AttributeError) as e:
        print(f"check_bench: unreadable {args.path} ({e}) — skipping")
        return 0
    regressed = False
    for strategy in STRATEGIES:
        line, bad = check_strategy(runs, strategy)
        print(f"check_bench: {line}")
        regressed = regressed or bad
    line, bad = check_serve_slo(runs)
    print(f"check_bench: {line}")
    regressed = regressed or bad
    line, bad = check_queue_wait(runs)
    print(f"check_bench: {line}")
    regressed = regressed or bad
    line, bad = check_analytics(runs)
    print(f"check_bench: {line}")
    regressed = regressed or bad
    return 1 if (regressed and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
