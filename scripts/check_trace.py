#!/usr/bin/env python
"""Validate a Chrome-trace file exported by the serving tracer.

    python scripts/check_trace.py results/trace_load.chrome.json

The --trace smoke in verify.sh runs load_perf at 100% sampling and then
asserts, via this script, that the export is a *structurally valid*
per-request timeline — the acceptance criterion for DESIGN.md §15:

  * the file is JSON with a non-empty ``traceEvents`` list;
  * every serve stage appears somewhere: ``request`` (the root),
    ``queue_wait``, ``host_prepare``, ``device_assign``, ``merge``;
  * grouping "X" events by ``args.trace_id``: every trace has exactly
    one ``request`` root, and every child interval nests inside the
    root's [ts, ts+dur] (small epsilon for float microseconds);
  * every child's ``parent_id`` resolves to a span in the same trace.

Exit 0 with a one-line summary on success; exit 1 with the first
violation otherwise.
"""
import json
import sys
from collections import defaultdict

# Host clocks are rebased to microseconds through floats; tolerate a
# microsecond of rounding when checking containment.
EPS_US = 1.0

REQUIRED_STAGES = {"request", "queue_wait", "host_prepare",
                   "device_assign", "merge"}


def fail(msg: str) -> None:
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main(path: str) -> None:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: no traceEvents")

    spans = [e for e in events if e.get("ph") == "X"]
    if not spans:
        fail(f"{path}: no complete ('X') events")
    names = {e["name"] for e in spans}
    missing = REQUIRED_STAGES - names
    if missing:
        fail(f"{path}: required stages never recorded: {sorted(missing)}")

    traces = defaultdict(list)
    for e in spans:
        args = e.get("args", {})
        if "trace_id" not in args:
            fail(f"event {e.get('name')!r} lacks args.trace_id")
        traces[args["trace_id"]].append(e)

    n_children = 0
    for tid, evs in sorted(traces.items()):
        roots = [e for e in evs if e["name"] == "request"]
        if len(roots) != 1:
            fail(f"trace {tid}: {len(roots)} 'request' roots (want 1)")
        root = roots[0]
        r0, r1 = root["ts"], root["ts"] + root["dur"]
        ids = {e["args"]["span_id"] for e in evs}
        for e in evs:
            if e is root:
                continue
            n_children += 1
            t0, t1 = e["ts"], e["ts"] + e["dur"]
            if t0 < r0 - EPS_US or t1 > r1 + EPS_US:
                fail(f"trace {tid}: child {e['name']!r} "
                     f"[{t0:.1f}, {t1:.1f}]us outside root "
                     f"[{r0:.1f}, {r1:.1f}]us")
            parent = e["args"].get("parent_id")
            if parent is None or parent not in ids:
                fail(f"trace {tid}: child {e['name']!r} parent_id "
                     f"{parent!r} does not resolve in its trace")
    print(f"check_trace: OK: {len(traces)} request timelines, "
          f"{n_children} child spans, stages {sorted(names)}")


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    main(sys.argv[1])
