#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins (ROADMAP.md), then a
# smoke-sized benchmarks/geo_perf run so every verify appends a row to
# results/BENCH_geo.json (the bench trajectory accumulates with the test
# history).  The smoke bench runs even when pytest fails (known-failing
# model-stack tests must not starve the bench record).  Exit status:
# pytest's failure wins; a bench failure surfaces only when pytest passed.
# Usage: scripts/verify.sh [extra pytest args]
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
python -m pytest -x -q "$@"
status=$?
python -m benchmarks.geo_perf --smoke
bench=$?
[ "$status" -eq 0 ] && status=$bench
exit $status
