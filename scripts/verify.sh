#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins (ROADMAP.md), then
# smoke-sized benchmark runs so every verify appends rows to
# results/BENCH_geo.json (the bench trajectory accumulates with the test
# history): benchmarks/geo_perf (batch strategies) and
# benchmarks/serve_perf (the GeoServer serving path — serve_* rows).
# The smoke benches run even when pytest fails (known-failing model-stack
# tests must not starve the bench record).  Exit status: pytest's failure
# wins; a bench failure surfaces only when pytest passed.
# Usage: scripts/verify.sh [extra pytest args]
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
python -m pytest -x -q "$@"
status=$?
python -m benchmarks.geo_perf --smoke
bench=$?
python -m benchmarks.serve_perf --smoke
serve_bench=$?
[ "$bench" -eq 0 ] && bench=$serve_bench
[ "$status" -eq 0 ] && status=$bench
exit $status
