#!/usr/bin/env bash
# Tier-1 verify with a baseline gate, then smoke-sized benchmark +
# artifact runs so every verify appends rows to results/BENCH_geo.json
# (the bench trajectory accumulates with the test history):
#
#   1. full pytest run (no -x: the baseline gate needs complete counts);
#   2. scripts/check_tier1.py prints the pass/fail delta vs the recorded
#      seed baseline (scripts/tier1_baseline.json) and fails the verify
#      on any regression — pytest's raw exit status is informational
#      (the baseline's known model-stack failures are expected);
#   2b. scripts/check_static.py — the GeoLint static-analysis ratchet
#      (lock discipline, wallclock, compat boundary, trace purity,
#      dead code; DESIGN.md §17) vs scripts/static_baseline.json —
#      then a REPRO_LOCKCHECK=1 rerun of the frontend + analytics
#      concurrency batteries under the runtime lock-order detector;
#   3. benchmarks/geo_perf --smoke, benchmarks/serve_perf --smoke, and
#      benchmarks/load_perf --smoke (sustained-QPS-at-SLO through the
#      concurrent AsyncGeoServer front-end — the serve_slo row) — run
#      even on test failure: known-failing model-stack tests must not
#      starve the bench record.  load_perf runs with --trace at 100%
#      sampling and scripts/check_trace.py validates the exported
#      Chrome trace (per-request timeline reconstruction, §15);
#      benchmarks/trace_overhead --smoke enforces the tracing overhead
#      budget (tracer-off and 1%-sampled within 3% of untraced);
#   4. benchmarks/roofline --geo --smoke — achieved-vs-peak bandwidth
#      rows for the geo kernels appended to the same trajectory, then
#      scripts/check_bench.py (soft perf ratchet: warns, never fails,
#      on a >30% regression vs the trailing median — points/sec and
#      qps_at_slo alike);
#   5. benchmarks/analytics_perf --smoke — fused vs unfused per-block
#      aggregation (bit-identity asserted in-bench) + windowed
#      streaming throughput rows appended to the same trajectory
#      (DESIGN.md §16);
#   6. scripts/artifact_smoke.py — GeoIndexSet save/load round trip
#      (the serving cold-start path) checked bit-identical — and
#      scripts/analytics_smoke.py — windowed-analytics snapshot schema,
#      event conservation, k-anonymity suppression, and window-state
#      merge associativity under a deterministic injected clock.
#
# Exit status: the baseline gate's verdict wins; bench/smoke failures
# surface only when the gate passed.
# Usage: scripts/verify.sh [extra pytest args]
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

pytest_log=$(mktemp)
trap 'rm -f "$pytest_log"' EXIT
python -m pytest -q "$@" 2>&1 | tee "$pytest_log"
python scripts/check_tier1.py "$pytest_log"
status=$?

# GeoLint static-analysis ratchet (DESIGN.md §17): per-rule finding
# counts gated against scripts/static_baseline.json — regressions AND
# stale baselines both fail.
python scripts/check_static.py
static=$?
[ "$status" -eq 0 ] && status=$static

# Runtime lock-order / guarded-write detector over the concurrency
# batteries: instruments the §14 locks and fails on any acquisition
# cycle or annotated-field write without its lock held.
REPRO_LOCKCHECK=1 python -m pytest -q tests/test_frontend.py \
    tests/test_analytics.py
lockcheck=$?
[ "$status" -eq 0 ] && status=$lockcheck

python -m benchmarks.geo_perf --smoke
bench=$?
python -m benchmarks.serve_perf --smoke
serve_bench=$?
# --trace at 100% sampling: the smoke's Chrome trace must reconstruct
# valid per-request timelines (scripts/check_trace.py, DESIGN.md §15).
python -m benchmarks.load_perf --smoke --trace --trace-sample 1.0 \
    --trace-out results/trace_load
load_bench=$?
python scripts/check_trace.py results/trace_load.chrome.json
trace_check=$?
python -m benchmarks.trace_overhead --smoke
overhead=$?
python -m benchmarks.roofline --geo --smoke
roofline=$?
python -m benchmarks.analytics_perf --smoke
analytics_bench=$?
python scripts/check_bench.py   # soft ratchet: informational exit only
python scripts/artifact_smoke.py
smoke=$?
python scripts/analytics_smoke.py
analytics_smoke=$?
[ "$bench" -eq 0 ] && bench=$serve_bench
[ "$bench" -eq 0 ] && bench=$load_bench
[ "$bench" -eq 0 ] && bench=$trace_check
[ "$bench" -eq 0 ] && bench=$overhead
[ "$bench" -eq 0 ] && bench=$roofline
[ "$bench" -eq 0 ] && bench=$analytics_bench
[ "$bench" -eq 0 ] && bench=$smoke
[ "$bench" -eq 0 ] && bench=$analytics_smoke
[ "$status" -eq 0 ] && status=$bench
exit $status
