"""Run the GeoLint static-analysis suite and ratchet per-rule finding
counts against scripts/static_baseline.json (tier-1 semantics —
mirrors check_tier1.py).

    python scripts/check_static.py                 # ratchet against baseline
    python scripts/check_static.py --strict        # any finding fails
    python scripts/check_static.py --update-baseline

Exit status: 0 only when every rule matches the ratchet exactly.  1 on:
  * a regression — a rule with more findings than recorded (the new
    findings are printed);
  * a STALE baseline — a rule with fewer findings than recorded.  A PR
    that fixes findings must tighten the baseline in the same PR, or
    the gate silently tolerates that much rot forever.

Scope: all six rules over src/repro; the portable rules (wallclock,
compat-boundary) additionally over benchmarks/, examples/, scripts/,
and tests/.  Per-line suppression: ``# geolint: ignore[rule] -- reason``
(DESIGN.md §17).
"""
import argparse
import datetime
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "scripts", "static_baseline.json")
SRC_ROOTS = [os.path.join(REPO, "src", "repro")]
WIDE_ROOTS = [os.path.join(REPO, d)
              for d in ("benchmarks", "examples", "scripts", "tests")]

sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis import ALL_RULES, counts_by_rule, run_all  # noqa: E402


def _relpath(findings):
    for f in findings:
        yield type(f)(f.rule, os.path.relpath(f.path, REPO), f.line,
                      f.message)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--strict", action="store_true",
                    help="fail on any finding, baseline ignored")
    ap.add_argument("--update-baseline", action="store_true",
                    help="record current per-rule counts as the baseline")
    args = ap.parse_args()

    findings = list(_relpath(run_all(SRC_ROOTS, WIDE_ROOTS)))
    counts = counts_by_rule(findings)

    if args.update_baseline:
        with open(BASELINE, "w") as f:
            json.dump({"recorded": datetime.date.today().isoformat(),
                       "rules": counts}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"static baseline recorded: {counts}")
        return 0

    if args.strict:
        for f in findings:
            print(f.render())
        print(f"geolint --strict: {len(findings)} finding(s)")
        return 1 if findings else 0

    with open(BASELINE) as f:
        base = json.load(f)
    base_rules = base.get("rules", {})
    status = 0
    for rule in sorted(set(ALL_RULES) | set(base_rules) | set(counts)):
        have = counts.get(rule, 0)
        want = base_rules.get(rule, 0)
        delta = have - want
        print(f"geolint {rule}: {have} finding(s) ({delta:+d} vs "
              f"baseline {base.get('recorded', '?')})")
        if delta > 0:
            print(f"geolint REGRESSION: rule '{rule}' gained {delta} "
                  f"finding(s):")
            for f in findings:
                if f.rule == rule:
                    print(f"  {f.render()}")
            status = 1
        elif delta < 0:
            print(f"geolint STALE BASELINE: rule '{rule}' has {-delta} "
                  f"fewer finding(s) than recorded — run "
                  f"check_static.py --update-baseline in this PR so the "
                  f"gate cannot drift back")
            status = 1
    return status


if __name__ == "__main__":
    sys.exit(main())
