"""Index-artifact save/load smoke for scripts/verify.sh: build a small
GeoIndexSet, round-trip it through disk, and insist the reloaded engine
assigns bit-identically.  Fast (<~30 s on CPU) — this guards the serving
cold-start path on every verify, not just when test_plan.py runs.
"""
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core.artifact import GeoIndexSet
from repro.core.engine import EngineConfig, GeoEngine
from repro.core.synth import build_synth_census


def main() -> int:
    sc = build_synth_census(seed=2, n_states=4, counties_per_state=3,
                            blocks_per_county=8)
    cfg = EngineConfig(backend="ref", max_level=6, fused=True)
    idx = GeoIndexSet.build(sc.census, components=("simple", "fast"),
                            pools=("simple", "fast"),
                            max_level=cfg.max_level)
    xy, bid, *_ = sc.sample_points(np.random.default_rng(2), 2048)
    pts = jnp.asarray(xy)
    with tempfile.TemporaryDirectory() as tmp:
        idx.save(tmp)
        loaded = GeoIndexSet.load(tmp)
        for strategy in ("simple", "fast", "hybrid"):
            a = GeoEngine.from_index_set(idx, strategy, cfg).assign(pts)
            b = GeoEngine.from_index_set(loaded, strategy, cfg).assign(pts)
            if not np.array_equal(np.asarray(a.block),
                                  np.asarray(b.block)):
                print(f"artifact smoke FAILED: {strategy} diverged "
                      f"after reload")
                return 1
    print("artifact smoke OK: save/load round trip bit-identical "
          "(simple, fast-fused, hybrid)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
