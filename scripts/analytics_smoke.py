"""Windowed-analytics invariant smoke for scripts/verify.sh: drive a
``WindowedAggregator`` with a deterministic injected clock and insist on
the DESIGN.md §16 contracts — snapshot schema, conservation of observed
events across panes, window finalization order, k-anonymity suppression
(suppressed blocks carry counts internally but never surface in
``top_k``/``as_dict``), and merge associativity of the window state.
Fast (<~5 s) — this guards the serving analytics mount on every verify,
not just when test_analytics.py runs.
"""
import sys

import numpy as np

from repro.analytics import (AnalyticsConfig, WindowState,
                             WindowedAggregator)

SNAP_KEYS = {"config", "observed", "off_map", "late_dropped",
             "open_panes", "finalized_total", "finalized", "open"}
WIN_KEYS = {"start", "end", "n_events", "active_blocks",
            "suppressed_blocks", "k_anon", "top"}


def fail(msg: str) -> int:
    print(f"analytics smoke FAILED: {msg}")
    return 1


def main() -> int:
    n_blocks = 64
    tick = [0.0]
    cfg = AnalyticsConfig(window_s=10.0, slide_s=5.0, k_anon=3,
                          sketch_bits=1024, allowed_lateness_s=5.0,
                          clock=lambda: tick[0])
    agg = WindowedAggregator(n_blocks, cfg)
    rng = np.random.default_rng(7)

    # Block 0 gets heavy distinct traffic; block 1 gets 2 sources —
    # under the k_anon floor, so it must be suppressed in every window.
    observed = 0
    for step in range(8):            # one batch per 5 s pane, ts 0..35
        ts = float(step * 5)
        bids = np.concatenate([np.zeros(20, np.int64),
                               np.ones(4, np.int64),
                               rng.integers(2, n_blocks, 40)])
        srcs = np.concatenate([np.arange(20) + 1000 * step,
                               np.array([7, 8, 7, 8]),
                               rng.integers(0, 1 << 16, 40)])
        observed += agg.observe(ts, bids, srcs)
    agg.advance(100.0)               # watermark past everything

    snap = agg.snapshot()
    if set(snap) != SNAP_KEYS:
        return fail(f"snapshot keys {sorted(snap)} != {sorted(SNAP_KEYS)}")
    if snap["observed"] != observed:
        return fail(f"observed {snap['observed']} != fed {observed}")
    if snap["late_dropped"] != 0 or snap["off_map"] != 0:
        return fail("unexpected late/off-map drops with in-order feed")
    wins = snap["finalized"]
    if not wins:
        return fail("no finalized windows after watermark advance")
    starts = [w["start"] for w in wins]
    if starts != sorted(starts):
        return fail(f"finalized windows out of order: {starts}")
    for w in wins:
        if set(w) != WIN_KEYS:
            return fail(f"window keys {sorted(w)} != {sorted(WIN_KEYS)}")
        if w["end"] - w["start"] != cfg.window_s:
            return fail(f"window span {w['end'] - w['start']} != "
                        f"{cfg.window_s}")
    # Every event landed in-window, so full windows hold 2 panes x 64.
    full = [w for w in wins if w["start"] >= 0.0 and w["end"] <= 40.0]
    if not full or any(w["n_events"] != 128 for w in full):
        return fail(f"full-window event counts "
                    f"{[w['n_events'] for w in full]} != 128")
    # Suppression: block 1 saw only 2 distinct sources < k_anon=3, so
    # every published view must hide it while the raw WindowSnapshot
    # keeps its counts intact.
    raw = {(s.start, s.end): s for s in agg.finalized}
    for w in full:
        s = raw[(w["start"], w["end"])]
        if not s.suppressed[1]:
            return fail(f"block 1 (2 sources < k_anon=3) not suppressed "
                        f"in window [{w['start']}, {w['end']})")
        if s.suppressed[0]:
            return fail("block 0 (20 distinct sources) wrongly "
                        "suppressed")
        if w["suppressed_blocks"] < 1:
            return fail("suppressed_blocks count missing suppression")
        if any(row["block"] == 1 for row in w["top"]):
            return fail("suppressed block 1 leaked into published top")
        if s.counts[1] != 8:         # raw counts stay intact internally
            return fail(f"suppression zeroed raw counts "
                        f"({s.counts[1]} != 8)")

    # Merge associativity on raw window state.
    states = []
    for seed in range(3):
        r = np.random.default_rng(seed)
        s = WindowState(n_blocks, cfg.sketch_bits)
        s.observe(r.integers(0, n_blocks, 100),
                  r.integers(0, 1 << 16, 100))
        states.append(s)
    a, b, c = states
    left, right = a.merge(b).merge(c), a.merge(b.merge(c))
    if not (np.array_equal(left.counts, right.counts)
            and np.array_equal(left.sketch.bitmap, right.sketch.bitmap)
            and left.n_events == right.n_events):
        return fail("window-state merge is not associative")

    print(f"analytics smoke OK: {len(wins)} windows finalized, schema + "
          f"conservation + suppression + merge associativity hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
