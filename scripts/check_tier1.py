"""Compare a pytest run's summary line against the recorded tier-1
baseline (scripts/tier1_baseline.json) and print the delta.

    python scripts/check_tier1.py <pytest-output-file>

Exit status: 0 when the failed count is at or below the baseline's,
1 on a regression (more failures than recorded) or an unparseable run
(a collection error must read as a regression, not a pass).  Improving
runs print a reminder to re-record the baseline.
"""
import json
import os
import re
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "tier1_baseline.json")


def parse_counts(text: str) -> dict:
    """Counts from pytest's final summary line, e.g.
    '27 failed, 123 passed, 2 skipped in 195.09s'."""
    counts = {"failed": 0, "passed": 0, "skipped": 0, "error": 0}
    found = False
    for kind in counts:
        m = re.findall(rf"(\d+) {kind}", text)
        if m:
            counts[kind] = int(m[-1])
            found = True
    if not found:
        raise ValueError("no pytest summary line found")
    return counts


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 1
    with open(BASELINE) as f:
        base = json.load(f)
    try:
        with open(sys.argv[1], errors="replace") as f:
            counts = parse_counts(f.read())
    except (OSError, ValueError) as e:
        print(f"tier-1 gate: cannot read run summary ({e}) — treating "
              f"as a regression")
        return 1
    failed = counts["failed"] + counts["error"]
    d_fail = failed - base["failed"]
    d_pass = counts["passed"] - base["passed"]
    print(f"tier-1 vs baseline ({base['recorded']}): "
          f"{failed} failed ({d_fail:+d}), "
          f"{counts['passed']} passed ({d_pass:+d}), "
          f"{counts['skipped']} skipped")
    if d_fail > 0:
        print(f"tier-1 REGRESSION: {d_fail} more failing test(s) than "
              f"the recorded baseline ({base['failed']})")
        return 1
    if d_pass < 0:
        # Fewer passing tests with no new failures means tests stopped
        # RUNNING (skipped out, deselected, deleted) — that hides
        # regressions rather than fixing them, so it gates too.
        print(f"tier-1 REGRESSION: {-d_pass} previously-passing test(s) "
              f"no longer run (baseline {base['passed']} passed)")
        return 1
    if d_fail < 0:
        print("tier-1 improved — consider re-recording "
              "scripts/tier1_baseline.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
