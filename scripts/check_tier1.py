"""Ratchet a pytest run against the recorded tier-1 baseline
(scripts/tier1_baseline.json) and print the delta.

    python scripts/check_tier1.py <pytest-output-file>

Exit status: 0 only when the run matches the ratchet exactly.  1 on:
  * a regression — more failures than recorded, or previously-passing
    tests that no longer run (skipped out / deselected / deleted);
  * an unparseable run (a collection error must read as a regression,
    not a pass);
  * a STALE baseline — fewer failures OR more passes than recorded.  A
    PR that fixes or adds tests must re-record the baseline in the same
    PR, otherwise the gate would silently tolerate that much regression
    (new failures, or deletion of the new tests) forever.
"""
import json
import os
import re
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "tier1_baseline.json")


def parse_counts(text: str) -> dict:
    """Counts from pytest's final summary line, e.g.
    '27 failed, 123 passed, 2 skipped in 195.09s'."""
    counts = {"failed": 0, "passed": 0, "skipped": 0, "error": 0}
    found = False
    for kind in counts:
        m = re.findall(rf"(\d+) {kind}", text)
        if m:
            counts[kind] = int(m[-1])
            found = True
    if not found:
        raise ValueError("no pytest summary line found")
    return counts


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 1
    with open(BASELINE) as f:
        base = json.load(f)
    try:
        with open(sys.argv[1], errors="replace") as f:
            counts = parse_counts(f.read())
    except (OSError, ValueError) as e:
        print(f"tier-1 gate: cannot read run summary ({e}) — treating "
              f"as a regression")
        return 1
    failed = counts["failed"] + counts["error"]
    d_fail = failed - base["failed"]
    d_pass = counts["passed"] - base["passed"]
    print(f"tier-1 vs baseline ({base['recorded']}): "
          f"{failed} failed ({d_fail:+d}), "
          f"{counts['passed']} passed ({d_pass:+d}), "
          f"{counts['skipped']} skipped")
    if d_fail > 0:
        print(f"tier-1 REGRESSION: {d_fail} more failing test(s) than "
              f"the recorded baseline ({base['failed']})")
        return 1
    if d_pass < 0:
        # Fewer passing tests with no new failures means tests stopped
        # RUNNING (skipped out, deselected, deleted) — that hides
        # regressions rather than fixing them, so it gates too.
        print(f"tier-1 REGRESSION: {-d_pass} previously-passing test(s) "
              f"no longer run (baseline {base['passed']} passed)")
        return 1
    if d_fail < 0:
        # The ratchet: an improvement must be locked in, not left slack.
        print(f"tier-1 STALE BASELINE: {-d_fail} fewer failing test(s) "
              f"than recorded ({base['failed']}) — tighten "
              f"scripts/tier1_baseline.json in this PR so the gate "
              f"cannot drift back")
        return 1
    if d_pass > 0:
        if counts["skipped"] == base["skipped"]:
            # Same ratchet for the passed count: tests added without
            # raising the baseline would not be protected by the
            # no-longer-run gate (a later PR could delete them and still
            # match the old floor).
            print(f"tier-1 STALE BASELINE: {d_pass} more passing test(s) "
                  f"than recorded ({base['passed']}) — record the new "
                  f"count in scripts/tier1_baseline.json so deleting "
                  f"them later reads as a regression")
            return 1
        # A different skip count means a different optional-dependency
        # environment (e.g. hypothesis installed un-skips modules): more
        # passes there is environment drift, not an untightened baseline.
        # The pinned CI image always reproduces the recorded skip count.
        print(f"tier-1 note: {d_pass} more passing test(s) with a "
              f"different skip count ({counts['skipped']} vs baseline "
              f"{base['skipped']}) — optional-dependency environment, "
              f"not gated")
    return 0


if __name__ == "__main__":
    sys.exit(main())
